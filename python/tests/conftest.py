import os
import sys

import jax

# f64 payloads (MPI_DOUBLE) require x64 before any tracing.
jax.config.update("jax_enable_x64", True)

# Make `compile.*` importable when pytest runs from python/.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
