"""Optional-hypothesis shim.

The offline test image may lack the hypothesis wheel (it cannot be pip
installed there), so test modules import `given` / `settings` / `st` /
`arrays` from here instead of from hypothesis directly.  With hypothesis
present this module is a pure re-export; without it, every `@given` test
degrades to a pytest skip while plain tests in the same module keep
running.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra.numpy import arrays

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Absorbs any strategy-building expression without evaluating it
        (strategies are constructed at decoration time, before the skip
        mark can take effect)."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

        def __or__(self, other):
            return self

        def __ror__(self, other):
            return self

    st = _Strategy()

    def arrays(*args, **kwargs):
        return _Strategy()

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn

        return decorate

    def given(*args, **kwargs):
        # Keep the original function (pytest.mark.parametrize introspects
        # its signature); the skip mark fires before fixture resolution,
        # so the hypothesis-provided parameters are never looked up.
        return pytest.mark.skip(reason="hypothesis not installed")
