"""AOT bridge: HLO text generation + manifest format.

Checks the interchange contract the Rust runtime depends on: HLO text with
an ENTRY computation, tuple return, and a parseable key=value manifest.
"""

import os

import jax
import pytest

from compile import aot, model
from compile.kernels import BLOCK


def test_to_hlo_text_contains_entry():
    fn = model.make_combine("sum")
    spec = jax.ShapeDtypeStruct((BLOCK,), model.dtype_of("i32"))
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True: the root must be a tuple so rust's to_tuple1 works.
    assert "tuple(" in text.replace(" ", "") or "(s32[2048]" in text


def test_variant_inventory_complete():
    names = [name for name, *_ in aot.variants()]
    assert len(names) == len(set(names)), "duplicate variant names"
    # 4 ops x 3 dtypes + 3 int bitwise + 3 dtypes x {inc,exc} + derive
    assert len(names) == 12 + 3 + 6 + 1
    assert "combine_sum_i32" in names
    assert "scan_exc_sum_f64" in names
    assert "derive_sub_i32" in names


def test_lower_one_variant_to_disk(tmp_path):
    name, fn, arity, record = next(iter(aot.variants()))
    line = aot.lower_variant(name, fn, arity, record, str(tmp_path))
    fields = dict(kv.split("=", 1) for kv in line.split())
    assert fields["name"] == name
    assert fields["block"] == str(BLOCK)
    assert fields["args"] == str(arity)
    path = tmp_path / fields["file"]
    assert path.exists() and path.stat().st_size > 100
    assert "ENTRY" in path.read_text()


def test_main_only_filter(tmp_path):
    aot.main(["--out-dir", str(tmp_path), "--only", "derive"])
    files = sorted(os.listdir(tmp_path))
    assert files == ["derive_sub_i32.hlo.txt", "manifest.txt"]
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "name=derive_sub_i32" in manifest
    assert manifest.startswith("#")
