"""L2 correctness: the AOT-exported graphs and the chunked-scan composition."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import arrays, given, settings, st

from compile import model
from compile.kernels import BLOCK, DTYPES, OPS, ref

_NP = {"i32": np.int32, "f32": np.float32, "f64": np.float64}


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("dt", DTYPES)
def test_make_combine_block_shape(op, dt):
    """The exported combine graph takes and returns exactly one AOT block."""
    fn = model.make_combine(op)
    a = jnp.asarray(np.arange(BLOCK) % 13, _NP[dt])
    b = jnp.asarray(np.arange(BLOCK) % 5, _NP[dt])
    (out,) = fn(a, b)
    assert out.shape == (BLOCK,) and out.dtype == _NP[dt]
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.combine_ref(a, b, op)), rtol=1e-6
    )


@pytest.mark.parametrize("inclusive", [True, False])
def test_make_scan_block(inclusive):
    fn = model.make_scan("sum", inclusive)
    x = jnp.asarray(np.arange(BLOCK) % 7, jnp.int32)
    (out,) = fn(x)
    want = ref.scan_ref(x, "sum", inclusive=inclusive)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_make_derive_block():
    fn = model.make_derive()
    own = jnp.asarray(np.arange(BLOCK) % 9, jnp.int32)
    peer = jnp.asarray(np.arange(BLOCK) % 4, jnp.int32)
    (got,) = fn(peer + own, own)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(peer))


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("nblocks", [1, 2, 3])
@pytest.mark.parametrize("inclusive", [True, False])
def test_chunked_scan_matches_ref(op, nblocks, inclusive):
    """Multi-block scan with lax.scan carry == oracle over the full payload."""
    rng = np.random.default_rng(nblocks)
    x = jnp.asarray(rng.uniform(0.5, 1.5, nblocks * BLOCK), jnp.float64)
    got = model.chunked_scan(x, op=op, inclusive=inclusive)
    want = ref.scan_ref(x, op, inclusive=inclusive)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(
    x=arrays(np.int32, st.sampled_from([BLOCK, 2 * BLOCK]), elements=st.integers(-5, 5))
)
def test_chunked_scan_carry_property(x):
    """Element BLOCK-1 of the chunked result equals the block-local scan of
    chunk 0 — the carry must not leak backwards."""
    got = np.asarray(model.chunked_scan(jnp.asarray(x), op="sum"))
    want0 = np.cumsum(x[:BLOCK], dtype=np.int32)
    np.testing.assert_array_equal(got[:BLOCK], want0)


def test_graphs_lower_without_python_closure_leaks():
    """Every exported variant must be lowerable with abstract args only —
    the precondition for AOT."""
    from compile.aot import variants

    for name, fn, arity, record in variants():
        dt = model.dtype_of(record["dtype"])
        spec = jax.ShapeDtypeStruct((BLOCK,), dt)
        lowered = jax.jit(fn).lower(*([spec] * arity))
        assert lowered is not None, name
