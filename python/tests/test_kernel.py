"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, dtypes and ops; every property asserts
allclose/exact-equality against the oracle.  This is the CORE correctness
signal for the compute datapath the Rust runtime executes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import arrays, given, settings, st

from compile.kernels import BLOCK, DTYPES, INT_OPS, OPS, combine, ref, scan

_NP_DTYPES = {"i32": np.int32, "f32": np.float32, "f64": np.float64}


# Bounded shape set: every jax trace is cached per shape, and interpret-mode
# Pallas tracing dominates test runtime — unbounded st.integers shapes would
# retrace on almost every hypothesis example.  These sizes still cover the
# edge cases: 1, non-tile-aligned, exactly-tile, tile+1, multi-tile.
SIZES = [1, 2, 3, 17, 255, combine.TILE - 1, combine.TILE, combine.TILE + 1, 2 * combine.TILE]


def payload(dtype_name, sizes=None, op=None):
    """Strategy for a 1-D payload with values kept small enough that the op
    over a block stays well-conditioned (no overflow / float blowup).  For
    float prod, values near 1.0 keep a 2048-long product finite so relative
    comparison is meaningful."""
    dt = _NP_DTYPES[dtype_name]
    if dtype_name == "i32":
        elems = st.integers(min_value=-7, max_value=7)
    elif op == "prod":
        # bounds exactly representable in binary32 (hypothesis requires it)
        elems = st.floats(
            min_value=0.90625, max_value=1.09375, allow_nan=False, allow_infinity=False, width=32
        )
    else:
        elems = st.floats(
            min_value=-4.0, max_value=4.0, allow_nan=False, allow_infinity=False, width=32
        )
    return arrays(dt, st.sampled_from(sizes or SIZES), elements=elems)


def assert_matches(got, want, dtype_name, scan_scale=None):
    """Exact match for ints; float tolerance scaled by the accumulated
    magnitude when comparing scans (Hillis-Steele associates differently
    from the oracle's associative_scan, so rounding differs legitimately).
    """
    got, want = np.asarray(got), np.asarray(want)
    assert got.dtype == want.dtype
    assert got.shape == want.shape
    if dtype_name == "i32":
        np.testing.assert_array_equal(got, want)
        return
    eps = np.finfo(got.dtype).eps
    atol = 1e-6 if scan_scale is None else 64 * eps * max(scan_scale, 1.0)
    rtol = 1e-5 if got.dtype == np.float32 else 1e-12
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)


# ---------------------------------------------------------------- combine


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("dt", DTYPES)
@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_combine_matches_ref(op, dt, data):
    a = data.draw(payload(dt))
    b = data.draw(
        arrays(
            _NP_DTYPES[dt],
            a.shape[0],
            elements=st.integers(-7, 7)
            if dt == "i32"
            else st.floats(-4.0, 4.0, allow_nan=False, width=32),
        )
    )
    got = combine.combine(jnp.asarray(a), jnp.asarray(b), op=op)
    want = ref.combine_ref(jnp.asarray(a), jnp.asarray(b), op)
    assert_matches(got, want, dt)


@pytest.mark.parametrize("op", INT_OPS)
@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_combine_bitwise_matches_ref(op, data):
    a = data.draw(arrays(np.int32, st.sampled_from(SIZES), elements=st.integers(-(2**31), 2**31 - 1)))
    b = data.draw(arrays(np.int32, a.shape[0], elements=st.integers(-(2**31), 2**31 - 1)))
    got = combine.combine(jnp.asarray(a), jnp.asarray(b), op=op)
    want = ref.combine_ref(jnp.asarray(a), jnp.asarray(b), op)
    assert_matches(got, want, "i32")


@pytest.mark.parametrize("op", OPS + INT_OPS)
def test_combine_identity_is_neutral(op):
    """x (op) identity == x — the property the runtime's padding relies on."""
    x = jnp.asarray(np.arange(-13, 50, dtype=np.int32))
    ident = jnp.full_like(x, ref.identity(op, jnp.int32))
    assert_matches(combine.combine(x, ident, op=op), x, "i32")


@pytest.mark.parametrize("dt", DTYPES)
def test_combine_exact_tile_boundary(dt):
    """Payloads exactly at 1x and 2x the VMEM tile hit the no-pad path."""
    for n in (combine.TILE, 2 * combine.TILE):
        a = jnp.asarray(np.arange(n) % 11, _NP_DTYPES[dt])
        b = jnp.asarray(np.arange(n) % 7, _NP_DTYPES[dt])
        assert_matches(
            combine.combine(a, b, op="sum"), ref.combine_ref(a, b, "sum"), dt
        )


def test_combine_associativity_chain():
    """Folding k payloads in any association order gives the same result —
    the invariant that lets scan algorithms reassociate partial sums."""
    rng = np.random.default_rng(0)
    xs = [jnp.asarray(rng.integers(-5, 5, 100), jnp.int32) for _ in range(5)]
    left = xs[0]
    for x in xs[1:]:
        left = combine.combine(left, x, op="sum")
    right = xs[-1]
    for x in reversed(xs[:-1]):
        right = combine.combine(x, right, op="sum")
    assert_matches(left, right, "i32")


# ---------------------------------------------------------------- derive


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_derive_recovers_peer(data):
    """cumulative = peer + own  =>  derive(cumulative, own) == peer
    (the SSIII-C multicast optimization, exact for MPI_INT / MPI_SUM)."""
    own = data.draw(payload("i32"))
    peer = data.draw(arrays(np.int32, own.shape[0], elements=st.integers(-7, 7)))
    cum = combine.combine(jnp.asarray(peer), jnp.asarray(own), op="sum")
    got = combine.derive(cum, jnp.asarray(own))
    assert_matches(got, jnp.asarray(peer), "i32")


# ---------------------------------------------------------------- scan


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("dt", DTYPES)
@pytest.mark.parametrize("inclusive", [True, False])
@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_block_scan_matches_ref(op, dt, inclusive, data):
    x = data.draw(payload(dt, sizes=[1, 2, 17, 255, 1024, scan.BLOCK], op=op))
    got = scan.block_scan(jnp.asarray(x), op=op, inclusive=inclusive)
    want = ref.scan_ref(jnp.asarray(x), op, inclusive=inclusive)
    if dt == "i32":
        assert_matches(got, want, dt)
        return
    # A scan of n elements accumulates O(n) rounding steps, and the two
    # implementations associate differently: compare with O(n*eps) rtol
    # plus an atol scaled by the accumulated magnitude (for cancellation).
    eps = float(np.finfo(_NP_DTYPES[dt]).eps)
    scale = float(np.sum(np.abs(x.astype(np.float64))) or 1.0)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(want),
        rtol=32 * len(x) * eps,
        atol=64 * eps * scale,
    )


def test_block_scan_single_element():
    x = jnp.asarray([42], jnp.int32)
    assert_matches(scan.block_scan(x, op="sum"), x, "i32")
    got = scan.block_scan(x, op="sum", inclusive=False)
    assert_matches(got, jnp.asarray([0], jnp.int32), "i32")


def test_block_scan_full_block():
    x = jnp.asarray(np.ones(scan.BLOCK), jnp.int32)
    got = scan.block_scan(x, op="sum")
    assert_matches(got, jnp.arange(1, scan.BLOCK + 1, dtype=jnp.int32), "i32")


def test_exclusive_is_shifted_inclusive():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-9, 9, 777), jnp.int32)
    inc = scan.block_scan(x, op="sum", inclusive=True)
    exc = scan.block_scan(x, op="sum", inclusive=False)
    np.testing.assert_array_equal(np.asarray(exc)[1:], np.asarray(inc)[:-1])
    assert np.asarray(exc)[0] == 0
