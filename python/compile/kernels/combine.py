"""Pallas elementwise-combine kernel: the FPGA adder-pipeline datapath.

On the NetFPGA the collective engine folds an incoming scan payload into a
buffered partial result word-by-word at line rate.  Here the same datapath
is a Pallas kernel: the payload is tiled through VMEM in ``TILE``-element
blocks (BlockSpec plays the role the streaming pipeline registers played)
and each block is combined on the VPU in one shot.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

#: VMEM tile, in elements.  A (8, 128) float32 tile is the native VPU lane
#: layout; 1024 elements keeps every dtype's tile a multiple of it.
TILE = 1024


def _combine_kernel(a_ref, b_ref, o_ref, *, op: str):
    """One VMEM-resident tile: o = a (op) b, fully vectorized on the VPU."""
    o_ref[...] = ref.binop(op)(a_ref[...], b_ref[...])


@functools.partial(jax.jit, static_argnames=("op",))
def combine(a, b, *, op: str = "sum"):
    """Elementwise ``a (op) b`` over equal-shape 1-D payloads.

    Pads to a TILE multiple with the op identity, tiles the payload through
    VMEM on a 1-D grid, and slices the pad back off.  The pad/identity dance
    mirrors what the Rust runtime does when it chunks wire payloads into the
    fixed AOT block size.
    """
    assert a.shape == b.shape and a.ndim == 1, (a.shape, b.shape)
    n = a.shape[0]
    padded = pl.cdiv(n, TILE) * TILE
    ident = ref.identity(op, a.dtype)
    ap = jnp.full((padded,), ident, a.dtype).at[:n].set(a)
    bp = jnp.full((padded,), ident, b.dtype).at[:n].set(b)
    out = pl.pallas_call(
        functools.partial(_combine_kernel, op=op),
        grid=(padded // TILE,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), a.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(ap, bp)
    return out[:n]


def _derive_kernel(cum_ref, own_ref, o_ref):
    """Inverse-subtract tile: peer = cumulative - own."""
    o_ref[...] = cum_ref[...] - own_ref[...]


@jax.jit
def derive(cumulative, own):
    """Recover a peer's payload from a tagged multicast cumulative message
    (paper SSIII-C).  Valid for MPI_SUM over exact (integer) types: the rank
    that cached its own contribution subtracts it from the received
    cumulative data to reconstruct the peer's message locally."""
    assert cumulative.shape == own.shape and cumulative.ndim == 1
    n = cumulative.shape[0]
    padded = pl.cdiv(n, TILE) * TILE
    cp = jnp.zeros((padded,), cumulative.dtype).at[:n].set(cumulative)
    op_ = jnp.zeros((padded,), own.dtype).at[:n].set(own)
    out = pl.pallas_call(
        _derive_kernel,
        grid=(padded // TILE,),
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), cumulative.dtype),
        interpret=True,
    )(cp, op_)
    return out[:n]
