"""L1 Pallas kernels for the NetFPGA MPI_Scan datapath.

The NetFPGA combined scan payloads with a hardware adder pipeline streaming
64-bit words at 125 MHz.  The TPU-shaped analogue implemented here:

- ``combine``  — tiled elementwise ``acc (op) x`` over payload blocks; the
  BlockSpec tiles the payload through VMEM the way the FPGA streamed words
  through its pipeline registers.
- ``scan``     — work-efficient block prefix scan (Hillis-Steele inside a
  VMEM-resident block), the Pallas analogue of the pipelined dataflow scan
  circuits of Park & Dai cited by the paper.
- ``derive``   — the inverse-subtract used by the recursive-doubling
  multicast optimization (paper SSIII-C): peer = cumulative - own.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime loads via the xla crate.
"""

from . import combine, ref, scan  # noqa: F401

OPS = ("sum", "prod", "max", "min")
INT_OPS = ("band", "bor", "bxor")
DTYPES = ("i32", "f32", "f64")

#: Fixed AOT block size (elements).  The Rust runtime pads / chunks payloads
#: to this length.  2048 x f64 = 16 KiB per operand — comfortably VMEM-sized
#: with double-buffering headroom on a real TPU.
BLOCK = 2048
