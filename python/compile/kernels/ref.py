"""Pure-jnp correctness oracles for the L1 kernels.

These are the single source of truth for what every kernel must compute;
pytest/hypothesis sweeps the Pallas kernels against them.
"""

import jax.lax as lax
import jax.numpy as jnp

#: MPI reduction op -> (binary fn, identity-producing fn(dtype)).
#: Identities let the runtime pad payloads to the fixed AOT block size
#: without perturbing results.
_BINOPS = {
    "sum": (lambda a, b: a + b, lambda dt: jnp.zeros((), dt)),
    "prod": (lambda a, b: a * b, lambda dt: jnp.ones((), dt)),
    "max": (
        jnp.maximum,
        lambda dt: jnp.array(
            jnp.finfo(dt).min if jnp.issubdtype(dt, jnp.floating) else jnp.iinfo(dt).min, dt
        ),
    ),
    "min": (
        jnp.minimum,
        lambda dt: jnp.array(
            jnp.finfo(dt).max if jnp.issubdtype(dt, jnp.floating) else jnp.iinfo(dt).max, dt
        ),
    ),
    "band": (lambda a, b: a & b, lambda dt: jnp.array(-1, dt)),
    "bor": (lambda a, b: a | b, lambda dt: jnp.zeros((), dt)),
    "bxor": (lambda a, b: a ^ b, lambda dt: jnp.zeros((), dt)),
}


def binop(op: str):
    """The associative binary function for MPI op name ``op``."""
    return _BINOPS[op][0]


def identity(op: str, dtype):
    """The identity element of ``op`` for ``dtype`` (scalar jnp array)."""
    return _BINOPS[op][1](jnp.dtype(dtype))


def combine_ref(a, b, op: str):
    """Elementwise ``a (op) b`` — what the FPGA adder pipeline computes when
    an incoming payload is folded into a buffered partial sum."""
    return binop(op)(a, b)


def scan_ref(x, op: str, inclusive: bool = True):
    """Prefix scan of a 1-D payload with ``op``.

    ``inclusive=True``  -> MPI_Scan semantics (element j includes x[j]);
    ``inclusive=False`` -> MPI_Exscan (element 0 is the identity).
    """
    inc = lax.associative_scan(binop(op), x)
    if inclusive:
        return inc
    ident = jnp.full((1,), identity(op, x.dtype))
    return jnp.concatenate([ident, inc[:-1]])


def derive_ref(cumulative, own):
    """Inverse-subtract of the multicast optimization (paper SSIII-C):
    given ``cumulative = peer + own`` recover ``peer``.  Exact only for
    MPI_SUM over integers, which is why the paper restricts the
    optimization to MPI_INT / MPI_SUM."""
    return cumulative - own
