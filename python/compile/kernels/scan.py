"""Pallas block prefix-scan kernel.

The Pallas analogue of the pipelined-dataflow scan circuits (Park & Dai)
the paper cites: a Hillis-Steele ladder over a VMEM-resident block.  Each
of the log2(BLOCK) ladder steps is a full-width vector shift + combine —
exactly the structure an FPGA scan pipeline unrolls in space, unrolled here
in time on the VPU.

Blocks larger than ``BLOCK`` are handled at L2 (``model.chunked_scan``) by
carrying the last element across chunks with ``lax.scan`` — the same
block-local-scan + carry decomposition every GPU/FPGA scan in the paper's
related work uses.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

#: Elements per scan block; must be a power of two for the ladder.
BLOCK = 2048


def _scan_kernel(x_ref, o_ref, *, op: str, n: int):
    """Hillis-Steele inclusive scan of one VMEM block.

    ``shift`` is materialized with a static concatenate (shapes are static
    inside the kernel), so each ladder step is one vector op + one combine.
    """
    f = ref.binop(op)
    x = x_ref[...]
    ident = ref.identity(op, x.dtype)
    d = 1
    while d < n:
        shifted = jnp.concatenate([jnp.full((d,), ident, x.dtype), x[:-d]])
        x = f(x, shifted)
        d *= 2
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("op", "inclusive"))
def block_scan(x, *, op: str = "sum", inclusive: bool = True):
    """Prefix scan of a 1-D payload of at most ``BLOCK`` elements.

    Pads with the op identity to the fixed block size, scans in one VMEM
    block, slices the pad off.  Exclusive scans shift the inclusive result
    right by one and inject the identity — identical to how MPI_Exscan
    relates to MPI_Scan.
    """
    assert x.ndim == 1 and x.shape[0] <= BLOCK, x.shape
    n = x.shape[0]
    ident = ref.identity(op, x.dtype)
    xp = jnp.full((BLOCK,), ident, x.dtype).at[:n].set(x)
    out = pl.pallas_call(
        functools.partial(_scan_kernel, op=op, n=BLOCK),
        out_shape=jax.ShapeDtypeStruct((BLOCK,), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp)
    inc = out[:n]
    if inclusive:
        return inc
    return jnp.concatenate([jnp.full((1,), ident, x.dtype), inc[:-1]])
