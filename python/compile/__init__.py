"""Build-time Python for nf-scan (never imported at runtime).

- ``kernels`` — L1 Pallas kernels + pure-jnp oracle.
- ``model``   — L2 JAX compute graphs over payload blocks.
- ``aot``     — lowers every (kind x op x dtype) variant to HLO text in
  ``artifacts/`` for the Rust PJRT runtime.
"""

import jax

# MPI_DOUBLE payloads need real f64; enable before any tracing happens.
jax.config.update("jax_enable_x64", True)
