"""AOT bridge: lower every L2 graph variant to HLO text for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 crate links) rejects (``proto.id() <=
INT_MAX``).  The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/gen_hlo.py.

Outputs, under ``artifacts/``:

- ``<name>.hlo.txt``  — one HLO module per variant
- ``manifest.txt``    — one ``key=value`` record line per variant, parsed
  by ``rust/src/runtime/manifest.rs`` (no JSON: the offline Rust build has
  no serde, and key=value is trivially greppable)

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import BLOCK, DTYPES, INT_OPS, OPS  # noqa: E402


def to_hlo_text(lowered, return_tuple: bool = False) -> str:
    """StableHLO -> XlaComputation -> HLO text (the verified bridge path).

    return_tuple=False gives a plain array root: the Rust runtime then
    reads results back with one raw memcpy (PjRtBuffer::
    copy_raw_to_host_sync) instead of materializing a tuple literal —
    measured 17.3us -> ~1us readback per block (EXPERIMENTS.md SSPerf).
    The runtime still accepts tuple-rooted artifacts (legacy path).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def variants():
    """Yield (name, fn, arity, record) for every artifact to build."""
    for op in OPS:
        for dt in DTYPES:
            yield (
                f"combine_{op}_{dt}",
                model.make_combine(op),
                2,
                {"kind": "combine", "op": op, "dtype": dt},
            )
    for op in INT_OPS:
        yield (
            f"combine_{op}_i32",
            model.make_combine(op),
            2,
            {"kind": "combine", "op": op, "dtype": "i32"},
        )
    for dt in DTYPES:
        for inclusive in (True, False):
            tag = "inc" if inclusive else "exc"
            yield (
                f"scan_{tag}_sum_{dt}",
                model.make_scan("sum", inclusive),
                1,
                {"kind": f"scan_{tag}", "op": "sum", "dtype": dt},
            )
    yield (
        "derive_sub_i32",
        model.make_derive(),
        2,
        {"kind": "derive", "op": "sum", "dtype": "i32"},
    )


def lower_variant(name, fn, arity, record, out_dir):
    dt = model.dtype_of(record["dtype"])
    spec = jax.ShapeDtypeStruct((BLOCK,), dt)
    lowered = jax.jit(fn).lower(*([spec] * arity))
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    fields = {"name": name, **record, "block": BLOCK, "args": arity, "file": fname}
    return " ".join(f"{k}={v}" for k, v in fields.items())


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--only", default=None, help="substring filter on variant names")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    lines = []
    for name, fn, arity, record in variants():
        if args.only and args.only not in name:
            continue
        line = lower_variant(name, fn, arity, record, args.out_dir)
        lines.append(line)
        print(f"lowered {name}", file=sys.stderr)
    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write(f"# nf-scan AOT manifest: block={BLOCK}\n")
        f.write("\n".join(lines) + "\n")
    print(f"wrote {len(lines)} artifacts + {manifest}", file=sys.stderr)


if __name__ == "__main__":
    main()
