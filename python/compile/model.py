"""L2: JAX compute graphs for the NetFPGA scan datapath.

These are the functions that get AOT-lowered to HLO and executed from the
Rust hot path.  Every function operates on fixed-size payload blocks
(``kernels.BLOCK`` elements) because AOT artifacts have static shapes; the
Rust runtime pads with the op identity / chunks larger payloads.

Exported graph kinds (see ``aot.VARIANTS``):

- ``combine``   — elementwise fold of an incoming payload into a partial
  result (the per-packet work of every scan algorithm's state machine).
- ``scan_inc`` / ``scan_exc`` — block-local prefix scan (host-side oracle
  path and the single-FPGA related-work baseline).
- ``derive``    — multicast inverse-subtract (recursive doubling, SSIII-C).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import BLOCK, combine as combine_k, ref, scan as scan_k

_DTYPES = {"i32": jnp.int32, "f32": jnp.float32, "f64": jnp.float64}


def dtype_of(name: str):
    """jnp dtype for the manifest dtype name (i32/f32/f64)."""
    return _DTYPES[name]


def make_combine(op: str):
    """Block combine graph: (a[BLOCK], b[BLOCK]) -> (a (op) b,).

    Returns a 1-tuple because the AOT bridge lowers with
    ``return_tuple=True`` and the Rust side unwraps with ``to_tuple1``.
    """

    def fn(a, b):
        return (combine_k.combine(a, b, op=op),)

    fn.__name__ = f"combine_{op}"
    return fn


def make_scan(op: str, inclusive: bool):
    """Block prefix-scan graph: (x[BLOCK],) -> (scan(x),)."""

    def fn(x):
        return (scan_k.block_scan(x, op=op, inclusive=inclusive),)

    fn.__name__ = f"scan_{'inc' if inclusive else 'exc'}_{op}"
    return fn


def make_derive():
    """Multicast inverse-subtract graph: (cum[BLOCK], own[BLOCK]) -> (peer,)."""

    def fn(cum, own):
        return (combine_k.derive(cum, own),)

    fn.__name__ = "derive_sub"
    return fn


@functools.partial(jax.jit, static_argnames=("op", "inclusive"))
def chunked_scan(x, *, op: str = "sum", inclusive: bool = True):
    """Prefix scan of payloads larger than one block.

    L2 composition over the L1 block kernel: ``lax.scan`` carries the last
    inclusive element across chunks — the block-local-scan + carry
    decomposition used by every blocked scan implementation the paper cites
    (Harris et al. for GPUs, Park & Dai for FPGAs).

    Requires ``len(x)`` to be a multiple of BLOCK (the runtime pads).
    """
    n = x.shape[0]
    assert n % BLOCK == 0, n
    f = ref.binop(op)
    chunks = x.reshape(n // BLOCK, BLOCK)

    def step(carry, chunk):
        inc = scan_k.block_scan(chunk, op=op, inclusive=True)
        inc = f(carry, inc)
        out = inc if inclusive else jnp.concatenate([carry[None], inc[:-1]])
        return inc[-1], out

    ident = ref.identity(op, x.dtype)
    _, outs = lax.scan(step, jnp.asarray(ident, x.dtype), chunks)
    return outs.reshape(n)
