//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this path dependency
//! provides exactly the surface nf-scan uses — `Error`, `Result`,
//! `anyhow!` / `bail!` / `ensure!`, and the `Context` extension trait for
//! `Result` and `Option` — with the same semantics:
//!
//! - `{e}` prints the outermost message, `{e:#}` the whole cause chain
//!   joined by `": "`, and `{e:?}` an anyhow-style "Caused by:" listing;
//! - `?` converts any `std::error::Error` into [`Error`], capturing its
//!   `source()` chain;
//! - `.context(..)` / `.with_context(..)` prepend a message, and work on
//!   both std-error results and already-`anyhow` results.
//!
//! The std-error chain is flattened to strings at conversion time (no
//! downcasting), which none of the crate's call sites need.

use std::error::Error as StdError;
use std::fmt::{self, Display};

/// A string-chained error: outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (what `Context::context` attaches).
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` intentionally does NOT implement `std::error::Error`; that is
// what makes this blanket `From` (and the blanket `Context` impl below)
// coherent, exactly as in the real anyhow.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    /// Sealed unification of `std::error::Error` types and [`super::Error`]
    /// so one `Context` impl covers both result flavors.
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T> for Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (or anything `Display`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Bail unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(::std::concat!("condition failed: `", ::std::stringify!($cond), "`"))
        }
    };
    ($cond:expr, $($tt:tt)*) => {
        if !$cond {
            $crate::bail!($($tt)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Result::<(), _>::Err(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("root").context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("top"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_option_and_anyhow_result() {
        let none: Option<i32> = None;
        let e = none.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");

        let r: Result<i32> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(flag: bool) -> Result<i32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");

        fn g() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(format!("{}", g().unwrap_err()).contains("condition failed"));
    }
}
