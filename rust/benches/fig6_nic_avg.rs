//! Regenerates the paper's Fig. 6: average on-NIC latency (the NetFPGA's
//! offload->release timestamp registers) per offloaded algorithm.
//! `cargo bench --bench fig6_nic_avg`.

use nfscan::bench::{fig6_table, figure_base, OSU_SIZES};
use nfscan::config::EngineKind;
use nfscan::runtime::make_engine;

fn main() {
    let iters = std::env::var("NFSCAN_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(500);
    let cfg = figure_base(iters);
    let compute = make_engine(EngineKind::Native, "artifacts");
    let t0 = std::time::Instant::now();
    let table = fig6_table(&cfg, compute, OSU_SIZES);
    println!("Fig. 6 — average on-NIC latency after offload (us), {iters} iters/cell");
    print!("{}", table.render());
    println!("[bench wallclock: {:.2}s]", t0.elapsed().as_secs_f64());
}
