//! Ablation: the recursive-doubling multicast + inverse-subtract
//! optimization (paper SSIII-C, Fig. 3).
//!
//! Sweeps the late-rank delay; for each delay, runs the offloaded
//! recursive-doubling scan with and without the optimization and reports
//! multicast generations taken and the latency delta.
//! `cargo bench --bench ablation_multicast`.

use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::metrics::Table;
use nfscan::packet::AlgoType;
use nfscan::runtime::make_engine;

fn run(opt: bool, late_ns: u64, iters: usize) -> nfscan::metrics::RunMetrics {
    let mut cfg = ExpConfig::default();
    cfg.p = 8;
    cfg.algo = AlgoType::RecursiveDoubling;
    cfg.path = ExecPath::Fpga;
    cfg.iters = iters;
    cfg.warmup = 8;
    cfg.multicast_opt = opt;
    cfg.late_rank = Some(1);
    cfg.late_delay_ns = late_ns;
    cfg.cost.start_jitter_ns = 0;
    let compute = make_engine(EngineKind::Native, "artifacts");
    let mut cluster = Cluster::new(cfg, Rc::clone(&compute));
    cluster.run().expect("run completes")
}

fn main() {
    let iters = 300;
    let mut t = Table::new(&[
        "late_delay_us",
        "multicasts",
        "avg_with_us",
        "avg_without_us",
        "saved_us",
    ]);
    for late_us in [0u64, 10, 50, 200, 1000] {
        let with = run(true, late_us * 1000, iters);
        let without = run(false, late_us * 1000, iters);
        let a = with.host_overall().avg_us();
        let b = without.host_overall().avg_us();
        t.row(vec![
            late_us.to_string(),
            with.multicasts.to_string(),
            format!("{a:.2}"),
            format!("{b:.2}"),
            format!("{:.3}", b - a),
        ]);
    }
    println!("SSIII-C multicast optimization — late rank 1 of 8, {iters} iters");
    print!("{}", t.render());
    println!("(multicasts rise with arrival skew; each saves one packet generation)");
}
