//! Regenerates the paper's Fig. 5: minimum MPI_Scan latency vs message
//! size on 8 nodes.  `cargo bench --bench fig5_min_latency`.

use nfscan::bench::{fig5_table, figure_base, OSU_SIZES};
use nfscan::config::EngineKind;
use nfscan::runtime::make_engine;

fn main() {
    let iters = std::env::var("NFSCAN_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(500);
    let cfg = figure_base(iters);
    let compute = make_engine(EngineKind::Native, "artifacts");
    let t0 = std::time::Instant::now();
    let table = fig5_table(&cfg, compute, OSU_SIZES);
    println!("Fig. 5 — minimum MPI_Scan latency (us), 8 nodes, {iters} iters/cell");
    print!("{}", table.render());
    println!("[bench wallclock: {:.2}s]", t0.elapsed().as_secs_f64());
}
