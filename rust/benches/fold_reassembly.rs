//! K-way fold + streaming-reassembly bench: the two multi-payload hot
//! paths the arena work targets, measured against in-repo reference
//! implementations that replicate the pre-arena shapes.
//! `cargo bench --bench fold_reassembly`.
//!
//! - **fold**: a 64-way chain fold (binomial root / verify-path shape):
//!   pairwise allocating `combine` vs in-place `combine_into`;
//! - **reassembly**: a 16 KB message from MTU fragments: buffer-clones +
//!   `Payload::concat` (the old double copy) vs the streaming
//!   `Reassembler` (first-fragment arena buffer + memcpy into place).

use std::time::Instant;

use nfscan::data::{Op, Payload};
use nfscan::fpga::reassembly::Reassembler;
use nfscan::net::frame::fragment;
use nfscan::runtime::{Compute, NativeEngine};
use nfscan::util::alloc as cnt;

#[global_allocator]
static ALLOC: nfscan::util::alloc::CountingAllocator = nfscan::util::alloc::CountingAllocator;

fn contribs(k: usize, n: usize) -> Vec<Payload> {
    (0..k)
        .map(|s| Payload::from_i32(&(0..n as i32).map(|v| (v + s as i32) % 17 - 8).collect::<Vec<_>>()))
        .collect()
}

fn measure(reps: usize, mut op: impl FnMut()) -> (f64, f64) {
    op(); // warmup
    op();
    let a0 = cnt::allocation_count();
    let t0 = Instant::now();
    for _ in 0..reps {
        op();
    }
    let ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    (ns, (cnt::allocation_count() - a0) as f64 / reps as f64)
}

fn main() {
    let e = NativeEngine::new();
    let mut t = nfscan::metrics::Table::new(&[
        "case", "pairwise_us", "pairwise_allocs", "inplace_us", "inplace_allocs", "speedup",
    ]);
    for (label, n, reps) in [("fold_k64_1k", 256usize, 2_000usize), ("fold_k64_16k", 4096, 300)] {
        let xs = contribs(64, n);
        let (pw_ns, pw_al) = measure(reps, || {
            let mut acc = xs[0].clone();
            for c in &xs[1..] {
                acc = e.combine(&acc, c, Op::Sum).unwrap();
            }
            std::hint::black_box(&acc);
        });
        let (ip_ns, ip_al) = measure(reps, || {
            let mut acc = xs[0].clone();
            for c in &xs[1..] {
                e.combine_into(&mut acc, c, Op::Sum).unwrap();
            }
            std::hint::black_box(&acc);
        });
        t.row(vec![
            label.to_string(),
            format!("{:.2}", pw_ns / 1e3),
            format!("{pw_al:.1}"),
            format!("{:.2}", ip_ns / 1e3),
            format!("{ip_al:.1}"),
            format!("{:.2}x", pw_ns / ip_ns),
        ]);
    }
    println!("64-way chain fold, i32 MPI_SUM (us per whole fold, allocs per fold)");
    print!("{}", t.render());
    println!();

    // ---- reassembly: old shape (clone fragments, concat at the end) vs
    // the streaming reassembler
    let msg = Payload::from_i32(&(0..4096).collect::<Vec<_>>()); // 16 KB
    let frags = fragment(&msg);
    let count = msg.len() as u32;
    let reps = 20_000;
    let (old_ns, old_al) = measure(reps, || {
        // reference: the pre-streaming double copy
        let mut parts: Vec<Option<Payload>> = vec![None; frags.len()];
        for (idx, _total, _off, chunk) in &frags {
            parts[*idx as usize] = Some(chunk.clone());
        }
        let chunks: Vec<Payload> = parts.into_iter().map(|p| p.unwrap()).collect();
        std::hint::black_box(Payload::concat(&chunks));
    });
    let mut r: Reassembler<u32> = Reassembler::new(32);
    let (new_ns, new_al) = measure(reps, || {
        let mut whole = None;
        for (idx, total, _off, chunk) in &frags {
            whole = r.add(1, *idx, *total, count, chunk.clone());
        }
        std::hint::black_box(whole.expect("complete"));
    });
    let mut t = nfscan::metrics::Table::new(&[
        "path", "us_per_msg", "allocs_per_msg", "speedup",
    ]);
    t.row(vec![
        "buffer+concat".into(),
        format!("{:.2}", old_ns / 1e3),
        format!("{old_al:.1}"),
        "1.00x".into(),
    ]);
    t.row(vec![
        "streaming".into(),
        format!("{:.2}", new_ns / 1e3),
        format!("{new_al:.1}"),
        format!("{:.2}x", old_ns / new_ns),
    ]);
    println!("16 KB message reassembly from {} MTU fragments ({reps} reps)", frags.len());
    print!("{}", t.render());
}
