//! Event-core microbenchmark: binary heap vs calendar queue, measured —
//! not asserted.  `cargo bench --bench event_queue`.
//!
//! Two views:
//! - **hold model** (classic event-queue benchmark): keep N events
//!   pending, repeatedly pop the earliest and schedule a replacement a
//!   random sim-typical delta ahead.  This isolates the queue itself.
//! - **end-to-end**: a p=128 fat-tree offloaded-scan run on the adaptive
//!   queue, the workload the calendar exists for.

use std::time::Instant;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::metrics::Table;
use nfscan::packet::AlgoType;
use nfscan::runtime::make_engine;
use nfscan::sim::{EventKind, EventQueue, SimTime, SplitMix64};

/// Delays mimicking the simulation's cost constants: wire serialization,
/// pipeline exits, stack crossings, call gaps, late ranks.
const DELTAS: &[u64] = &[120, 500, 992, 2_000, 28_000, 120_000, 2_000_000];

fn hold_model(mut q: EventQueue, held: usize, ops: usize) -> f64 {
    let mut rng = SplitMix64::new(0xBE9C4);
    for i in 0..held {
        q.push(SimTime::ns(rng.next_below(100_000)), EventKind::HostStart { rank: i });
    }
    let t0 = Instant::now();
    for _ in 0..ops {
        let (now, kind) = q.pop().expect("hold model never drains");
        let delta = DELTAS[rng.next_below(DELTAS.len() as u64) as usize];
        q.push(now + delta, kind);
    }
    t0.elapsed().as_nanos() as f64 / ops as f64
}

fn main() {
    let ops = 400_000;
    let mut t = Table::new(&["held_events", "heap_ns_op", "calendar_ns_op", "speedup"]);
    for held in [16usize, 256, 4_096, 65_536] {
        let heap = hold_model(EventQueue::with_heap(), held, ops);
        let cal = hold_model(EventQueue::with_calendar(), held, ops);
        t.row(vec![
            held.to_string(),
            format!("{heap:.1}"),
            format!("{cal:.1}"),
            format!("{:.2}x", heap / cal),
        ]);
    }
    println!("hold model: pop-min + reschedule, {ops} ops (ns/op)");
    print!("{}", t.render());
    println!();

    let mut cfg = ExpConfig::default();
    cfg.p = 128;
    cfg.algo = AlgoType::RecursiveDoubling;
    cfg.path = ExecPath::Fpga;
    cfg.topology = "fattree".into();
    cfg.msg_bytes = 64;
    cfg.iters = 60;
    cfg.warmup = 8;
    let compute = make_engine(EngineKind::Native, "artifacts");
    let t0 = Instant::now();
    let mut cluster = Cluster::new(cfg, compute);
    let m = cluster.run().expect("fat-tree run completes");
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "end-to-end: p=128 fat-tree NF_rd, 60 iters — {:.2}s wallclock, \
         {} frames ({} via switch trunks), sim {:.3} ms",
        wall,
        m.total_frames(),
        m.switch_frames_tx,
        m.sim_ns as f64 / 1e6
    );
}
