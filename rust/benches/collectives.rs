//! Extension bench: MPI_Barrier and MPI_Allreduce offload (the other
//! collectives the paper's packet format reserves; the authors' companion
//! works [6] and [7]).  Compares software recursive doubling against the
//! offloaded butterfly and the offloaded binomial tree whose down phase
//! is ONE multicast per node (the paper's SSIII-D contrast with scan).
//! `cargo bench --bench collectives`.

use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::metrics::Table;
use nfscan::packet::{AlgoType, CollType};
use nfscan::runtime::make_engine;

fn run(coll: CollType, algo: AlgoType, offloaded: bool, msg: usize, iters: usize) -> f64 {
    let mut cfg = ExpConfig::default();
    cfg.coll = coll;
    cfg.algo = algo;
    cfg.path = if offloaded { ExecPath::Fpga } else { ExecPath::Sw };
    cfg.msg_bytes = msg;
    cfg.iters = iters;
    cfg.warmup = 8;
    let compute = make_engine(EngineKind::Native, "artifacts");
    let mut cluster = Cluster::new(cfg, Rc::clone(&compute));
    cluster.run().expect("run completes").host_overall().avg_us()
}

fn main() {
    let iters = 300;

    println!("MPI_Barrier, 8 nodes ({iters} iters): avg latency (us)");
    let mut t = Table::new(&["series", "avg_us"]);
    let barrier = |algo, nf| format!("{:.2}", run(CollType::Barrier, algo, nf, 4, iters));
    t.row(vec!["sw_rd".into(), barrier(AlgoType::RecursiveDoubling, false)]);
    t.row(vec!["NF_rd".into(), barrier(AlgoType::RecursiveDoubling, true)]);
    t.row(vec!["NF_binomial".into(), barrier(AlgoType::BinomialTree, true)]);
    print!("{}", t.render());

    println!("\nMPI_Allreduce, 8 nodes ({iters} iters): avg latency (us) vs msg size");
    let mut t = Table::new(&["msg_size", "sw_rd_us", "NF_rd_us", "NF_binomial_us"]);
    for msg in [4usize, 64, 1024, 4096] {
        let allreduce =
            |algo, nf| format!("{:.2}", run(CollType::Allreduce, algo, nf, msg, iters));
        t.row(vec![
            nfscan::util::fmt_bytes(msg),
            allreduce(AlgoType::RecursiveDoubling, false),
            allreduce(AlgoType::RecursiveDoubling, true),
            allreduce(AlgoType::BinomialTree, true),
        ]);
    }
    print!("{}", t.render());
    println!(
        "(the binomial allreduce's down phase is ONE multicast per node —\n\
         the SSIII-D capability MPI_Scan's per-rank outcomes forbid)"
    );
}
