//! Scaling study: the paper's SSIV claim that the sequential algorithm
//! "is not scalable algorithmically and would produce significant
//! performance degradation on big clusters", while the log-p algorithms
//! hold.  `cargo bench --bench scaling`.
//!
//! Two views:
//! - **cold** (single scan, all ranks call together): the O(p) vs
//!   O(log p) critical path the claim is about;
//! - **steady-state** (back-to-back OSU loop): sequential *pipelines* —
//!   per-call latency flattens because rank j's iteration i overlaps
//!   rank j+1's iteration i-1.  This is exactly why the paper's Fig. 4
//!   average for sw_seq is so low; the cold view is why it still "would
//!   produce significant performance degradation" for a program that
//!   scans once and moves on.

use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::metrics::Table;
use nfscan::packet::AlgoType;
use nfscan::runtime::make_engine;

fn run(algo: AlgoType, offloaded: bool, p: usize, iters: usize) -> f64 {
    let mut cfg = ExpConfig::default();
    cfg.p = p;
    cfg.algo = algo;
    cfg.path = if offloaded { ExecPath::Fpga } else { ExecPath::Sw };
    cfg.iters = iters;
    cfg.warmup = if iters == 1 { 0 } else { 8 };
    cfg.cost.start_jitter_ns = 0; // all ranks call together
    let compute = make_engine(EngineKind::Native, "artifacts");
    let mut cluster = Cluster::new(cfg, Rc::clone(&compute));
    // cold single-shot: report the SLOWEST rank (completion of the whole
    // collective); steady-state: the OSU average
    let m = cluster.run().expect("run completes");
    if iters == 1 {
        m.host_latency.iter().map(|s| s.max_ns()).max().unwrap() as f64 / 1e3
    } else {
        m.host_overall().avg_us()
    }
}

fn table(iters: usize, title: &str) {
    let mut t = Table::new(&["p", "sw_seq_us", "NF_seq_us", "NF_rd_us", "NF_binomial_us"]);
    for p in [2usize, 4, 8, 16, 32, 64] {
        t.row(vec![
            p.to_string(),
            format!("{:.2}", run(AlgoType::Sequential, false, p, iters)),
            format!("{:.2}", run(AlgoType::Sequential, true, p, iters)),
            format!("{:.2}", run(AlgoType::RecursiveDoubling, true, p, iters)),
            format!("{:.2}", run(AlgoType::BinomialTree, true, p, iters)),
        ]);
    }
    println!("{title}");
    print!("{}", t.render());
    println!();
}

fn main() {
    table(1, "scaling (cold): one MPI_Scan, slowest-rank completion (us), 4-byte messages");
    table(
        200,
        "scaling (steady-state): back-to-back OSU average latency (us), 4-byte messages",
    );
    println!(
        "(cold: sequential grows O(p), log-p algorithms ~flat — the paper's\n\
         'not scalable' claim.  steady-state: pipelining hides sequential's\n\
         depth — the reason its Fig. 4 average is the lowest.)"
    );
}
