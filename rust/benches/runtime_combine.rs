//! Hot-path microbenchmark: the payload-combine datapath — the in-place
//! arena fold (`combine_into`) vs the allocating path vs the XLA
//! artifacts (PJRT) — across payload sizes.  This is the real wallclock
//! cost of the runtime the simulator charges virtual time for, and the
//! primary L3 perf-iteration target (EXPERIMENTS.md SSPerf).
//! `cargo bench --bench runtime_combine`.

use nfscan::config::EngineKind;
use nfscan::data::{Op, Payload};
use nfscan::metrics::Table;
use nfscan::runtime::{make_engine, Compute};
use nfscan::util::alloc as cnt;

// count allocations around the hot loops (allocs/op column)
#[global_allocator]
static ALLOC: nfscan::util::alloc::CountingAllocator = nfscan::util::alloc::CountingAllocator;

fn inputs(n: usize) -> (Payload, Payload) {
    let a = Payload::from_i32(&(0..n as i32).map(|v| v % 17 - 8).collect::<Vec<_>>());
    let b = Payload::from_i32(&(0..n as i32).map(|v| v % 11 - 5).collect::<Vec<_>>());
    (a, b)
}

/// Allocating combine: `acc = combine(acc, b)` (the pre-arena shape).
fn bench_alloc(engine: &dyn Compute, n: usize, reps: usize) -> (f64, f64) {
    let (a, b) = inputs(n);
    let mut acc = engine.combine(&a, &b, Op::Sum).unwrap(); // warmup
    let a0 = cnt::allocation_count();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        acc = engine.combine(&acc, &b, Op::Sum).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let allocs = (cnt::allocation_count() - a0) as f64 / reps as f64;
    std::hint::black_box(&acc);
    (dt / reps as f64 * 1e6, allocs)
}

/// In-place combine: `combine_into(&mut acc, b)` on a unique accumulator.
fn bench_in_place(engine: &dyn Compute, n: usize, reps: usize) -> (f64, f64) {
    let (a, b) = inputs(n);
    let mut acc = a;
    for _ in 0..16 {
        engine.combine_into(&mut acc, &b, Op::Sum).unwrap(); // warmup + materialize
    }
    let a0 = cnt::allocation_count();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        engine.combine_into(&mut acc, &b, Op::Sum).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let allocs = (cnt::allocation_count() - a0) as f64 / reps as f64;
    std::hint::black_box(&acc);
    (dt / reps as f64 * 1e6, allocs)
}

fn main() {
    let native = make_engine(EngineKind::Native, "artifacts");
    let xla = make_engine(EngineKind::Xla, "artifacts");
    let reps = 2000;
    let mut t = Table::new(&[
        "elements",
        "alloc_us",
        "alloc/op",
        "inplace_us",
        "inplace/op",
        "speedup",
        "xla_us",
    ]);
    for n in [64usize, 512, 2048, 8192, 65536] {
        let (au, aa) = bench_alloc(&*native, n, reps);
        let (iu, ia) = bench_in_place(&*native, n, reps);
        let (xu, _) = bench_alloc(&*xla, n, reps.min(500));
        t.row(vec![
            n.to_string(),
            format!("{au:.2}"),
            format!("{aa:.1}"),
            format!("{iu:.2}"),
            format!("{ia:.1}"),
            format!("{:.2}x", au / iu),
            format!("{xu:.2}"),
        ]);
    }
    println!(
        "combine hot path: i32 MPI_SUM, allocating vs in-place arena fold vs {} ({} reps)",
        xla.name(),
        reps
    );
    print!("{}", t.render());
    println!(
        "(inplace/op must read 0.0 — the zero-alloc regression test asserts it; \
         xla column uses the AOT Pallas->HLO artifacts via PJRT; run `make artifacts`)"
    );
}
