//! Hot-path microbenchmark: the payload-combine datapath, XLA artifacts
//! (PJRT) vs native Rust, across payload sizes.  This is the real
//! wallclock cost of the runtime the simulator charges virtual time for,
//! and the primary L3 perf-iteration target (EXPERIMENTS.md SSPerf).
//! `cargo bench --bench runtime_combine`.

use nfscan::config::EngineKind;
use nfscan::data::{Op, Payload};
use nfscan::metrics::Table;
use nfscan::runtime::{make_engine, Compute};

fn bench_engine(engine: &dyn Compute, n: usize, reps: usize) -> (f64, f64) {
    let a = Payload::from_i32(&(0..n as i32).map(|v| v % 17 - 8).collect::<Vec<_>>());
    let b = Payload::from_i32(&(0..n as i32).map(|v| v % 11 - 5).collect::<Vec<_>>());
    // warmup (compile on first use for the XLA engine)
    let mut acc = engine.combine(&a, &b, Op::Sum).unwrap();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        acc = engine.combine(&acc, &b, Op::Sum).unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(&acc);
    let per_call_us = dt / reps as f64 * 1e6;
    let mbps = (n * 4 * reps) as f64 / dt / 1e6;
    (per_call_us, mbps)
}

fn main() {
    let native = make_engine(EngineKind::Native, "artifacts");
    let xla = make_engine(EngineKind::Xla, "artifacts");
    let reps = 2000;
    let mut t = Table::new(&[
        "elements",
        "native_us",
        "native_MB/s",
        "xla_us",
        "xla_MB/s",
        "xla/native",
    ]);
    for n in [64usize, 512, 2048, 8192, 65536] {
        let (nu, nm) = bench_engine(&*native, n, reps);
        let (xu, xm) = bench_engine(&*xla, n, reps.min(500));
        t.row(vec![
            n.to_string(),
            format!("{nu:.2}"),
            format!("{nm:.0}"),
            format!("{xu:.2}"),
            format!("{xm:.0}"),
            format!("{:.1}x", xu / nu),
        ]);
    }
    println!(
        "combine hot path: i32 MPI_SUM, {} vs {} ({} reps)",
        native.name(),
        xla.name(),
        reps
    );
    print!("{}", t.render());
    println!("(xla column uses the AOT Pallas->HLO artifacts via PJRT; run `make artifacts`)");
}
