//! Ablation: the sequential ACK protocol (paper SSIII-B).
//!
//! The paper argues back-to-back MPI_Scan calls would exhaust the
//! NetFPGA's limited buffering without the ACK that keeps upstream ranks
//! from running ahead; "no matter how much we try to buffer outstanding
//! MPI_Scan requests, the resources are limited."
//!
//! This bench runs the sequential offload path with the ACK enabled
//! (baseline latency) and disabled (the NIC's single upstream buffer and
//! the engine-table cap blow up — caught as a panic and reported), plus
//! the latency the ACK costs on a *single* (non-back-to-back) scan.
//! `cargo bench --bench ablation_ack`.

use std::rc::Rc;

use nfscan::cluster::Cluster;
use nfscan::config::{EngineKind, ExecPath, ExpConfig};
use nfscan::packet::AlgoType;
use nfscan::runtime::make_engine;

fn cfg(ack: bool, iters: usize) -> ExpConfig {
    let mut c = ExpConfig::default();
    c.algo = AlgoType::Sequential;
    c.path = ExecPath::Fpga;
    c.iters = iters;
    // single-shot runs must not pipeline at all (that's the point of the
    // comparison); back-to-back runs warm the pipeline first
    c.warmup = if iters == 1 { 0 } else { 8 };
    c.ack_enabled = ack;
    c
}

fn main() {
    let compute = make_engine(EngineKind::Native, "artifacts");

    // baseline: ACK on, heavy back-to-back traffic
    let mut cluster = Cluster::new(cfg(true, 500), Rc::clone(&compute));
    let with_ack = cluster.run().expect("ack-enabled run completes");
    println!("ACK enabled : 500 back-to-back scans OK");
    println!(
        "              avg {:.2} us | min {:.2} us | on-NIC avg {:.2} us",
        with_ack.host_overall().avg_us(),
        with_ack.host_overall().min_us(),
        with_ack.nic_overall().avg_us()
    );

    // ablation: ACK off — upstream ranks run ahead until a card's
    // buffers overflow (the assertion models the hardware dropping).
    // the panic is EXPECTED: silence its backtrace for readable output
    let default_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let result = std::panic::catch_unwind(|| {
        let compute = make_engine(EngineKind::Native, "artifacts");
        let mut cluster = Cluster::new(cfg(false, 500), compute);
        cluster.run().map(|m| m.host_overall().count()).unwrap_or(0)
    });
    std::panic::set_hook(default_hook);
    match result {
        Err(_) => println!(
            "ACK disabled: back-to-back sequential scans OVERFLOW the card's\n              \
             single upstream buffer (panic caught) — the paper's SSIII-B\n              \
             protocol is load-bearing"
        ),
        Ok(n) => println!(
            "ACK disabled: run survived ({n} samples) — buffer margin absorbed it \
             (unexpected at this pressure)"
        ),
    }

    // the price of the ACK on one isolated scan: one extra wire round
    let one_with = {
        let mut c = Cluster::new(cfg(true, 1), Rc::clone(&compute));
        c.run().unwrap().host_overall().avg_us()
    };
    let one_without = {
        let mut c = Cluster::new(cfg(false, 1), compute);
        c.run().unwrap().host_overall().avg_us()
    };
    println!(
        "single-scan cost of the ACK: {:.2} us -> {:.2} us (+{:.2} us)",
        one_without,
        one_with,
        one_with - one_without
    );
}
