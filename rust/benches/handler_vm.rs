//! Handler-VM dispatch-overhead bench: what does running a collective
//! as an interpreted packet program cost over the fixed-function state
//! machine?  Measured, not asserted — `cargo bench --bench handler_vm`.
//!
//! Two views:
//! - **activation micro**: engine construction + one `on_host_request`
//!   activation of the recursive-doubling allreduce, VM program vs
//!   native state machine.  Construction stays inside the timed loop on
//!   purpose — the cluster builds one engine per epoch, so the real
//!   dispatch path pays flow-scratchpad setup (VM) vs a plain struct
//!   (fixed-function) exactly once per collective too;
//! - **end-to-end**: a p=8 64B scan cell on both offload paths —
//!   simulated latency (the VM charges per-instruction cycles, so its
//!   *modeled* latency is higher too), host wallclock, and the
//!   handler_instrs / handler_stalls counters per epoch.

use std::time::Instant;

use nfscan::cluster::Cluster;
use nfscan::config::{CostModel, EngineKind, ExecPath, ExpConfig};
use nfscan::data::{Dtype, Op, Payload};
use nfscan::fpga::allreduce::RdAllreduce;
use nfscan::fpga::engine::{CollEngine, EngineCtx};
use nfscan::metrics::Table;
use nfscan::nic::handler_engine;
use nfscan::packet::{AlgoType, CollType};
use nfscan::runtime::{make_engine, NativeEngine};
use nfscan::sim::OffloadRequest;

fn activation_ns(mut mk: impl FnMut() -> Box<dyn CollEngine>, reps: usize) -> f64 {
    let compute = NativeEngine::new();
    let cost = CostModel::default();
    let req = OffloadRequest {
        rank: 0,
        comm: 0,
        epoch: 0,
        comm_size: 2,
        coll: CollType::Allreduce,
        algo: AlgoType::RecursiveDoubling,
        op: Op::Sum,
        dtype: Dtype::I32,
        payload: Payload::from_i32(&(0..16).collect::<Vec<i32>>()),
    };
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut engine = mk();
        let mut ctx = EngineCtx {
            rank: 0,
            p: 2,
            inclusive: false,
            op: Op::Sum,
            coll: CollType::Allreduce,
            epoch: 0,
            compute: &compute,
            cost: &cost,
            cycles: 0,
            combine_cycles: 0,
            instrs: 0,
            stalls: 0,
        };
        let actions = engine.on_host_request(&mut ctx, &req);
        std::hint::black_box(&actions);
    }
    t0.elapsed().as_nanos() as f64 / reps as f64
}

fn cell(handler: bool, iters: usize) -> (f64, f64, u64, u64) {
    let mut cfg = ExpConfig::default();
    cfg.p = 8;
    cfg.msg_bytes = 64;
    cfg.iters = iters;
    cfg.warmup = 32;
    cfg.path = if handler { ExecPath::Handler } else { ExecPath::Fpga };
    let compute = make_engine(EngineKind::Native, "artifacts");
    let t0 = Instant::now();
    let mut cluster = Cluster::new(cfg, compute);
    let m = cluster.run().expect("bench run");
    let wall = t0.elapsed().as_secs_f64();
    (m.host_overall().avg_us(), wall, m.handler_instrs, m.handler_stalls)
}

fn main() {
    let reps = 200_000;
    let vm = activation_ns(|| handler_engine(CollType::Allreduce), reps);
    let ff = activation_ns(|| Box::new(RdAllreduce::new(0, 2)), reps);
    let mut t = Table::new(&["activation", "ns_per_call", "overhead"]);
    t.row(vec!["fixed-function".into(), format!("{ff:.1}"), "1.00x".into()]);
    t.row(vec!["handler VM".into(), format!("{vm:.1}"), format!("{:.2}x", vm / ff)]);
    println!("allreduce on_host_request activation, {reps} reps (host wallclock)");
    print!("{}", t.render());
    println!();

    let iters = 1_500;
    let (ff_us, ff_wall, _, _) = cell(false, iters);
    let (vm_us, vm_wall, instrs, stalls) = cell(true, iters);
    let epochs = (iters + 32) as u64;
    let mut t = Table::new(&[
        "path", "sim_avg_us", "wallclock_s", "instrs_per_epoch", "stalls_per_epoch",
    ]);
    t.row(vec!["NF_rd".into(), format!("{ff_us:.2}"), format!("{ff_wall:.2}"), "0".into(), "0".into()]);
    t.row(vec![
        "handler:scan".into(),
        format!("{vm_us:.2}"),
        format!("{vm_wall:.2}"),
        format!("{}", instrs / epochs),
        format!("{}", stalls / epochs),
    ]);
    println!("p=8 64B scan cell, {iters} iters (simulated latency + host wallclock)");
    print!("{}", t.render());
}
