//! Regenerates the paper's Fig. 4: average MPI_Scan latency vs message
//! size on 8 nodes, five series (sw_seq, sw_rd, NF_seq, NF_rd,
//! NF_binomial).  `cargo bench --bench fig4_avg_latency`.

use nfscan::bench::{fig4_table, figure_base, OSU_SIZES};
use nfscan::config::EngineKind;
use nfscan::runtime::make_engine;

fn main() {
    let iters = std::env::var("NFSCAN_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(500);
    let cfg = figure_base(iters);
    let compute = make_engine(EngineKind::Native, "artifacts");
    let t0 = std::time::Instant::now();
    let table = fig4_table(&cfg, compute, OSU_SIZES);
    println!("Fig. 4 — average MPI_Scan latency (us), 8 nodes, {iters} iters/cell");
    print!("{}", table.render());
    println!("[bench wallclock: {:.2}s]", t0.elapsed().as_secs_f64());
}
