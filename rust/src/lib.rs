//! nf-scan: in-network offload of MPI parallel prefix scan (MPI_Scan),
//! reproducing Arap & Swany, "Offloading MPI Parallel Prefix Scan
//! (MPI_Scan) with the NetFPGA" (2014) on a simulated NetFPGA cluster.
//!
//! Architecture (three layers, python never on the simulation path):
//!
//! - **L3 (this crate)** — the paper's system: a deterministic discrete-
//!   event cluster of hosts + NetFPGA NICs ([`sim`], [`net`], [`fpga`],
//!   plus the sPIN-style programmable handler VM in [`nic`]),
//!   the software-MPI baseline ([`mpi`]), the offload coordinator
//!   ([`offload`]) and the OSU-style benchmark harness ([`bench`]).
//! - **L2/L1 (python/compile)** — JAX graphs calling Pallas kernels for
//!   the payload-combine datapath, AOT-lowered to HLO text artifacts.
//! - **Runtime bridge** ([`runtime`]) — loads the artifacts via the PJRT
//!   CPU client (xla crate) and executes every reduction through them.

// The crate builds configs as `let mut cfg = ExpConfig::default(); cfg.x = ..`
// on purpose (mirrors the TOML [run] override model); the lint would force
// struct-update syntax on a 17-field struct.
#![allow(clippy::field_reassign_with_default)]

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod data;
pub mod fpga;
pub mod metrics;
pub mod mpi;
pub mod net;
pub mod nic;
pub mod offload;
pub mod packet;
pub mod prop;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod trace;
pub mod util;

// The lib test binary runs the allocation-counting assertions (pool
// behavior, counting-allocator self-test); integration tests and the
// nfscan binary install their own copies of the same allocator.  Not
// under Miri: a custom global allocator defeats Miri's allocation
// tracking, and the CI Miri job only runs the arena/payload suites.
#[cfg(all(test, not(miri)))]
#[global_allocator]
static TEST_ALLOC: util::alloc::CountingAllocator = util::alloc::CountingAllocator;
