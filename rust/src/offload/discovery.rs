//! Topology self-configuration (paper SSVI future work).
//!
//! "We are planning to put hardware logic into the NetFPGA to learn the
//! topology of the NetFPGA collective network and configure node roles
//! as appropriate ... eliminating the hardcoding that comes with the
//! current design."
//!
//! This module implements that plan at the model level: each card sends a
//! hello on every port (one LLDP-style exchange), the collected neighbor
//! maps are flooded, and every node independently reconstructs the wiring
//! and classifies it — from which `derive_role_in_hardware` assigns roles
//! with no software pre-configuration.  Tests assert the derived
//! configuration equals the manual one for every built-in wiring.

use std::collections::BTreeMap;

use crate::net::{PortNo, Rank, Topology};
use crate::packet::AlgoType;

/// What one card learns in the hello exchange: its own port -> neighbor.
pub type NeighborMap = BTreeMap<PortNo, Rank>;

/// Phase 1 — per-card neighbor discovery (one hello per cabled port).
pub fn discover_neighbors(topo: &Topology, rank: Rank) -> NeighborMap {
    topo.neighbors(rank).iter().copied().collect()
}

/// Phase 2 — flood: every card's neighbor map reaches every other card.
/// Returns the global wiring as each card reconstructs it.
pub fn flood_maps(topo: &Topology) -> Vec<NeighborMap> {
    (0..topo.p()).map(|r| discover_neighbors(topo, r)).collect()
}

/// What the discovered wiring looks like, as far as algorithm selection
/// cares.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum WiringClass {
    /// Every rank j is cabled to j+1 (and nothing else): sequential's
    /// natural wiring.
    Chain,
    /// A chain plus the wraparound cable.
    Ring,
    /// Every rank is cabled to all ranks differing in one bit.
    Hypercube,
    /// Anything else: fall back to routing + log-p algorithms.
    Irregular,
}

/// Phase 3 — classify the reconstructed wiring.
pub fn classify(maps: &[NeighborMap]) -> WiringClass {
    let p = maps.len();
    let degree_sum: usize = maps.iter().map(|m| m.len()).sum();
    let is_chain = (0..p).all(|j| {
        let peers: Vec<Rank> = maps[j].values().copied().collect();
        let mut want: Vec<Rank> = Vec::new();
        if j > 0 {
            want.push(j - 1);
        }
        if j + 1 < p {
            want.push(j + 1);
        }
        let mut sorted = peers.clone();
        sorted.sort_unstable();
        sorted == want
    });
    if is_chain {
        return WiringClass::Chain;
    }
    let is_ring = p >= 3
        && (0..p).all(|j| {
            let mut peers: Vec<Rank> = maps[j].values().copied().collect();
            peers.sort_unstable();
            let mut want = vec![(j + p - 1) % p, (j + 1) % p];
            want.sort_unstable();
            peers == want
        });
    if is_ring {
        return WiringClass::Ring;
    }
    if crate::util::is_pow2(p) {
        let dim = crate::util::log2(p);
        let is_cube = degree_sum == p * dim as usize
            && (0..p).all(|j| {
                maps[j].values().all(|&peer| (j ^ peer).count_ones() == 1)
                    && maps[j].len() == dim as usize
            });
        if is_cube {
            return WiringClass::Hypercube;
        }
    }
    WiringClass::Irregular
}

/// The full self-configuration pipeline: discover -> classify -> pick the
/// algorithm -> derive every node's role in hardware.  Returns
/// (algorithm, role per rank).
pub fn self_configure(
    topo: &Topology,
    msg_bytes: usize,
) -> (AlgoType, Vec<crate::packet::NodeType>) {
    let maps = flood_maps(topo);
    let class = classify(&maps);
    let p = topo.p();
    let algo = match class {
        WiringClass::Chain | WiringClass::Ring => {
            super::select_algorithm(topo, msg_bytes, p)
        }
        WiringClass::Hypercube => super::select_algorithm(topo, msg_bytes, p),
        WiringClass::Irregular => AlgoType::BinomialTree, // works over routing
    };
    let roles = (0..p)
        .map(|r| super::roles::derive_role_in_hardware(algo, r as u16, p as u16))
        .collect();
    (algo, roles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offload::node_role;

    #[test]
    fn classifies_builtin_wirings() {
        assert_eq!(classify(&flood_maps(&Topology::chain(8))), WiringClass::Chain);
        assert_eq!(classify(&flood_maps(&Topology::ring(8))), WiringClass::Ring);
        assert_eq!(classify(&flood_maps(&Topology::hypercube(8))), WiringClass::Hypercube);
        assert_eq!(classify(&flood_maps(&Topology::hypercube(16))), WiringClass::Hypercube);
    }

    #[test]
    fn irregular_detected() {
        let t = Topology::custom(
            "y",
            4,
            &[((0, 0), (1, 0)), ((0, 1), (2, 0)), ((0, 2), (3, 0))],
        );
        assert_eq!(classify(&flood_maps(&t)), WiringClass::Irregular);
    }

    #[test]
    fn chain_of_two_is_chain_not_cube() {
        // p=2: one cable; chain check runs first and wins (either
        // classification would work for the algorithms)
        let t = Topology::chain(2);
        assert_eq!(classify(&flood_maps(&t)), WiringClass::Chain);
    }

    #[test]
    fn self_configuration_matches_manual_roles() {
        for (topo, msg) in [
            (Topology::chain(8), 4usize),
            (Topology::hypercube(8), 4),
            (Topology::hypercube(8), 64 * 1024),
        ] {
            let (algo, roles) = self_configure(&topo, msg);
            for (r, &role) in roles.iter().enumerate() {
                assert_eq!(
                    role,
                    node_role(algo, r, topo.p()),
                    "rank {r} on {} msg {msg}",
                    topo.name()
                );
            }
        }
    }

    #[test]
    fn neighbor_maps_are_symmetric() {
        let topo = Topology::hypercube(16);
        let maps = flood_maps(&topo);
        for (j, m) in maps.iter().enumerate() {
            for &peer in m.values() {
                assert!(
                    maps[peer].values().any(|&back| back == j),
                    "cable {j}<->{peer} must appear on both ends"
                );
            }
        }
    }
}
