//! Pre-assigned node roles (the packet's `node_type` field).
//!
//! "The node_type could be derived from the rank and comm_size fields in
//! the hardware, but for simplicity, we let the software assign node
//! roles in advance, and let the NetFPGA run the algorithm based on the
//! assigned node_type role."  `derive_role_in_hardware` is the SSVI
//! future-work variant: the same mapping computed from (rank, comm_size)
//! alone, used by the self-configuration path and asserted equal.

use crate::net::Rank;
use crate::packet::{AlgoType, NodeType};

/// Software-side role pre-assignment.
pub fn node_role(algo: AlgoType, rank: Rank, p: usize) -> NodeType {
    match algo {
        AlgoType::Sequential => {
            if rank == 0 {
                NodeType::Head
            } else if rank == p - 1 {
                NodeType::Tail
            } else {
                NodeType::Mid
            }
        }
        AlgoType::RecursiveDoubling => NodeType::Generic,
        AlgoType::BinomialTree => {
            if rank == p - 1 {
                NodeType::Root
            } else if (rank as u64).trailing_ones() == 0 {
                NodeType::Leaf
            } else {
                NodeType::Internal
            }
        }
    }
}

/// The hardware-derivable version (paper SSVI): must agree with the
/// software assignment for every (rank, comm_size).
pub fn derive_role_in_hardware(algo: AlgoType, rank: u16, comm_size: u16) -> NodeType {
    node_role(algo, rank as Rank, comm_size as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_roles() {
        assert_eq!(node_role(AlgoType::Sequential, 0, 8), NodeType::Head);
        assert_eq!(node_role(AlgoType::Sequential, 3, 8), NodeType::Mid);
        assert_eq!(node_role(AlgoType::Sequential, 7, 8), NodeType::Tail);
    }

    #[test]
    fn binomial_roles_p8() {
        // even ranks are leaves; 7 is root; 1, 3, 5 internal
        assert_eq!(node_role(AlgoType::BinomialTree, 7, 8), NodeType::Root);
        for r in [0usize, 2, 4, 6] {
            assert_eq!(node_role(AlgoType::BinomialTree, r, 8), NodeType::Leaf, "rank {r}");
        }
        for r in [1usize, 3, 5] {
            assert_eq!(node_role(AlgoType::BinomialTree, r, 8), NodeType::Internal, "rank {r}");
        }
    }

    #[test]
    fn rd_everyone_generic() {
        for r in 0..8 {
            assert_eq!(node_role(AlgoType::RecursiveDoubling, r, 8), NodeType::Generic);
        }
    }

    #[test]
    fn hardware_derivation_agrees_everywhere() {
        for p in [2u16, 4, 8, 16, 32] {
            for algo in AlgoType::ALL {
                for r in 0..p {
                    assert_eq!(
                        derive_role_in_hardware(algo, r, p),
                        node_role(algo, r as Rank, p as usize),
                        "algo {algo:?} rank {r} p {p}"
                    );
                }
            }
        }
    }
}
