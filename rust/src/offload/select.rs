//! Topology-aware algorithm selection.
//!
//! The paper (SSI, contribution 3): "We provide different algorithm
//! selection at the hardware level.  Therefore MPI runtime can make an
//! intelligent selection of algorithms based on the underlying network
//! topology."  The policy below encodes what the paper's evaluation
//! found: the sequential chain wins on a chain wiring at small scale;
//! hypercube wirings favor recursive doubling at small messages (fewest
//! serialized hops) and the binomial tree for large multi-fragment
//! payloads (fewer total exchanged bytes: 2 log p one-directional hops vs
//! log p bidirectional exchanges).

use crate::net::{Topology, CHUNK_BYTES};
use crate::packet::AlgoType;

/// Pick the scan algorithm for a given wiring, message size and scale.
pub fn select_algorithm(topo: &Topology, msg_bytes: usize, p: usize) -> AlgoType {
    match topo.name() {
        // chain/ring wirings make j -> j+1 one hop: sequential is the
        // only algorithm whose pattern maps; it also wins the paper's
        // 8-node average-latency comparison.  Beyond a couple dozen ranks
        // its O(p) critical path loses to any log-p algorithm even with
        // hop penalties (the paper: "not scalable algorithmically").
        "chain" | "ring" if p <= 16 => AlgoType::Sequential,
        "chain" | "ring" => AlgoType::BinomialTree,
        // hypercube: partners are all one hop away.
        _ => {
            if msg_bytes <= CHUNK_BYTES {
                AlgoType::RecursiveDoubling
            } else {
                AlgoType::BinomialTree
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_small_scale_picks_sequential() {
        let t = Topology::chain(8);
        assert_eq!(select_algorithm(&t, 4, 8), AlgoType::Sequential);
    }

    #[test]
    fn chain_large_scale_abandons_sequential() {
        let t = Topology::chain(64);
        assert_eq!(select_algorithm(&t, 4, 64), AlgoType::BinomialTree);
    }

    #[test]
    fn hypercube_small_messages_pick_rd() {
        let t = Topology::hypercube(8);
        assert_eq!(select_algorithm(&t, 64, 8), AlgoType::RecursiveDoubling);
    }

    #[test]
    fn hypercube_large_messages_pick_binomial() {
        let t = Topology::hypercube(8);
        assert_eq!(select_algorithm(&t, 64 * 1024, 8), AlgoType::BinomialTree);
    }
}
