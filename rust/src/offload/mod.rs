//! The offload coordinator: the host side of NF_Scan.
//!
//! Builds the specially-crafted UDP request (Fig. 1), pre-assigns node
//! roles ("for simplicity, we let the software assign node roles in
//! advance"), and implements the algorithm-selection intelligence the
//! paper's introduction promises: "MPI runtime can make an intelligent
//! selection of algorithms based on the underlying network topology."

pub mod discovery;
pub mod roles;
pub mod select;

pub use discovery::{self_configure, WiringClass};
pub use roles::node_role;
pub use select::select_algorithm;

use crate::config::ExpConfig;
use crate::data::Payload;
use crate::net::Rank;
use crate::sim::OffloadRequest;

/// Build the offload request rank `rank` sends down to its card for
/// iteration `epoch` — the decoded HostRequest packet.
pub fn build_request(cfg: &ExpConfig, rank: Rank, epoch: u16, payload: Payload) -> OffloadRequest {
    OffloadRequest {
        rank,
        comm: 0, // MPI_COMM_WORLD in every paper experiment
        epoch,
        comm_size: cfg.p as u16,
        coll: cfg.coll,
        algo: cfg.algo,
        op: cfg.op,
        dtype: cfg.dtype,
        payload,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packet::AlgoType;

    #[test]
    fn request_carries_experiment_parameters() {
        let mut cfg = ExpConfig::default();
        cfg.algo = AlgoType::BinomialTree;
        let req = build_request(&cfg, 3, 17, Payload::from_i32(&[1]));
        assert_eq!(req.rank, 3);
        assert_eq!(req.epoch, 17);
        assert_eq!(req.algo, AlgoType::BinomialTree);
        assert_eq!(req.comm_size, 8);
    }
}
