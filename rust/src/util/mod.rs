//! Small shared utilities: power-of-two bit math used by every scan
//! algorithm, byte/duration formatting for reports, and the counting
//! allocator behind the zero-alloc regression gate.

pub mod alloc;

/// True iff `p` is a power of two (and non-zero).
pub fn is_pow2(p: usize) -> bool {
    p != 0 && (p & (p - 1)) == 0
}

/// floor(log2(p)); panics on 0.
pub fn log2(p: usize) -> u32 {
    assert!(p > 0, "log2(0)");
    usize::BITS - 1 - p.leading_zeros()
}

/// Smallest multiple of `m` that is >= `n`.
pub fn round_up(n: usize, m: usize) -> usize {
    assert!(m > 0);
    n.div_ceil(m) * m
}

/// Human-readable byte count for table headers (powers of two: 4B, 1KB...).
pub fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 20 && b % (1 << 20) == 0 {
        format!("{}MB", b >> 20)
    } else if b >= 1 << 10 && b % (1 << 10) == 0 {
        format!("{}KB", b >> 10)
    } else {
        format!("{b}B")
    }
}

/// Nanoseconds -> microseconds with 2 decimals, the unit of every figure
/// in the paper.
pub fn ns_to_us(ns: u64) -> f64 {
    ns as f64 / 1_000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_basics() {
        assert!(is_pow2(1));
        assert!(is_pow2(8));
        assert!(!is_pow2(0));
        assert!(!is_pow2(6));
    }

    #[test]
    fn log2_exact_and_floor() {
        assert_eq!(log2(1), 0);
        assert_eq!(log2(2), 1);
        assert_eq!(log2(8), 3);
        assert_eq!(log2(9), 3);
    }

    #[test]
    #[should_panic]
    fn log2_zero_panics() {
        log2(0);
    }

    #[test]
    fn round_up_cases() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(4), "4B");
        assert_eq!(fmt_bytes(1024), "1KB");
        assert_eq!(fmt_bytes(1 << 20), "1MB");
        assert_eq!(fmt_bytes(1500), "1500B");
    }
}
