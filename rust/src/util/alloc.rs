//! A counting global allocator: `System` plus relaxed atomic counters.
//!
//! The hot-datapath work (arena payloads, in-place combine, streaming
//! reassembly) claims *zero steady-state allocations*; that claim is only
//! worth anything if it is measured.  The `nfscan` binary, the
//! `fold_reassembly` bench and the `alloc_free` regression test install
//! this allocator via `#[global_allocator]` and read the counters around
//! their hot loops — two relaxed increments per malloc, unmeasurable
//! against the allocator itself.
//!
//! Library builds that do NOT install it (other benches, downstream
//! users) see counters frozen at zero; [`counting_installed`] probes for
//! that so reports can say "n/a" instead of lying with 0.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static DEALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Install with `#[global_allocator] static A: CountingAllocator =
/// CountingAllocator;` in a binary/test root.
pub struct CountingAllocator;

// SAFETY: defers every operation to `System`; the counters are plain
// atomics with no allocation of their own.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        DEALLOCS.fetch_add(1, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // one allocation event (a grow/shrink hits the allocator once)
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    // alloc_zeroed: the default impl routes through self.alloc -> counted
}

/// Total allocation events since process start (0 if not installed).
pub fn allocation_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Total deallocation events since process start.
pub fn deallocation_count() -> u64 {
    DEALLOCS.load(Ordering::Relaxed)
}

/// Total bytes requested since process start.
pub fn allocated_bytes() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

/// True iff the counting allocator is actually the global allocator:
/// performs one throwaway heap allocation and checks the counter moved.
pub fn counting_installed() -> bool {
    let before = allocation_count();
    let probe = std::hint::black_box(Box::new(0xA5u8));
    drop(std::hint::black_box(probe));
    allocation_count() != before
}

#[cfg(test)]
mod tests {
    // the lib test binary installs CountingAllocator (see lib.rs), so the
    // probe must see it — except under Miri, where the allocator is gated
    // out so Miri keeps its own allocation tracking
    #[test]
    #[cfg(not(miri))]
    fn installed_in_lib_tests_and_counts() {
        assert!(super::counting_installed());
        let a0 = super::allocation_count();
        let v = std::hint::black_box(vec![1u8, 2, 3]);
        drop(std::hint::black_box(v));
        assert!(super::allocation_count() > a0);
        assert!(super::allocated_bytes() >= 3);
    }
}
