//! The collective-offload engine interface — the "user-data-path" module
//! of the paper's NetFPGA design.
//!
//! One engine instance runs ONE collective invocation (one epoch) on one
//! card.  The NIC creates instances on demand — either when the host's
//! offload request crosses down, or when a peer's packet arrives first
//! (late-rank scenarios) — and retires them when [`CollEngine::done`]
//! reports completion.  That per-epoch lifetime is exactly the
//! (comm_id, collective_state) table the paper's SSVI sketches as future
//! work.

use crate::config::CostModel;
use crate::data::{Op, Payload};
use crate::net::Rank;
use crate::packet::{AlgoType, CollPacket, CollType, MsgType};
use crate::runtime::Compute;
use crate::sim::OffloadRequest;

/// What an engine instructs its card to do.  The NIC turns these into
/// framed, fragmented, routed packets (or a host delivery).
#[derive(Debug)]
pub enum NicAction {
    /// Unicast a collective packet to peer `dst`'s card.
    Send { dst: Rank, mt: MsgType, step: u16, tag: u32, payload: Payload },
    /// Multicast one packet to several cards at once (the NetFPGA
    /// multicast engine of the paper's SSIII-C optimization).  Ports are
    /// driven in parallel; a shared output port serializes naturally.
    Multicast { dsts: Vec<Rank>, mt: MsgType, step: u16, tag: u32, payload: Payload },
    /// Deliver the final outcome up to the local host (the Result packet;
    /// the NIC attaches the elapsed-time register value).
    Deliver { payload: Payload },
    /// Resend the pending reliable frame this activation was fired for.
    /// Only meaningful from a timer activation; the NIC (which owns the
    /// pending-transaction store) clones and re-transmits the frame.
    Retransmit,
}

/// Activation context: compute access + cycle accounting.  The engine
/// charges datapath cycles (combine at line rate) here; the NIC adds the
/// fixed pipeline latency and converts to virtual time.
pub struct EngineCtx<'a> {
    pub rank: Rank,
    pub p: usize,
    pub inclusive: bool,
    pub op: Op,
    /// Which collective this activation serves — carried so dynamic
    /// trips (handler-VM asserts, the static verifier's backstop) can
    /// name the failing flow.
    pub coll: CollType,
    /// Epoch of the flow being activated (same role: diagnostics).
    pub epoch: u16,
    pub compute: &'a dyn Compute,
    pub cost: &'a CostModel,
    /// Cycles consumed by this activation's datapath work.
    pub cycles: u64,
    /// The subset of `cycles` spent in combine folds (the arithmetic
    /// itself, not packet handling) — latency attribution splits an
    /// activation into handler-exec vs compute along this line.
    pub combine_cycles: u64,
    /// Handler-VM instructions retired by this activation (0 on the
    /// fixed-function path) — pooled into `metrics.handler_instrs`.
    pub instrs: u64,
    /// Handler-VM activations parked waiting for input (`drop`
    /// terminator) — pooled into `metrics.handler_stalls`.
    pub stalls: u64,
}

impl EngineCtx<'_> {
    /// Elementwise combine, charging line-rate cycles (64-bit datapath).
    pub fn combine(&mut self, a: &Payload, b: &Payload) -> Payload {
        let c = self.cost.nic_combine_cycles(a.byte_len());
        self.cycles += c;
        self.combine_cycles += c;
        self.compute.combine(a, b, self.op).expect("engine combine")
    }

    /// In-place combine `acc = acc (op) b` — identical cycle charge and
    /// bit-identical result to [`EngineCtx::combine`], but the state
    /// machines' running accumulators fold without allocating (the
    /// hardware's preallocated-buffer discipline).
    pub fn combine_into(&mut self, acc: &mut Payload, b: &Payload) {
        let c = self.cost.nic_combine_cycles(acc.byte_len());
        self.cycles += c;
        self.combine_cycles += c;
        self.compute.combine_into(acc, b, self.op).expect("engine combine");
    }

    /// In-place combine with the accumulator on the right:
    /// `acc = a (op) acc` (the rank-ordered folds feed from both sides).
    pub fn combine_into_rev(&mut self, acc: &mut Payload, a: &Payload) {
        let c = self.cost.nic_combine_cycles(a.byte_len());
        self.cycles += c;
        self.combine_cycles += c;
        self.compute.combine_into_rev(acc, a, self.op).expect("engine combine");
    }

    /// Inverse-subtract (multicast optimization).  Charges NO extra
    /// cycles: the subtraction overlaps packet reception — "we do not
    /// need extra cycles to perform subtraction while streaming the
    /// data" (SSIII-C).
    pub fn derive(&mut self, cumulative: &Payload, own: &Payload) -> Payload {
        self.compute.derive(cumulative, own).expect("engine derive")
    }

    /// Identity payload (for exclusive-scan rank 0).
    pub fn identity(&self, like: &Payload) -> Payload {
        Payload::identity(like.dtype(), self.op, like.len())
    }
}

/// One collective state machine (one epoch on one card).
pub trait CollEngine {
    /// The local host's offload request arrived (HostRequest packet).
    fn on_host_request(&mut self, ctx: &mut EngineCtx, req: &OffloadRequest) -> Vec<NicAction>;

    /// A (fully reassembled) peer packet arrived for this epoch.
    fn on_packet(&mut self, ctx: &mut EngineCtx, pkt: &CollPacket) -> Vec<NicAction>;

    /// True when this instance can be retired (result delivered AND all
    /// protocol obligations — ACKs, down-phase sends — discharged).
    fn done(&self) -> bool;

    fn algo(&self) -> AlgoType;
}

/// Hardware feature switches (ablation benches flip these).
#[derive(Clone, Copy, Debug)]
pub struct EngineOpts {
    /// SSIII-C multicast + inverse-subtract optimization (recursive
    /// doubling only).
    pub multicast_opt: bool,
    /// SSIII-B ACK flow control (sequential only).
    pub ack_enabled: bool,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts { multicast_opt: true, ack_enabled: true }
    }
}

/// Instantiate the state machine for a (collective, algorithm) pair.
pub fn make_engine(
    algo: AlgoType,
    rank: Rank,
    p: usize,
    coll: CollType,
    opts: EngineOpts,
) -> Box<dyn CollEngine> {
    match coll {
        CollType::Scan | CollType::Exscan => match algo {
            AlgoType::Sequential => {
                let mut e = super::seq::SeqEngine::new(rank, p, coll);
                e.ack_enabled = opts.ack_enabled;
                Box::new(e)
            }
            AlgoType::RecursiveDoubling => {
                Box::new(super::rd::RdEngine::new(rank, p, coll, opts.multicast_opt))
            }
            AlgoType::BinomialTree => {
                let mut e = super::binomial::BinomialEngine::new(rank, p, coll);
                e.ack_enabled = opts.ack_enabled;
                Box::new(e)
            }
        },
        CollType::Allreduce | CollType::Barrier => match algo {
            AlgoType::BinomialTree => Box::new(super::allreduce::TreeAllreduce::new(rank, p)),
            AlgoType::RecursiveDoubling => Box::new(super::allreduce::RdAllreduce::new(rank, p)),
            AlgoType::Sequential => {
                panic!("no sequential hardware machine for {coll:?} (use rd/binomial)")
            }
        },
        CollType::Bcast => panic!(
            "MPI_Bcast has no fixed-function machine — offload it via the handler VM \
             (nic::programs::handler_engine)"
        ),
        CollType::Reduce => panic!("MPI_Reduce offload not implemented (coll_type reserved)"),
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    //! Drive engines directly (no network) — shared by the per-algorithm
    //! unit tests.  A tiny in-memory "wire" delivers actions between
    //! engines until quiescence, then results are compared to the oracle.

    use std::collections::VecDeque;

    use super::*;
    use crate::data::Dtype;
    use crate::packet::NodeType;
    use crate::runtime::NativeEngine;

    pub struct Harness {
        pub p: usize,
        pub coll: CollType,
        pub op: Op,
        pub engines: Vec<Box<dyn CollEngine>>,
        pub results: Vec<Option<Payload>>,
        queue: VecDeque<(Rank, CollPacket)>, // (dst, packet)
        compute: NativeEngine,
        cost: CostModel,
    }

    impl Harness {
        pub fn new(algo: AlgoType, p: usize, coll: CollType, multicast_opt: bool) -> Harness {
            let opts = EngineOpts { multicast_opt, ..Default::default() };
            Harness::with_engines(p, coll, |r| make_engine(algo, r, p, coll, opts))
        }

        /// Build with custom engine instances (the handler-VM tests plug
        /// `nic::programs::handler_engine` in here).
        pub fn with_engines(
            p: usize,
            coll: CollType,
            mk: impl Fn(Rank) -> Box<dyn CollEngine>,
        ) -> Harness {
            Harness {
                p,
                coll,
                op: Op::Sum,
                engines: (0..p).map(mk).collect(),
                results: vec![None; p],
                queue: VecDeque::new(),
                compute: NativeEngine::new(),
                cost: CostModel::default(),
            }
        }

        fn enqueue(&mut self, from: Rank, actions: Vec<NicAction>) {
            for a in actions {
                match a {
                    NicAction::Send { dst, mt, step, tag, payload } => {
                        self.queue.push_back((dst, self.pkt(from, mt, step, tag, payload)));
                    }
                    NicAction::Multicast { dsts, mt, step, tag, payload } => {
                        for dst in dsts {
                            self.queue.push_back((
                                dst,
                                self.pkt(from, mt, step, tag, payload.clone()),
                            ));
                        }
                    }
                    NicAction::Deliver { payload } => {
                        assert!(self.results[from].is_none(), "double result at {from}");
                        self.results[from] = Some(payload);
                    }
                    NicAction::Retransmit => {
                        panic!("engine emitted Retransmit outside a timer activation")
                    }
                }
            }
        }

        fn pkt(
            &self,
            from: Rank,
            mt: MsgType,
            step: u16,
            tag: u32,
            payload: Payload,
        ) -> CollPacket {
            CollPacket {
                comm_id: 0,
                comm_size: self.p as u16,
                coll_type: self.coll,
                algo_type: self.engines[from].algo(),
                node_type: NodeType::Generic,
                msg_type: mt,
                step,
                rank: from as u16,
                root: 0,
                operation: self.op,
                data_type: payload.dtype(),
                count: payload.len() as u32,
                frag_idx: 0,
                frag_total: 1,
                tag,
                payload,
            }
        }

        /// Host calls MPI_Scan at `rank` with `own` data.
        pub fn call(&mut self, rank: Rank, own: Payload) {
            let req = OffloadRequest {
                rank,
                comm: 0,
                epoch: 0,
                comm_size: self.p as u16,
                coll: self.coll,
                algo: self.engines[rank].algo(),
                op: self.op,
                dtype: Dtype::I32,
                payload: own,
            };
            // field-disjoint borrows: engines (mut) + compute/cost (ref)
            let mut ctx = EngineCtx {
                rank,
                p: self.p,
                inclusive: self.coll.inclusive(),
                op: self.op,
                coll: self.coll,
                epoch: 0,
                compute: &self.compute,
                cost: &self.cost,
                cycles: 0,
                combine_cycles: 0,
                instrs: 0,
                stalls: 0,
            };
            let actions = self.engines[rank].on_host_request(&mut ctx, &req);
            self.enqueue(rank, actions);
        }

        /// Deliver queued packets until quiescent.
        pub fn drain(&mut self) {
            while let Some((dst, pkt)) = self.queue.pop_front() {
                let mut ctx = EngineCtx {
                    rank: dst,
                    p: self.p,
                    inclusive: self.coll.inclusive(),
                    op: self.op,
                    coll: self.coll,
                    epoch: 0,
                    compute: &self.compute,
                    cost: &self.cost,
                    cycles: 0,
                    combine_cycles: 0,
                    instrs: 0,
                    stalls: 0,
                };
                let actions = self.engines[dst].on_packet(&mut ctx, &pkt);
                self.enqueue(dst, actions);
            }
        }

        /// Run the collective with every rank calling in `order`, then
        /// assert every rank's result equals the oracle (prefix for
        /// scans, total for allreduce, empty for barrier).
        pub fn run_and_check(&mut self, contributions: &[Vec<i32>], order: &[Rank]) {
            assert_eq!(contributions.len(), self.p);
            for &r in order {
                self.call(r, Payload::from_i32(&contributions[r]));
                self.drain();
            }
            let payloads: Vec<Payload> =
                contributions.iter().map(|c| Payload::from_i32(c)).collect();
            for r in 0..self.p {
                let want = match self.coll {
                    CollType::Scan | CollType::Exscan => crate::runtime::engine::oracle_prefix(
                        &self.compute,
                        &payloads,
                        self.op,
                        self.coll.inclusive(),
                        r,
                    )
                    .unwrap(),
                    // allreduce: every rank gets the full reduction
                    CollType::Allreduce | CollType::Barrier => {
                        crate::runtime::engine::oracle_prefix(
                            &self.compute,
                            &payloads,
                            self.op,
                            true,
                            self.p - 1,
                        )
                        .unwrap()
                    }
                    // every rank receives the root's contribution
                    CollType::Bcast => payloads[0].clone(),
                    CollType::Reduce => unreachable!(),
                };
                let got = self.results[r].as_ref().unwrap_or_else(|| panic!("rank {r} no result"));
                assert_eq!(got.to_i32(), want.to_i32(), "rank {r} wrong {:?} result", self.coll);
                assert!(self.engines[r].done(), "rank {r} engine not done");
            }
        }
    }
}
