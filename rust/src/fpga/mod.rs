//! The NetFPGA model: a cycle-approximate first-generation card.
//!
//! Everything the paper's hardware design does lives here:
//!
//! - [`engine`] — the collective-offload engine interface (the user-data-
//!   path module of the paper's design) and its activation context;
//! - [`seq`] / [`rd`] / [`binomial`] — the three per-algorithm hardware
//!   state machines of SSIII-B/C/D, including the sequential ACK protocol,
//!   the recursive-doubling multicast + inverse-subtract optimization and
//!   the binomial up/down phases with preallocated child buffers;
//! - [`registers`] — the 125 MHz cycle counter and the offload/release
//!   timestamp registers behind Figs. 6 and 7;
//! - [`reassembly`] — per-(src, type, step, epoch) fragment buffers for
//!   messages larger than one MTU;
//! - [`nic`] — per-card state: port FIFOs, engines per epoch, counters,
//!   and the reference-NIC IP forwarding passthrough.

pub mod allreduce;
pub mod binomial;
pub mod engine;
pub mod nic;
pub mod rd;
pub mod reassembly;
pub mod registers;
pub mod seq;

pub use engine::{make_engine, CollEngine, EngineCtx, EngineOpts, NicAction};
pub use nic::{HpuJob, HpuSched, Nic, PendingTx};
pub use registers::Registers;
