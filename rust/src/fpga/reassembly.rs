//! Fragment reassembly for messages larger than one MTU.
//!
//! The paper's hardware streams payload words through the combine pipeline
//! as they arrive; the simulation's equivalent is to buffer fragments (they
//! arrive in order on a FIFO link) and activate the state machine when the
//! message is complete, charging line-rate combine cycles for the whole
//! payload — identical completion time, simpler state.

use std::collections::HashMap;
use std::hash::Hash;

use crate::data::Payload;

/// In-progress messages keyed by K (src, type, step, epoch — caller's
/// choice).  Capacity-limited: the NetFPGA has "preallocated buffers";
/// exceeding the configured budget is a protocol violation and panics
/// (the ACK machinery exists to make that impossible).
#[derive(Debug)]
pub struct Reassembler<K: Eq + Hash + Clone + std::fmt::Debug> {
    parts: HashMap<K, Vec<Option<Payload>>>,
    max_messages: usize,
}

impl<K: Eq + Hash + Clone + std::fmt::Debug> Reassembler<K> {
    pub fn new(max_messages: usize) -> Self {
        // presized to the budget: the per-NIC maps sit on the hot receive
        // path and must never rehash mid-run
        Reassembler { parts: HashMap::with_capacity(max_messages), max_messages }
    }

    /// Add a fragment; returns the complete payload when all fragments of
    /// the message have arrived.
    pub fn add(
        &mut self,
        key: K,
        frag_idx: u16,
        frag_total: u16,
        payload: Payload,
    ) -> Option<Payload> {
        assert!(frag_total >= 1 && frag_idx < frag_total, "bad fragment indices");
        if frag_total == 1 {
            return Some(payload); // fast path: unfragmented
        }
        let entry = self.parts.entry(key.clone()).or_insert_with(|| {
            vec![None; frag_total as usize]
        });
        assert_eq!(entry.len(), frag_total as usize, "inconsistent frag_total for {key:?}");
        assert!(
            self.parts.len() <= self.max_messages,
            "reassembly buffer overflow (> {} messages) — flow control failed",
            self.max_messages
        );
        let entry = self.parts.get_mut(&key).unwrap();
        assert!(entry[frag_idx as usize].is_none(), "duplicate fragment {frag_idx} for {key:?}");
        entry[frag_idx as usize] = Some(payload);
        if entry.iter().all(|p| p.is_some()) {
            let chunks: Vec<Payload> =
                self.parts.remove(&key).unwrap().into_iter().map(|p| p.unwrap()).collect();
            Some(Payload::concat(&chunks))
        } else {
            None
        }
    }

    /// Messages currently buffered (for buffer-occupancy metrics).
    pub fn pending(&self) -> usize {
        self.parts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_fragment_passthrough() {
        let mut r: Reassembler<u32> = Reassembler::new(4);
        let p = Payload::from_i32(&[1, 2]);
        assert_eq!(r.add(1, 0, 1, p.clone()), Some(p));
        assert_eq!(r.pending(), 0);
    }

    #[test]
    fn multi_fragment_in_order() {
        let mut r: Reassembler<u32> = Reassembler::new(4);
        let a = Payload::from_i32(&[1, 2]);
        let b = Payload::from_i32(&[3]);
        assert_eq!(r.add(7, 0, 2, a), None);
        assert_eq!(r.pending(), 1);
        let whole = r.add(7, 1, 2, b).unwrap();
        assert_eq!(whole.to_i32(), vec![1, 2, 3]);
        assert_eq!(r.pending(), 0);
    }

    #[test]
    fn out_of_order_fragments_ok() {
        let mut r: Reassembler<u32> = Reassembler::new(4);
        assert_eq!(r.add(7, 1, 2, Payload::from_i32(&[3])), None);
        let whole = r.add(7, 0, 2, Payload::from_i32(&[1, 2])).unwrap();
        assert_eq!(whole.to_i32(), vec![1, 2, 3]);
    }

    #[test]
    fn interleaved_keys() {
        let mut r: Reassembler<(u32, u32)> = Reassembler::new(4);
        assert_eq!(r.add((1, 0), 0, 2, Payload::from_i32(&[1])), None);
        assert_eq!(r.add((2, 0), 0, 2, Payload::from_i32(&[9])), None);
        assert!(r.add((1, 0), 1, 2, Payload::from_i32(&[2])).is_some());
        assert!(r.add((2, 0), 1, 2, Payload::from_i32(&[10])).is_some());
    }

    #[test]
    #[should_panic]
    fn duplicate_fragment_panics() {
        let mut r: Reassembler<u32> = Reassembler::new(4);
        r.add(7, 0, 2, Payload::from_i32(&[1]));
        r.add(7, 0, 2, Payload::from_i32(&[1]));
    }

    #[test]
    #[should_panic]
    fn overflow_panics() {
        let mut r: Reassembler<u32> = Reassembler::new(1);
        r.add(1, 0, 2, Payload::from_i32(&[1]));
        r.add(2, 0, 2, Payload::from_i32(&[1]));
    }
}
