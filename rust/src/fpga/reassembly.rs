//! Streaming fragment reassembly for messages larger than one MTU.
//!
//! The paper's hardware streams payload words through the combine pipeline
//! as they arrive; the simulation's equivalent buffers fragments (they
//! arrive in order on a FIFO link) and activates the state machine when
//! the message is complete, charging line-rate combine cycles for the
//! whole payload — identical completion time, simpler state.
//!
//! The buffering itself is streaming: the whole-message arena buffer is
//! allocated (from the thread-local pool) on the FIRST fragment and each
//! fragment is memcpy'd straight into its slot — one copy per byte, like
//! the card's preallocated receive SRAM.  The previous design buffered a
//! `Vec<Option<Payload>>` of fragment clones and `concat`ed at the end,
//! copying every multi-MTU message twice and allocating per message.
//!
//! A fragment's slot is derivable from its own shape: `fragment()` cuts
//! uniform chunks except the last, so a non-last fragment of length L
//! sits at element `frag_idx * L`, and the last sits at `count - L`.
//! That keeps the wire format unchanged (no explicit offset field).

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::hash::Hash;

use crate::data::Payload;

/// Cap on fragments per message (the `seen` bitmap width).  128 MTU-sized
/// fragments ≈ 180 KB — far beyond any benchmarked message; the card's
/// reassembly SRAM would overflow long before.
pub const MAX_FRAGS_PER_MSG: usize = 128;

/// One in-progress message: the preallocated whole-message buffer plus a
/// received-fragment bitmap.
#[derive(Debug)]
struct InProgress {
    buf: Payload,
    frag_total: u16,
    total_elems: u32,
    seen: u128,
    /// Uniform non-last fragment length (elements), once observed — the
    /// slot derivation relies on it, so it is checked, not assumed.
    chunk_elems: Option<u32>,
    /// Last fragment's length (elements), once observed.
    last_elems: Option<u32>,
}

impl InProgress {
    fn full_mask(frag_total: u16) -> u128 {
        if frag_total as usize == MAX_FRAGS_PER_MSG {
            u128::MAX
        } else {
            (1u128 << frag_total) - 1
        }
    }

    /// Memcpy one fragment into its slot; true when the message is whole.
    fn accept(&mut self, frag_idx: u16, frag_total: u16, total_count: u32, p: &Payload) -> bool {
        assert_eq!(self.frag_total, frag_total, "inconsistent frag_total for message");
        assert_eq!(self.total_elems, total_count, "inconsistent element count for message");
        assert_eq!(self.buf.dtype(), p.dtype(), "inconsistent dtype for message");
        let bit = 1u128 << frag_idx;
        assert!(self.seen & bit == 0, "duplicate fragment {frag_idx}");
        self.seen |= bit;
        let len = p.len();
        let off_elems = if frag_idx + 1 == frag_total {
            self.last_elems = Some(len as u32);
            (total_count as usize).checked_sub(len).expect("last fragment longer than message")
        } else {
            // all non-last fragments must share one chunk length — the
            // slot derivation depends on it
            match self.chunk_elems {
                None => self.chunk_elems = Some(len as u32),
                Some(c) => assert_eq!(
                    c as usize, len,
                    "non-uniform fragment length (frag {frag_idx})"
                ),
            }
            frag_idx as usize * len
        };
        assert!(off_elems + len <= total_count as usize, "fragment overruns message");
        // once both lengths are known the fragments must tile the message
        // exactly — overlaps/gaps would otherwise pass the bitmap check
        if let (Some(c), Some(l)) = (self.chunk_elems, self.last_elems) {
            assert_eq!(
                c as u64 * (frag_total as u64 - 1) + l as u64,
                total_count as u64,
                "fragments do not tile the message"
            );
        }
        self.buf.write_bytes_at(off_elems * p.dtype().size(), p.bytes());
        self.seen == Self::full_mask(frag_total)
    }
}

/// In-progress messages keyed by K (src, type, step, epoch — caller's
/// choice).  Capacity-limited: the NetFPGA has "preallocated buffers";
/// exceeding the configured budget is a protocol violation and panics
/// (the ACK machinery exists to make that impossible).
#[derive(Debug)]
pub struct Reassembler<K: Eq + Hash + Clone + std::fmt::Debug> {
    parts: HashMap<K, InProgress>,
    max_messages: usize,
}

impl<K: Eq + Hash + Clone + std::fmt::Debug> Reassembler<K> {
    pub fn new(max_messages: usize) -> Self {
        // presized to the budget: the per-NIC maps sit on the hot receive
        // path and must never rehash mid-run
        Reassembler { parts: HashMap::with_capacity(max_messages), max_messages }
    }

    /// Add a fragment (`total_count` = element count of the whole
    /// message, the packet's `count` field); returns the complete payload
    /// when all fragments have arrived.
    pub fn add(
        &mut self,
        key: K,
        frag_idx: u16,
        frag_total: u16,
        total_count: u32,
        payload: Payload,
    ) -> Option<Payload> {
        assert!(frag_total >= 1 && frag_idx < frag_total, "bad fragment indices");
        if frag_total == 1 {
            return Some(payload); // fast path: unfragmented
        }
        assert!(
            (frag_total as usize) <= MAX_FRAGS_PER_MSG,
            "message of {frag_total} fragments exceeds the {MAX_FRAGS_PER_MSG}-fragment \
             reassembly budget"
        );
        let live = self.parts.len();
        match self.parts.entry(key) {
            Entry::Occupied(mut e) => {
                let done = e.get_mut().accept(frag_idx, frag_total, total_count, &payload);
                if done {
                    Some(e.remove().buf)
                } else {
                    None
                }
            }
            Entry::Vacant(v) => {
                // budget check BEFORE inserting: the violating insert
                // itself panics, not the one after it
                assert!(
                    live < self.max_messages,
                    "reassembly buffer overflow (> {} messages) — flow control failed",
                    self.max_messages
                );
                let mut ip = InProgress {
                    buf: Payload::zeroed(payload.dtype(), total_count as usize),
                    frag_total,
                    total_elems: total_count,
                    seen: 0,
                    chunk_elems: None,
                    last_elems: None,
                };
                let done = ip.accept(frag_idx, frag_total, total_count, &payload);
                debug_assert!(!done, "frag_total >= 2 cannot complete on one fragment");
                v.insert(ip);
                None
            }
        }
    }

    /// Messages currently buffered (for buffer-occupancy metrics).
    pub fn pending(&self) -> usize {
        self.parts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_fragment_passthrough() {
        let mut r: Reassembler<u32> = Reassembler::new(4);
        let p = Payload::from_i32(&[1, 2]);
        assert_eq!(r.add(1, 0, 1, 2, p.clone()), Some(p));
        assert_eq!(r.pending(), 0);
    }

    #[test]
    fn multi_fragment_in_order() {
        let mut r: Reassembler<u32> = Reassembler::new(4);
        let a = Payload::from_i32(&[1, 2]);
        let b = Payload::from_i32(&[3]);
        assert_eq!(r.add(7, 0, 2, 3, a), None);
        assert_eq!(r.pending(), 1);
        let whole = r.add(7, 1, 2, 3, b).unwrap();
        assert_eq!(whole.to_i32(), vec![1, 2, 3]);
        assert_eq!(r.pending(), 0);
    }

    #[test]
    fn out_of_order_fragments_ok() {
        // the last (short) fragment first: its slot is count - len
        let mut r: Reassembler<u32> = Reassembler::new(4);
        assert_eq!(r.add(7, 1, 2, 3, Payload::from_i32(&[3])), None);
        let whole = r.add(7, 0, 2, 3, Payload::from_i32(&[1, 2])).unwrap();
        assert_eq!(whole.to_i32(), vec![1, 2, 3]);
    }

    #[test]
    fn middle_fragments_any_order() {
        // 3 uniform + 1 tail, delivered shuffled
        let chunks: [&[i32]; 4] = [&[0, 1], &[2, 3], &[4, 5], &[6]];
        for order in [[2u16, 0, 3, 1], [3, 2, 1, 0], [1, 3, 0, 2]] {
            let mut r: Reassembler<u32> = Reassembler::new(4);
            let mut whole = None;
            for idx in order {
                whole = r.add(9, idx, 4, 7, Payload::from_i32(chunks[idx as usize]));
            }
            assert_eq!(whole.unwrap().to_i32(), vec![0, 1, 2, 3, 4, 5, 6], "{order:?}");
        }
    }

    #[test]
    fn interleaved_keys() {
        let mut r: Reassembler<(u32, u32)> = Reassembler::new(4);
        assert_eq!(r.add((1, 0), 0, 2, 2, Payload::from_i32(&[1])), None);
        assert_eq!(r.add((2, 0), 0, 2, 2, Payload::from_i32(&[9])), None);
        assert!(r.add((1, 0), 1, 2, 2, Payload::from_i32(&[2])).is_some());
        assert!(r.add((2, 0), 1, 2, 2, Payload::from_i32(&[10])).is_some());
    }

    #[test]
    fn f64_fragments_reassemble() {
        let mut r: Reassembler<u32> = Reassembler::new(4);
        assert_eq!(r.add(1, 0, 2, 3, Payload::from_f64(&[1.5, 2.5])), None);
        let whole = r.add(1, 1, 2, 3, Payload::from_f64(&[3.5])).unwrap();
        assert_eq!(whole.to_f64(), vec![1.5, 2.5, 3.5]);
    }

    #[test]
    #[should_panic(expected = "non-uniform fragment length")]
    fn non_uniform_chunks_rejected() {
        // [3, 2, 2] tiling: slot derivation would corrupt silently, so it
        // must refuse loudly
        let mut r: Reassembler<u32> = Reassembler::new(4);
        r.add(1, 0, 3, 7, Payload::from_i32(&[0, 1, 2]));
        r.add(1, 1, 3, 7, Payload::from_i32(&[3, 4]));
    }

    #[test]
    #[should_panic(expected = "do not tile")]
    fn gapped_tiling_rejected() {
        // chunk 3 + last 2 covers 5 of 7 elements: bitmap would complete
        // with a hole, so the tiling equation must refuse
        let mut r: Reassembler<u32> = Reassembler::new(4);
        r.add(1, 0, 2, 7, Payload::from_i32(&[0, 1, 2]));
        r.add(1, 1, 2, 7, Payload::from_i32(&[5, 6]));
    }

    #[test]
    #[should_panic(expected = "duplicate fragment")]
    fn duplicate_fragment_panics() {
        let mut r: Reassembler<u32> = Reassembler::new(4);
        r.add(7, 0, 2, 3, Payload::from_i32(&[1, 2]));
        r.add(7, 0, 2, 3, Payload::from_i32(&[1, 2]));
    }

    #[test]
    #[should_panic(expected = "reassembly buffer overflow")]
    fn overflow_panics_at_the_violating_insert() {
        let mut r: Reassembler<u32> = Reassembler::new(1);
        r.add(1, 0, 2, 2, Payload::from_i32(&[1]));
        r.add(2, 0, 2, 2, Payload::from_i32(&[1]));
    }

    #[test]
    fn whole_message_reuses_pooled_storage() {
        // same-shaped messages recycle the whole-message buffer: after
        // the first, pool hits must grow
        let mut r: Reassembler<u32> = Reassembler::new(4);
        let n = 1217usize; // uncommon size so the bin is ours
        let a: Vec<i32> = (0..n as i32).collect();
        let head = Payload::from_i32(&a[..1000]);
        let tail = Payload::from_i32(&a[1000..]);
        let first = {
            r.add(1, 0, 2, n as u32, head.clone());
            r.add(1, 1, 2, n as u32, tail.clone()).unwrap()
        };
        assert_eq!(first.to_i32(), a);
        drop(first);
        let (h0, _) = crate::data::arena::pool_stats();
        let second = {
            r.add(2, 0, 2, n as u32, head);
            r.add(2, 1, 2, n as u32, tail).unwrap()
        };
        assert_eq!(second.to_i32(), a);
        let (h1, _) = crate::data::arena::pool_stats();
        assert!(h1 > h0, "second message must draw its buffer from the pool");
    }
}
