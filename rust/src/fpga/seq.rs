//! Sequential scan state machine (paper SSIII-B).
//!
//! Rank j waits for the partial prefix from rank j-1, folds in its own
//! contribution, forwards to j+1 — O(p) steps.  Offloading needs the ACK
//! protocol the paper describes: back-to-back MPI_Scan calls would
//! otherwise require unbounded NIC buffering for upstream ranks that run
//! ahead.  "Rank j does not immediately return after it generates its
//! final outcome.  It waits for an acknowledgment packet from rank j+1.
//! The NetFPGA of rank j+1 sends an acknowledgment packet to the NetFPGA
//! of rank j after it receives the MPI_Scan request from its host and the
//! packet from rank j.  With this technique ... it can simply require a
//! single buffer."

use crate::net::Rank;
use crate::packet::{AlgoType, CollPacket, CollType, MsgType};
use crate::sim::OffloadRequest;

use super::engine::{CollEngine, EngineCtx, NicAction};

pub struct SeqEngine {
    rank: Rank,
    p: usize,
    coll: CollType,
    /// Host's offload request received.
    called: bool,
    own: Option<crate::data::Payload>,
    /// The single upstream buffer the ACK protocol guarantees suffices.
    upstream: Option<crate::data::Payload>,
    /// Result computed, waiting (possibly) for the downstream ACK.
    result: Option<crate::data::Payload>,
    sent_data: bool,
    sent_ack: bool,
    got_ack: bool,
    delivered: bool,
    /// Disable the result-gating ACK wait (ablation: shows why the paper
    /// needs it — the no-ack variant overflows the single buffer).
    pub ack_enabled: bool,
}

impl SeqEngine {
    pub fn new(rank: Rank, p: usize, coll: CollType) -> SeqEngine {
        SeqEngine {
            rank,
            p,
            coll,
            called: false,
            own: None,
            upstream: None,
            result: None,
            sent_data: false,
            sent_ack: false,
            got_ack: false,
            delivered: false,
            ack_enabled: true,
        }
    }

    fn is_head(&self) -> bool {
        self.rank == 0
    }

    fn is_tail(&self) -> bool {
        self.rank == self.p - 1
    }

    /// Advance the machine as far as current inputs allow.
    fn proceed(&mut self, ctx: &mut EngineCtx) -> Vec<NicAction> {
        let mut out = Vec::new();
        if !self.called {
            return out;
        }
        let own = self.own.as_ref().unwrap().clone();

        if self.is_head() {
            // rank 0 receives nothing: its prefix is its own data.
            if !self.sent_data {
                self.sent_data = true;
                self.result = Some(if self.coll.inclusive() {
                    own.clone()
                } else {
                    ctx.identity(&own)
                });
                out.push(NicAction::Send {
                    dst: 1,
                    mt: MsgType::Data,
                    step: 0,
                    tag: 0,
                    payload: own,
                });
            }
        } else if let Some(upstream) = self.upstream.clone() {
            if !self.sent_ack {
                // both the host request and the upstream packet are here:
                // release rank j-1 (this is what lets it return).
                self.sent_ack = true;
                out.push(NicAction::Send {
                    dst: self.rank - 1,
                    mt: MsgType::Ack,
                    step: 0,
                    tag: 0,
                    payload: crate::data::Payload::identity(own.dtype(), ctx.op, 0),
                });
            }
            if self.result.is_none() {
                // prefix = upstream (op) own, folded in place
                let mut prefix = upstream.clone();
                ctx.combine_into(&mut prefix, &own);
                self.result = Some(if self.coll.inclusive() { prefix.clone() } else { upstream });
                if !self.is_tail() && !self.sent_data {
                    self.sent_data = true;
                    out.push(NicAction::Send {
                        dst: self.rank + 1,
                        mt: MsgType::Data,
                        step: 0,
                        tag: 0,
                        payload: prefix,
                    });
                }
            }
        }

        // deliver when the downstream ACK has released us (tail exempt).
        if !self.delivered && self.result.is_some() {
            let released = self.is_tail() || self.got_ack || !self.ack_enabled;
            if released {
                self.delivered = true;
                out.push(NicAction::Deliver { payload: self.result.clone().unwrap() });
            }
        }
        out
    }
}

impl CollEngine for SeqEngine {
    fn on_host_request(&mut self, ctx: &mut EngineCtx, req: &OffloadRequest) -> Vec<NicAction> {
        assert!(!self.called, "duplicate host request");
        self.called = true;
        self.own = Some(req.payload.clone());
        self.proceed(ctx)
    }

    fn on_packet(&mut self, ctx: &mut EngineCtx, pkt: &CollPacket) -> Vec<NicAction> {
        match pkt.msg_type {
            MsgType::Data => {
                assert_eq!(pkt.rank as usize, self.rank - 1, "seq data must come from j-1");
                assert!(
                    self.upstream.is_none(),
                    "sequential single-buffer overflow at rank {} — ACK protocol violated",
                    self.rank
                );
                self.upstream = Some(pkt.payload.clone());
                self.proceed(ctx)
            }
            MsgType::Ack => {
                assert_eq!(pkt.rank as usize, self.rank + 1, "ack must come from j+1");
                self.got_ack = true;
                self.proceed(ctx)
            }
            other => panic!("seq engine got unexpected {other:?}"),
        }
    }

    fn done(&self) -> bool {
        // all protocol obligations discharged:
        //  - result delivered to the host
        //  - downstream released us (or we are the tail / ack disabled)
        //  - upstream acked (or we are the head)
        self.delivered
            && (self.is_head() || self.sent_ack)
            && (self.is_tail() || self.got_ack || !self.ack_enabled)
    }

    fn algo(&self) -> AlgoType {
        AlgoType::Sequential
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::testutil::Harness;
    use crate::packet::{AlgoType, CollType};

    fn contributions(p: usize) -> Vec<Vec<i32>> {
        (0..p).map(|r| vec![r as i32 + 1, 10 * (r as i32 + 1)]).collect()
    }

    #[test]
    fn scan_in_order_8() {
        let mut h = Harness::new(AlgoType::Sequential, 8, CollType::Scan, false);
        let c = contributions(8);
        h.run_and_check(&c, &(0..8).collect::<Vec<_>>());
    }

    #[test]
    fn scan_reverse_call_order() {
        // every rank calls before rank 0 does: all partials flow late.
        let mut h = Harness::new(AlgoType::Sequential, 8, CollType::Scan, false);
        let c = contributions(8);
        h.run_and_check(&c, &(0..8).rev().collect::<Vec<_>>());
    }

    #[test]
    fn scan_two_ranks() {
        let mut h = Harness::new(AlgoType::Sequential, 2, CollType::Scan, false);
        h.run_and_check(&contributions(2), &[1, 0]);
    }

    #[test]
    fn exscan_8() {
        let mut h = Harness::new(AlgoType::Sequential, 8, CollType::Exscan, false);
        h.run_and_check(&contributions(8), &(0..8).collect::<Vec<_>>());
    }

    #[test]
    fn non_power_of_two_p() {
        // sequential has no power-of-two requirement
        let mut h = Harness::new(AlgoType::Sequential, 5, CollType::Scan, false);
        h.run_and_check(&contributions(5), &[3, 0, 4, 1, 2]);
    }

    #[test]
    fn ack_releases_upstream_before_tail_finishes() {
        // rank 0 must be delivered as soon as rank 1 acks, even if the
        // tail never gets to run: call only ranks 0 and 1 of 3.
        let mut h = Harness::new(AlgoType::Sequential, 3, CollType::Scan, false);
        let c = contributions(3);
        h.call(0, crate::data::Payload::from_i32(&c[0]));
        h.drain();
        assert!(h.results[0].is_none(), "rank 0 must wait for rank 1's ack");
        h.call(1, crate::data::Payload::from_i32(&c[1]));
        h.drain();
        assert!(h.results[0].is_some(), "rank 1's ack releases rank 0");
        assert!(h.results[1].is_none(), "rank 1 still waits for rank 2");
    }
}
