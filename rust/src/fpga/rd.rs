//! Recursive-doubling scan state machine (paper SSIII-C).
//!
//! log2(p) steps; at step k rank j exchanges its running *block partial*
//! with partner j ^ 2^k.  Incoming partials from lower-ranked partners
//! also fold into the prefix result; higher-ranked partners only feed the
//! block partial.
//!
//! The multicast optimization (Fig. 3): when rank j arrives late — its
//! partner's step-k data is already buffered when the host request shows
//! up — the reply to the partner and the step-k+1 message to the next
//! partner are the *same* cumulative payload.  The engine then emits ONE
//! CumTagged multicast covering both, tagged with the covered rank range.
//! A receiver whose rank falls inside the tag range reconstructs its
//! partner's raw block by inverse-subtracting its own cached partial
//! ("subtraction is inverse of addition"), which is why the paper limits
//! the optimization to MPI_INT / MPI_SUM.

use std::collections::HashMap;

use crate::data::Payload;
use crate::net::Rank;
use crate::packet::{AlgoType, CollPacket, CollType, MsgType};
use crate::sim::OffloadRequest;
use crate::util::log2;

use super::engine::{CollEngine, EngineCtx, NicAction};

pub struct RdEngine {
    rank: Rank,
    logp: u16,
    coll: CollType,
    multicast_opt: bool,

    called: bool,
    /// Next step to complete.
    step: u16,
    /// Running block partial; before step k it covers the 2^k-aligned
    /// block containing `rank`.
    partial: Option<Payload>,
    /// Inclusive prefix accumulator (starts at own contribution).
    recv_inc: Option<Payload>,
    /// Exclusive prefix accumulator (identity until a lower block folds).
    recv_exc: Option<Payload>,
    /// Our step-k message already sent (directly or covered by an earlier
    /// multicast).
    sent: Vec<bool>,
    /// Buffered raw partner data per step (future steps / early arrivals).
    inbox: HashMap<u16, Payload>,
    /// Buffered in-range CumTagged payloads we could not derive yet
    /// (we had not called when they arrived), per step.
    cum_inbox: HashMap<u16, Payload>,
    delivered: bool,
    /// Number of multicast sends actually taken (optimization metric).
    pub multicasts: u32,
}

impl RdEngine {
    pub fn new(rank: Rank, p: usize, coll: CollType, multicast_opt: bool) -> RdEngine {
        assert!(crate::util::is_pow2(p), "recursive doubling needs power-of-two ranks");
        let logp = log2(p) as u16;
        RdEngine {
            rank,
            logp,
            coll,
            multicast_opt,
            called: false,
            step: 0,
            partial: None,
            recv_inc: None,
            recv_exc: None,
            sent: vec![false; logp as usize],
            inbox: HashMap::new(),
            cum_inbox: HashMap::new(),
            delivered: false,
            multicasts: 0,
        }
    }

    fn partner(&self, k: u16) -> Rank {
        self.rank ^ (1usize << k)
    }

    /// Fold partner data for step k into prefix + partial state.  All
    /// three accumulators fold in place (allocation-free in steady state;
    /// operand order preserved bit-for-bit).
    fn fold_step(&mut self, ctx: &mut EngineCtx, k: u16, incoming: Payload) {
        let partner = self.partner(k);
        let mut partial = self.partial.take().unwrap();
        if partner < self.rank {
            // partner's block sits immediately below ours: it extends both
            // the prefix accumulators and the block partial from the left.
            let mut inc = self.recv_inc.take().unwrap();
            ctx.combine_into_rev(&mut inc, &incoming);
            self.recv_inc = Some(inc);
            self.recv_exc = Some(match self.recv_exc.take() {
                Some(mut exc) => {
                    ctx.combine_into_rev(&mut exc, &incoming);
                    exc
                }
                None => incoming.clone(),
            });
            ctx.combine_into_rev(&mut partial, &incoming);
        } else {
            ctx.combine_into(&mut partial, &incoming);
        }
        self.partial = Some(partial);
        self.step = k + 1;
    }

    /// The 2^(k+1)-aligned rank range the post-step-k partial covers.
    fn covered_range(&self, k: u16) -> (u16, u16) {
        let size = 1usize << (k + 1);
        let lo = self.rank & !(size - 1);
        (lo as u16, (lo + size - 1) as u16)
    }

    /// Advance as far as buffered inputs allow.
    fn advance(&mut self, ctx: &mut EngineCtx) -> Vec<NicAction> {
        let mut out = Vec::new();
        if !self.called {
            return out;
        }
        while self.step < self.logp {
            let k = self.step;
            // resolve a deferred in-range CumTagged now that we can derive
            if let Some(cum) = self.cum_inbox.remove(&k) {
                let own_partial = self.partial.as_ref().unwrap();
                let derived = ctx.derive(&cum, own_partial);
                assert!(
                    self.inbox.insert(k, derived).is_none(),
                    "both raw and cum data for step {k}"
                );
            }

            let have_incoming = self.inbox.contains_key(&k);
            if !self.sent[k as usize] {
                let partial = self.partial.clone().unwrap();
                let can_multicast = self.multicast_opt
                    && have_incoming
                    && k + 1 < self.logp
                    && ctx.op.invertible_for(partial.dtype());
                if can_multicast {
                    // late-rank path: fold first, one multicast covers the
                    // reply to partner k AND the step-k+1 message.
                    let incoming = self.inbox.remove(&k).unwrap();
                    self.fold_step(ctx, k, incoming);
                    let cum = self.partial.clone().unwrap();
                    let (lo, hi) = self.covered_range(k);
                    self.sent[k as usize] = true;
                    self.sent[k as usize + 1] = true;
                    self.multicasts += 1;
                    out.push(NicAction::Multicast {
                        dsts: vec![self.partner(k), self.partner(k + 1)],
                        mt: MsgType::CumTagged,
                        step: k,
                        tag: CollPacket::make_tag(lo, hi),
                        payload: cum,
                    });
                    continue;
                }
                self.sent[k as usize] = true;
                out.push(NicAction::Send {
                    dst: self.partner(k),
                    mt: MsgType::Data,
                    step: k,
                    tag: 0,
                    payload: partial,
                });
            }
            match self.inbox.remove(&k) {
                Some(incoming) => self.fold_step(ctx, k, incoming),
                None => break, // wait for the partner
            }
        }
        if self.step == self.logp && !self.delivered {
            self.delivered = true;
            let result = if self.coll.inclusive() {
                self.recv_inc.clone().unwrap()
            } else {
                match &self.recv_exc {
                    Some(exc) => exc.clone(),
                    None => ctx.identity(self.recv_inc.as_ref().unwrap()),
                }
            };
            out.push(NicAction::Deliver { payload: result });
        }
        out
    }
}

impl CollEngine for RdEngine {
    fn on_host_request(&mut self, ctx: &mut EngineCtx, req: &OffloadRequest) -> Vec<NicAction> {
        assert!(!self.called, "duplicate host request");
        self.called = true;
        self.partial = Some(req.payload.clone());
        self.recv_inc = Some(req.payload.clone());
        self.advance(ctx)
    }

    fn on_packet(&mut self, ctx: &mut EngineCtx, pkt: &CollPacket) -> Vec<NicAction> {
        match pkt.msg_type {
            MsgType::Data => {
                assert!(
                    self.inbox.insert(pkt.step, pkt.payload.clone()).is_none(),
                    "duplicate rd data for step {}",
                    pkt.step
                );
                assert!(
                    self.inbox.len() <= self.logp as usize + 1,
                    "rd inbox overflow at rank {}",
                    self.rank
                );
                self.advance(ctx)
            }
            MsgType::CumTagged => {
                let (lo, hi) = pkt.tag_range();
                let in_range = (lo..=hi).contains(&(self.rank as u16));
                if in_range {
                    // the cumulative covers our own block too: recover the
                    // partner's raw block by inverse subtraction.  That
                    // needs our cached partial for this step, so defer if
                    // the host has not called yet.
                    let k = pkt.step;
                    if self.called && self.step == k {
                        let own_partial = self.partial.as_ref().unwrap();
                        let derived = ctx.derive(&pkt.payload, own_partial);
                        assert!(self.inbox.insert(k, derived).is_none());
                    } else {
                        assert!(
                            self.cum_inbox.insert(k, pkt.payload.clone()).is_none(),
                            "duplicate cum data for step {k}"
                        );
                    }
                } else {
                    // disjoint range: this IS the partner's block for the
                    // next stage — size 2^(k+1) means it carries step k+1.
                    let size = (hi - lo + 1) as usize;
                    let k2 = log2(size) as u16;
                    assert_eq!(self.partner(k2) as u16, pkt.rank, "cum from non-partner");
                    assert!(self.inbox.insert(k2, pkt.payload.clone()).is_none());
                }
                self.advance(ctx)
            }
            other => panic!("rd engine got unexpected {other:?}"),
        }
    }

    fn done(&self) -> bool {
        self.delivered
    }

    fn algo(&self) -> AlgoType {
        AlgoType::RecursiveDoubling
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::testutil::Harness;
    use crate::packet::{AlgoType, CollType};

    fn contributions(p: usize) -> Vec<Vec<i32>> {
        (0..p).map(|r| vec![r as i32 + 1, -(r as i32), 100 + r as i32]).collect()
    }

    fn orders(p: usize) -> Vec<Vec<usize>> {
        vec![
            (0..p).collect(),
            (0..p).rev().collect(),
            // interleaved: evens then odds (every pair has a late member)
            (0..p).step_by(2).chain((1..p).step_by(2)).collect(),
        ]
    }

    #[test]
    fn scan_various_orders_and_sizes() {
        for p in [2usize, 4, 8, 16] {
            for order in orders(p) {
                for opt in [false, true] {
                    let mut h = Harness::new(AlgoType::RecursiveDoubling, p, CollType::Scan, opt);
                    h.run_and_check(&contributions(p), &order);
                }
            }
        }
    }

    #[test]
    fn exscan_various_orders() {
        for p in [4usize, 8] {
            for order in orders(p) {
                for opt in [false, true] {
                    let mut h =
                        Harness::new(AlgoType::RecursiveDoubling, p, CollType::Exscan, opt);
                    h.run_and_check(&contributions(p), &order);
                }
            }
        }
    }

    #[test]
    fn late_rank_takes_multicast_path() {
        // Fig. 3b: rank 1 arrives after rank 0's data is already buffered.
        let mut h = Harness::new(AlgoType::RecursiveDoubling, 4, CollType::Scan, true);
        let c = contributions(4);
        h.run_and_check(&c, &[0, 2, 3, 1]);
        // downcast to count multicasts: rank 1 must have used at least one
        let e = &h.engines[1];
        assert_eq!(e.algo(), AlgoType::RecursiveDoubling);
        // correctness was already asserted; the multicast count is checked
        // through the cluster-level ablation bench (frames emitted).
    }

    #[test]
    fn multicast_disabled_still_correct_when_late() {
        let mut h = Harness::new(AlgoType::RecursiveDoubling, 4, CollType::Scan, false);
        h.run_and_check(&contributions(4), &[0, 2, 3, 1]);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        Harness::new(AlgoType::RecursiveDoubling, 6, CollType::Scan, false);
    }
}
