//! MPI_Allreduce / MPI_Barrier offload engines — the "other collective
//! operations" the paper's packet format reserves (SSIII-A enumerates
//! their coll_type codes) and its SSVII plans.
//!
//! Two machines, both directly grounded in the paper's text:
//!
//! - [`TreeAllreduce`] — SSIII-D: "in MPI_Allreduce the accumulated data
//!   is gathered in the root rank and then multicasted to its children."
//!   Up-phase identical to the binomial scan's reduce; the down-phase is
//!   where allreduce differs from scan: the outcome is the SAME for every
//!   rank, so each node drives ONE multicast to all of its children —
//!   the NetFPGA multicast engine the scan down-phase cannot use.
//! - [`RdAllreduce`] — the recursive-doubling butterfly of the authors'
//!   companion work [7] (standard form; the late-rank tree adaptation of
//!   Fig. 2 is [7]'s own contribution and out of scope here).
//!
//! MPI_Barrier is either machine with a zero-element payload (a barrier
//! is an allreduce that carries no data), exactly how the authors' [6]
//! built it.
//!
//! Flow control: no ACKs needed.  Every non-root rank's delivery is gated
//! on a message that causally requires its whole subtree/partner set to
//! have called (the down multicast / the last exchange), so epoch skew is
//! structurally bounded — unlike the scan engines where base-0 ranks
//! complete "for free".

use std::collections::HashMap;

use crate::data::Payload;
use crate::net::Rank;
use crate::packet::{AlgoType, CollPacket, MsgType};
use crate::sim::OffloadRequest;
use crate::util::{is_pow2, log2};

use super::engine::{CollEngine, EngineCtx, NicAction};

// ------------------------------------------------------------ binomial

pub struct TreeAllreduce {
    rank: Rank,
    p: usize,
    /// trailing_ones(rank): number of children.
    t: u32,
    called: bool,
    own: Option<Payload>,
    child_bufs: Vec<Option<Payload>>,
    children_seen: usize,
    /// Reduced block over [rank - 2^t + 1, rank].
    block: Option<Payload>,
    up_sent: bool,
    /// The final total (arrives via the down multicast, or is computed
    /// locally at the root).
    total: Option<Payload>,
    down_sent: bool,
    delivered: bool,
}

impl TreeAllreduce {
    pub fn new(rank: Rank, p: usize) -> TreeAllreduce {
        assert!(is_pow2(p), "binomial allreduce needs power-of-two ranks");
        let t = (rank as u64).trailing_ones();
        TreeAllreduce {
            rank,
            p,
            t,
            called: false,
            own: None,
            child_bufs: vec![None; t as usize],
            children_seen: 0,
            block: None,
            up_sent: false,
            total: None,
            down_sent: false,
            delivered: false,
        }
    }

    fn is_root(&self) -> bool {
        self.rank == self.p - 1
    }

    fn try_complete_up(&mut self, ctx: &mut EngineCtx) -> Vec<NicAction> {
        let mut out = Vec::new();
        if self.block.is_some() || !self.called || self.children_seen != self.child_bufs.len() {
            return out;
        }
        // fold children in rank order (child t-1 covers the lowest ranks);
        // k-way in-place fold: one pooled buffer for the whole chain
        let mut fold: Option<Payload> = None;
        for k in (0..self.t as usize).rev() {
            let c = self.child_bufs[k].clone().unwrap();
            fold = Some(match fold {
                Some(mut f) => {
                    ctx.combine_into(&mut f, &c);
                    f
                }
                None => c,
            });
        }
        let own = self.own.clone().unwrap();
        let block = match fold {
            Some(mut f) => {
                ctx.combine_into(&mut f, &own);
                f
            }
            None => own,
        };
        self.block = Some(block.clone());
        if self.is_root() {
            // root holds the total: turn the tree around
            self.total = Some(block);
            out.extend(self.emit_down_and_deliver());
        } else if !self.up_sent {
            self.up_sent = true;
            out.push(NicAction::Send {
                dst: self.rank + (1usize << self.t),
                mt: MsgType::Data,
                step: self.t as u16,
                tag: 0,
                payload: block,
            });
        }
        out
    }

    /// SSIII-D: the total is identical everywhere, so ONE multicast per
    /// node covers all of its children.
    fn emit_down_and_deliver(&mut self) -> Vec<NicAction> {
        let mut out = Vec::new();
        let total = self.total.clone().unwrap();
        if !self.down_sent {
            self.down_sent = true;
            let children: Vec<Rank> =
                (0..self.t as usize).map(|k| self.rank - (1usize << k)).collect();
            if !children.is_empty() {
                out.push(NicAction::Multicast {
                    dsts: children,
                    mt: MsgType::Down,
                    step: 0,
                    tag: 0,
                    payload: total.clone(),
                });
            }
        }
        if !self.delivered {
            self.delivered = true;
            out.push(NicAction::Deliver { payload: total });
        }
        out
    }
}

impl CollEngine for TreeAllreduce {
    fn on_host_request(&mut self, ctx: &mut EngineCtx, req: &OffloadRequest) -> Vec<NicAction> {
        assert!(!self.called, "duplicate host request");
        self.called = true;
        self.own = Some(req.payload.clone());
        self.try_complete_up(ctx)
    }

    fn on_packet(&mut self, ctx: &mut EngineCtx, pkt: &CollPacket) -> Vec<NicAction> {
        match pkt.msg_type {
            MsgType::Data => {
                let src = pkt.rank as usize;
                let k = pkt.step as usize;
                assert!(k < self.child_bufs.len(), "not my child: rank {src} step {k}");
                assert_eq!(src + (1 << k), self.rank, "child/slot mismatch");
                assert!(self.child_bufs[k].is_none(), "child buffer overrun");
                self.child_bufs[k] = Some(pkt.payload.clone());
                self.children_seen += 1;
                self.try_complete_up(ctx)
            }
            MsgType::Down => {
                assert!(self.total.is_none(), "duplicate down total");
                assert_eq!(
                    pkt.rank as usize,
                    self.rank + (1usize << self.t),
                    "down multicast must come from the parent"
                );
                self.total = Some(pkt.payload.clone());
                self.emit_down_and_deliver()
            }
            other => panic!("tree allreduce got unexpected {other:?}"),
        }
    }

    fn done(&self) -> bool {
        self.delivered && self.down_sent && (self.is_root() || self.up_sent)
    }

    fn algo(&self) -> AlgoType {
        AlgoType::BinomialTree
    }
}

// ----------------------------------------------------- recursive doubling

pub struct RdAllreduce {
    rank: Rank,
    logp: u16,
    called: bool,
    step: u16,
    value: Option<Payload>,
    sent: Vec<bool>,
    inbox: HashMap<u16, Payload>,
    delivered: bool,
}

impl RdAllreduce {
    pub fn new(rank: Rank, p: usize) -> RdAllreduce {
        assert!(is_pow2(p), "recursive doubling needs power-of-two ranks");
        let logp = log2(p) as u16;
        RdAllreduce {
            rank,
            logp,
            called: false,
            step: 0,
            value: None,
            sent: vec![false; logp as usize],
            inbox: HashMap::new(),
            delivered: false,
        }
    }

    fn partner(&self, k: u16) -> Rank {
        self.rank ^ (1usize << k)
    }

    fn advance(&mut self, ctx: &mut EngineCtx) -> Vec<NicAction> {
        let mut out = Vec::new();
        if !self.called {
            return out;
        }
        while self.step < self.logp {
            let k = self.step;
            if !self.sent[k as usize] {
                self.sent[k as usize] = true;
                out.push(NicAction::Send {
                    dst: self.partner(k),
                    mt: MsgType::Data,
                    step: k,
                    tag: 0,
                    payload: self.value.clone().unwrap(),
                });
            }
            let Some(incoming) = self.inbox.remove(&k) else { break };
            let partner = self.partner(k);
            let mut value = self.value.take().unwrap();
            // rank-ordered in-place fold keeps non-commutative ops
            // well-defined (and bit-identical to the allocating path)
            if partner < self.rank {
                ctx.combine_into_rev(&mut value, &incoming);
            } else {
                ctx.combine_into(&mut value, &incoming);
            }
            self.value = Some(value);
            self.step = k + 1;
        }
        if self.step == self.logp && !self.delivered {
            self.delivered = true;
            out.push(NicAction::Deliver { payload: self.value.clone().unwrap() });
        }
        out
    }
}

impl CollEngine for RdAllreduce {
    fn on_host_request(&mut self, ctx: &mut EngineCtx, req: &OffloadRequest) -> Vec<NicAction> {
        assert!(!self.called, "duplicate host request");
        self.called = true;
        self.value = Some(req.payload.clone());
        self.advance(ctx)
    }

    fn on_packet(&mut self, ctx: &mut EngineCtx, pkt: &CollPacket) -> Vec<NicAction> {
        assert_eq!(pkt.msg_type, MsgType::Data, "rd allreduce only exchanges Data");
        assert_eq!(pkt.rank as usize, self.partner(pkt.step), "data from non-partner");
        assert!(self.inbox.insert(pkt.step, pkt.payload.clone()).is_none());
        assert!(self.inbox.len() <= self.logp as usize + 1, "rd allreduce inbox overflow");
        self.advance(ctx)
    }

    fn done(&self) -> bool {
        self.delivered
    }

    fn algo(&self) -> AlgoType {
        AlgoType::RecursiveDoubling
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::testutil::Harness;
    use crate::packet::{AlgoType, CollType};

    fn contributions(p: usize) -> Vec<Vec<i32>> {
        (0..p).map(|r| vec![r as i32 + 1, -(2 * r as i32), 7]).collect()
    }

    fn orders(p: usize) -> Vec<Vec<usize>> {
        vec![
            (0..p).collect(),
            (0..p).rev().collect(),
            (0..p).step_by(2).chain((1..p).step_by(2)).collect(),
        ]
    }

    #[test]
    fn allreduce_both_machines_all_orders() {
        for algo in [AlgoType::BinomialTree, AlgoType::RecursiveDoubling] {
            for p in [2usize, 4, 8, 16] {
                for order in orders(p) {
                    let mut h = Harness::new(algo, p, CollType::Allreduce, false);
                    h.run_and_check(&contributions(p), &order);
                }
            }
        }
    }

    #[test]
    fn barrier_is_zero_payload_allreduce() {
        for algo in [AlgoType::BinomialTree, AlgoType::RecursiveDoubling] {
            let p = 8;
            let empty: Vec<Vec<i32>> = vec![vec![]; p];
            let mut h = Harness::new(algo, p, CollType::Barrier, false);
            h.run_and_check(&empty, &(0..p).rev().collect::<Vec<_>>());
        }
    }

    #[test]
    fn tree_down_phase_is_one_multicast_per_node() {
        // rank 7 (root, p=8) has 3 children: its down phase must be a
        // single Multicast action with 3 destinations — the SSIII-D
        // contrast with scan, which cannot multicast its down phase.
        use crate::data::Payload;
        let mut h = Harness::new(AlgoType::BinomialTree, 8, CollType::Allreduce, false);
        let c = contributions(8);
        for r in 0..8 {
            h.call(r, Payload::from_i32(&c[r]));
        }
        h.drain();
        // correctness implies the multicast fan-out worked; the explicit
        // action-shape assertion lives in the harness-level frame counts
        // (cluster test `allreduce_multicasts_down`).
        for r in 0..8 {
            assert!(h.results[r].is_some());
        }
    }
}
