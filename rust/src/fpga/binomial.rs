//! Binomial-tree scan state machine (paper SSIII-D).
//!
//! Up-phase: rank j receives partials from its trailing_ones(j) children
//! (child k = j - 2^k) into preallocated buffers, folds them with its own
//! contribution into a block covering [j - 2^t + 1, j], and sends the
//! block to parent j + 2^t.  Down-phase: every rank j whose block starts
//! at 0 (j = 2^t - 1, including the root p-1) already has its prefix; it
//! sends the prefix to j + 2^(k-1) for each k <= t (paper's rule
//! "j & (2^k - 1) = 2^k - 1 sends to j + 2^(k-1)").  Receivers combine
//! the incoming prefix with their buffered block, deliver, and cascade
//! their own down-phase sends "back-to-back ... at line rate".
//!
//! Unlike MPI_Allreduce, the outcome differs per rank, so the down-phase
//! cannot use the multicast engine — each down message is a distinct
//! prefix (the paper's SSIII-D observation).
//!
//! FLOW CONTROL: back-to-back offloaded scans have the same hazard the
//! paper's SSIII-B solves for the sequential algorithm — ranks whose
//! prefix needs no network round-trip (rank 0, and every j = 2^t - 1)
//! return immediately and can run arbitrarily many epochs ahead of their
//! parent, overflowing the card's preallocated buffers.  We extend the
//! paper's ACK mechanism to the up-phase: a parent acknowledges each
//! child when it consumes the child's block, and a rank does not return
//! its result to the host until its parent has acknowledged.  For ranks
//! that wait for a down-phase message anyway the ACK arrives strictly
//! earlier (the parent consumes before the root can possibly turn
//! around), so only the "free" base-0 ranks pay — exactly like the
//! sequential ACK the paper accepted.

use crate::data::Payload;
use crate::net::Rank;
use crate::packet::{AlgoType, CollPacket, CollType, MsgType};
use crate::sim::OffloadRequest;

use super::engine::{CollEngine, EngineCtx, NicAction};

pub struct BinomialEngine {
    rank: Rank,
    p: usize,
    coll: CollType,
    /// trailing_ones(rank): number of children / up-phase steps.
    t: u32,
    called: bool,
    own: Option<Payload>,
    /// Preallocated child buffers; slot k holds the block from j - 2^k.
    child_bufs: Vec<Option<Payload>>,
    children_seen: usize,
    /// Fold over child blocks only — covers [j-2^t+1, j-1] (exscan path).
    children_fold: Option<Payload>,
    /// Fold over children + own — covers [j-2^t+1, j].
    block: Option<Payload>,
    up_sent: bool,
    /// Incoming down-phase prefix [0, j - 2^t] (non-base-0 ranks).
    down_in: Option<Payload>,
    /// Final inclusive prefix [0, j].
    prefix: Option<Payload>,
    downs_sent: bool,
    delivered: bool,
    /// Result computed but held back until the parent's ACK (see module
    /// docs on flow control).
    pending_result: Option<Payload>,
    /// Parent consumed our up-block.
    parent_acked: bool,
    acks_sent: bool,
    /// Flow control switch (ablation; default on).
    pub ack_enabled: bool,
}

impl BinomialEngine {
    pub fn new(rank: Rank, p: usize, coll: CollType) -> BinomialEngine {
        assert!(crate::util::is_pow2(p), "binomial tree needs power-of-two ranks");
        let t = (rank as u64).trailing_ones();
        BinomialEngine {
            rank,
            p,
            coll,
            t,
            called: false,
            own: None,
            child_bufs: vec![None; t as usize],
            children_seen: 0,
            children_fold: None,
            block: None,
            up_sent: false,
            down_in: None,
            prefix: None,
            downs_sent: false,
            delivered: false,
            pending_result: None,
            parent_acked: false,
            acks_sent: false,
            ack_enabled: true,
        }
    }

    fn is_root(&self) -> bool {
        self.rank == self.p - 1
    }

    /// Block starts at rank 0 <=> j == 2^t - 1: prefix known at up-phase
    /// completion (root included, since p-1 is all ones for 2^k ranks).
    fn base_is_zero(&self) -> bool {
        self.rank + 1 == (1usize << self.t)
    }

    fn try_complete_up(&mut self, ctx: &mut EngineCtx) -> Vec<NicAction> {
        let mut out = Vec::new();
        if self.block.is_some()
            || !self.called
            || self.children_seen != self.child_bufs.len()
        {
            return out;
        }
        // fold children in rank order: child t-1 covers the lowest ranks.
        // k-way in-place fold: one pooled buffer for the whole chain.
        let mut fold: Option<Payload> = None;
        for k in (0..self.t as usize).rev() {
            let c = self.child_bufs[k].clone().unwrap();
            fold = Some(match fold {
                Some(mut f) => {
                    ctx.combine_into(&mut f, &c);
                    f
                }
                None => c,
            });
        }
        self.children_fold = fold.clone();
        let own = self.own.clone().unwrap();
        let block = match fold {
            Some(mut f) => {
                ctx.combine_into(&mut f, &own);
                f
            }
            None => own,
        };
        self.block = Some(block.clone());
        if !self.acks_sent {
            // release every child: its block is consumed, its buffer free
            self.acks_sent = true;
            if self.ack_enabled {
                for k in 0..self.t as u16 {
                    out.push(NicAction::Send {
                        dst: self.rank - (1usize << k),
                        mt: MsgType::Ack,
                        step: k,
                        tag: 0,
                        payload: Payload::identity(block.dtype(), ctx.op, 0),
                    });
                }
            }
        }
        if !self.is_root() && !self.up_sent {
            self.up_sent = true;
            let parent = self.rank + (1usize << self.t);
            debug_assert!(parent < self.p);
            out.push(NicAction::Send {
                dst: parent,
                mt: MsgType::Data,
                step: self.t as u16,
                tag: 0,
                payload: block,
            });
        }
        if self.base_is_zero() {
            self.prefix = Some(self.block.clone().unwrap());
            out.extend(self.emit_down_and_deliver(ctx));
        } else if self.down_in.is_some() {
            // the down prefix raced ahead of our up completion
            out.extend(self.absorb_down(ctx));
        }
        out
    }

    fn absorb_down(&mut self, ctx: &mut EngineCtx) -> Vec<NicAction> {
        if self.prefix.is_some() || self.block.is_none() || self.down_in.is_none() {
            return Vec::new();
        }
        let down = self.down_in.clone().unwrap();
        // prefix = down (op) block, folded in place
        let mut prefix = self.block.clone().unwrap();
        ctx.combine_into_rev(&mut prefix, &down);
        self.prefix = Some(prefix);
        self.emit_down_and_deliver(ctx)
    }

    /// Once the prefix is known: deliver to the host and cascade the
    /// down-phase sends (generated back-to-back at the hardware).
    fn emit_down_and_deliver(&mut self, ctx: &mut EngineCtx) -> Vec<NicAction> {
        let mut out = Vec::new();
        let prefix = self.prefix.clone().unwrap();
        if !self.downs_sent {
            self.downs_sent = true;
            // paper's rule: for k with j&(2^k-1)==2^k-1, send to j+2^(k-1)
            for k in (1..=self.t as u16).rev() {
                let target = self.rank + (1usize << (k - 1));
                if target < self.p {
                    out.push(NicAction::Send {
                        dst: target,
                        mt: MsgType::Down,
                        step: k,
                        tag: 0,
                        payload: prefix.clone(),
                    });
                }
            }
        }
        if !self.delivered && self.pending_result.is_none() {
            let result = if self.coll.inclusive() {
                prefix
            } else {
                // exclusive: prefix below own = down_in (+ children blocks)
                match (&self.down_in, &self.children_fold) {
                    (Some(d), Some(cf)) => {
                        let mut r = cf.clone();
                        ctx.combine_into_rev(&mut r, d); // r = d (op) cf
                        r
                    }
                    (Some(d), None) => d.clone(),
                    (None, Some(cf)) => cf.clone(),
                    (None, None) => ctx.identity(self.own.as_ref().unwrap()),
                }
            };
            self.pending_result = Some(result);
        }
        out.extend(self.try_deliver());
        out
    }

    /// Deliver the held result once the parent has released us.
    fn try_deliver(&mut self) -> Vec<NicAction> {
        let released = self.is_root() || self.parent_acked || !self.ack_enabled;
        if self.delivered || !released {
            return Vec::new();
        }
        match self.pending_result.take() {
            Some(result) => {
                self.delivered = true;
                vec![NicAction::Deliver { payload: result }]
            }
            None => Vec::new(),
        }
    }
}

impl CollEngine for BinomialEngine {
    fn on_host_request(&mut self, ctx: &mut EngineCtx, req: &OffloadRequest) -> Vec<NicAction> {
        assert!(!self.called, "duplicate host request");
        self.called = true;
        self.own = Some(req.payload.clone());
        self.try_complete_up(ctx)
    }

    fn on_packet(&mut self, ctx: &mut EngineCtx, pkt: &CollPacket) -> Vec<NicAction> {
        match pkt.msg_type {
            MsgType::Data => {
                // up-phase child block: sender j - 2^k at slot k
                let src = pkt.rank as usize;
                let k = pkt.step as usize;
                assert!(k < self.child_bufs.len(), "not my child: rank {src} step {k}");
                assert_eq!(src + (1 << k), self.rank, "child/slot mismatch");
                assert!(
                    self.child_bufs[k].is_none(),
                    "binomial child buffer {k} overrun at rank {}",
                    self.rank
                );
                self.child_bufs[k] = Some(pkt.payload.clone());
                self.children_seen += 1;
                self.try_complete_up(ctx)
            }
            MsgType::Down => {
                assert!(self.down_in.is_none(), "duplicate down prefix");
                assert!(!self.base_is_zero(), "base-0 rank got a down message");
                self.down_in = Some(pkt.payload.clone());
                self.absorb_down(ctx)
            }
            MsgType::Ack => {
                // parent consumed our up-block: we may return to the host
                assert_eq!(
                    pkt.rank as usize,
                    self.rank + (1usize << self.t),
                    "ack must come from the parent"
                );
                self.parent_acked = true;
                self.try_deliver()
            }
            other => panic!("binomial engine got unexpected {other:?}"),
        }
    }

    fn done(&self) -> bool {
        self.delivered
            && self.downs_sent
            && (self.is_root() || self.up_sent)
            && (self.t == 0 || self.acks_sent)
    }

    fn algo(&self) -> AlgoType {
        AlgoType::BinomialTree
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::testutil::Harness;
    use crate::packet::{AlgoType, CollType};

    fn contributions(p: usize) -> Vec<Vec<i32>> {
        (0..p).map(|r| vec![2 * r as i32 + 1, -(r as i32) - 5]).collect()
    }

    fn orders(p: usize) -> Vec<Vec<usize>> {
        vec![
            (0..p).collect(),
            (0..p).rev().collect(),
            (0..p).step_by(2).chain((1..p).step_by(2)).collect(),
        ]
    }

    #[test]
    fn scan_various_orders_and_sizes() {
        for p in [2usize, 4, 8, 16, 32] {
            for order in orders(p) {
                let mut h = Harness::new(AlgoType::BinomialTree, p, CollType::Scan, false);
                h.run_and_check(&contributions(p), &order);
            }
        }
    }

    #[test]
    fn exscan_various_orders() {
        for p in [2usize, 4, 8, 16] {
            for order in orders(p) {
                let mut h = Harness::new(AlgoType::BinomialTree, p, CollType::Exscan, false);
                h.run_and_check(&contributions(p), &order);
            }
        }
    }

    #[test]
    fn root_receives_all_children() {
        // in p=8, rank 7 has children 6 (k=0), 5 (k=1), 3 (k=2)
        let h = Harness::new(AlgoType::BinomialTree, 8, CollType::Scan, false);
        assert_eq!(h.engines[7].algo(), AlgoType::BinomialTree);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        Harness::new(AlgoType::BinomialTree, 6, CollType::Scan, false);
    }
}
