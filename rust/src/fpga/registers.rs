//! The NetFPGA's timing registers (paper SSIV):
//!
//! "The NetFPGA has 125MHz clock which enables us to create an 8ns
//! resolution timer.  We initialize a 64-bit counter once the design is
//! loaded ... We also create two 64-bit timestamp registers to track the
//! offload and release time of the collective operations."
//!
//! The elapsed (release - offload) time is attached to the Result packet —
//! that is the quantity of Figs. 6/7.

use std::collections::HashMap;

use crate::sim::SimTime;

#[derive(Debug, Default)]
pub struct Registers {
    /// Offload timestamp (cycles) per in-flight epoch.
    offload_cycles: HashMap<u16, u64>,
}

impl Registers {
    pub fn new() -> Self {
        Registers::default()
    }

    /// The free-running 64-bit cycle counter: virtual ns / 8 (125 MHz).
    /// Truncation to cycle boundaries is the hardware's 8 ns resolution.
    pub fn cycles(now: SimTime) -> u64 {
        now.as_ns() / 8
    }

    /// Record the offload timestamp: the initial HostRequest packet
    /// arrived from the local host.
    pub fn stamp_offload(&mut self, epoch: u16, now: SimTime) {
        self.offload_cycles.insert(epoch, Self::cycles(now));
    }

    /// Record the release timestamp (final outcome sent to the host) and
    /// return the elapsed time in ns, quantized to 8 ns cycles like the
    /// hardware would report.  Returns 0 if offload was never stamped
    /// (defensive: a result without a request is a model bug upstream).
    pub fn stamp_release(&mut self, epoch: u16, now: SimTime) -> u64 {
        match self.offload_cycles.remove(&epoch) {
            Some(start) => (Self::cycles(now).saturating_sub(start)) * 8,
            None => 0,
        }
    }

    /// In-flight collective count (for buffer-limit assertions).
    pub fn in_flight(&self) -> usize {
        self.offload_cycles.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_is_cycle_quantized() {
        let mut r = Registers::new();
        r.stamp_offload(0, SimTime::ns(100)); // cycle 12
        let e = r.stamp_release(0, SimTime::ns(1000)); // cycle 125
        assert_eq!(e, (125 - 12) * 8);
    }

    #[test]
    fn epochs_tracked_independently() {
        let mut r = Registers::new();
        r.stamp_offload(1, SimTime::ns(0));
        r.stamp_offload(2, SimTime::ns(800));
        assert_eq!(r.in_flight(), 2);
        assert_eq!(r.stamp_release(2, SimTime::ns(1600)), 800);
        assert_eq!(r.stamp_release(1, SimTime::ns(1600)), 1600);
        assert_eq!(r.in_flight(), 0);
    }

    #[test]
    fn release_without_offload_is_zero() {
        let mut r = Registers::new();
        assert_eq!(r.stamp_release(9, SimTime::ns(500)), 0);
    }
}
