//! Per-card state: output-port FIFOs, the per-epoch engine table, the
//! fragment reassembler, timing registers and traffic counters.
//!
//! The engine table is the (comm_id, collective_state) store the paper's
//! SSVI sketches: keyed by epoch (the low half of comm_id), instances
//! created on demand and retired on completion, with a hard capacity that
//! models the card's limited resources.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::net::{Frame, PortNo, Rank};
use crate::packet::CollPacket;
use crate::sim::{OffloadRequest, SimTime};

use super::engine::CollEngine;
use super::reassembly::Reassembler;
use super::registers::Registers;

/// Default cap on simultaneous collective state machines per card.  The
/// sequential ACK protocol guarantees <= 2 live epochs; recursive
/// doubling / binomial pipelining stays within a handful.  Exceeding this
/// means flow control is broken, and the card would have dropped packets.
pub const MAX_LIVE_ENGINES: usize = 8;

/// Reassembly budget: in-progress multi-fragment messages per card.
pub const MAX_REASM_MSGS: usize = 32;

/// One reliable frame awaiting its end-to-end ack: the frame itself (so
/// the card can replay it bit-identically), how often it has been
/// resent, and when the original copy first left the card (so recovery
/// latency can be charged once the ack finally lands).
pub struct PendingTx {
    pub frame: Frame,
    pub retries: u32,
    pub first_send: SimTime,
}

/// One parked handler activation: the input that would have run had a
/// handler processing unit been free, plus when it arrived (so the wait
/// can be charged as queueing delay when it finally runs).
pub struct HpuJob {
    pub epoch: u16,
    pub req: Option<OffloadRequest>,
    pub pkt: Option<CollPacket>,
    pub arrival: SimTime,
}

/// sPIN-style bounded pool of handler processing units: `units`
/// execution slots running handler activations to completion.  When all
/// are busy, activations park in a per-flow run queue — FIFO within a
/// flow (comm_id order must be preserved), round-robin across flows (no
/// tenant starves another).  `units == 0` means unconstrained: nothing
/// ever parks and the scheduler is never consulted, keeping the
/// pre-HPU event schedule byte-identical.
#[derive(Default)]
pub struct HpuSched {
    pub units: u64,
    pub busy: u64,
    /// Activations queued (lifetime total, for metrics).
    pub queued_total: u64,
    queues: HashMap<u32, VecDeque<HpuJob>>,
    /// Round-robin order over flows with queued work.
    ring: VecDeque<u32>,
}

impl HpuSched {
    /// All units occupied?
    pub fn saturated(&self) -> bool {
        self.units > 0 && self.busy >= self.units
    }

    /// Park one activation on `flow`'s queue.
    pub fn enqueue(&mut self, flow: u32, job: HpuJob) {
        self.queued_total += 1;
        let q = self.queues.entry(flow).or_default();
        if q.is_empty() {
            self.ring.push_back(flow);
        }
        q.push_back(job);
    }

    /// Pop the next runnable activation, round-robin across flows.
    pub fn next(&mut self) -> Option<HpuJob> {
        let flow = self.ring.pop_front()?;
        let q = self.queues.get_mut(&flow).expect("ring entries have queues");
        let job = q.pop_front().expect("ring entries have work");
        if q.is_empty() {
            self.queues.remove(&flow);
        } else {
            self.ring.push_back(flow);
        }
        Some(job)
    }
}

pub struct Nic {
    pub rank: Rank,
    /// Per-port output serialization horizon.
    ports_busy: Vec<SimTime>,
    /// Live collective state machines, keyed by comm_id
    /// ((communicator << 16) | epoch) — the paper SSVI's
    /// (comm_ID, collective_state) tuple store.
    pub engines: HashMap<u32, Box<dyn CollEngine>>,
    /// Fragment reassembly keyed (src, msg_type code, step, epoch).
    pub reasm: Reassembler<(Rank, u16, u16, u16)>,
    pub regs: Registers,
    pub frames_tx: u64,
    pub bytes_tx: u64,
    pub frames_forwarded: u64,
    /// High-water mark of simultaneous engines (buffer-pressure metric).
    pub max_live_engines_seen: usize,
    /// Handler processing units (sPIN's bounded execution pool).
    pub hpu: HpuSched,
    /// Reliable frames this card sent that are still awaiting their
    /// end-to-end ack, keyed by transaction id.  Empty unless the run's
    /// fault plan is lossy (txn 0 = reliability layer off).
    pub pending: HashMap<u64, PendingTx>,
    /// Transaction ids this card has already accepted as final
    /// destination (receiver-side dedup: a duplicate is re-acked but
    /// not re-processed).
    pub seen_txns: HashSet<u64>,
    /// Liveness bookkeeping (crash-scheduled runs only): when each peer
    /// rank was last heard from (any frame sourced by it that reached
    /// this card).  Fresh entries suppress redundant probes.
    pub last_heard: HashMap<Rank, SimTime>,
    /// Liveness probes this card originated (metrics / tests).
    pub probes_tx: u64,
    /// Monotonic sequence for probes this card originates.
    pub probe_seq: u64,
}

impl Nic {
    pub fn new(rank: Rank, ports: usize) -> Nic {
        Nic {
            rank,
            ports_busy: vec![SimTime::ZERO; ports],
            engines: HashMap::new(),
            reasm: Reassembler::new(MAX_REASM_MSGS),
            regs: Registers::new(),
            frames_tx: 0,
            bytes_tx: 0,
            frames_forwarded: 0,
            max_live_engines_seen: 0,
            hpu: HpuSched::default(),
            pending: HashMap::new(),
            seen_txns: HashSet::new(),
            last_heard: HashMap::new(),
            probes_tx: 0,
            probe_seq: 0,
        }
    }

    /// Reserve the output port for one frame: returns when transmission
    /// actually starts and when the last bit leaves the card.  `ready_at`
    /// is when the frame is ready to go (engine pipeline exit /
    /// forwarding decision done); transmission starts when both the
    /// frame and the port are ready, so `start - ready_at` is the time
    /// spent queued behind the port FIFO (switch/trunk contention).
    pub fn tx_reserve(&mut self, port: PortNo, ready_at: SimTime, tx_ns: u64) -> (SimTime, SimTime) {
        let p = port as usize;
        assert!(p < self.ports_busy.len(), "port {port} out of range");
        let start = self.ports_busy[p].max(ready_at);
        let end = start + tx_ns;
        self.ports_busy[p] = end;
        self.frames_tx += 1;
        (start, end)
    }

    pub fn note_bytes(&mut self, bytes: usize) {
        self.bytes_tx += bytes as u64;
    }

    /// Track the engine-table high-water mark and enforce the cap.
    pub fn check_engine_pressure(&mut self) {
        self.max_live_engines_seen = self.max_live_engines_seen.max(self.engines.len());
        assert!(
            self.engines.len() <= MAX_LIVE_ENGINES,
            "NIC {} exceeded {} live collective engines — flow control failed",
            self.rank,
            MAX_LIVE_ENGINES
        );
    }

    /// Retire completed engines.
    pub fn gc_engines(&mut self) {
        self.engines.retain(|_, e| !e.done());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn port_fifo_serializes() {
        let mut n = Nic::new(0, 4);
        // two frames ready at the same instant on one port queue up
        let (start1, end1) = n.tx_reserve(1, SimTime::ns(100), 500);
        let (start2, end2) = n.tx_reserve(1, SimTime::ns(100), 500);
        assert_eq!(start1.as_ns(), 100);
        assert_eq!(end1.as_ns(), 600);
        assert_eq!(start2.as_ns(), 600, "second frame queues behind the first");
        assert_eq!(end2.as_ns(), 1100);
        // a different port is independent
        let (start3, end3) = n.tx_reserve(2, SimTime::ns(100), 500);
        assert_eq!(start3.as_ns(), 100);
        assert_eq!(end3.as_ns(), 600);
        assert_eq!(n.frames_tx, 3);
    }

    #[test]
    fn idle_port_starts_at_ready() {
        let mut n = Nic::new(0, 4);
        n.tx_reserve(0, SimTime::ns(0), 100);
        let (start, end) = n.tx_reserve(0, SimTime::ns(10_000), 100);
        assert_eq!(start.as_ns(), 10_000, "idle port does not queue");
        assert_eq!(end.as_ns(), 10_100, "idle port does not delay");
    }

    #[test]
    #[should_panic]
    fn bad_port_panics() {
        let mut n = Nic::new(0, 2);
        n.tx_reserve(5, SimTime::ZERO, 1);
    }

    #[test]
    fn hpu_queue_is_fifo_within_flow_round_robin_across() {
        let mut s = HpuSched { units: 1, ..Default::default() };
        let job = |epoch| HpuJob { epoch, req: None, pkt: None, arrival: SimTime::ZERO };
        // flow A gets two jobs, then flow B gets two
        s.enqueue(0xA, job(1));
        s.enqueue(0xA, job(2));
        s.enqueue(0xB, job(3));
        s.enqueue(0xB, job(4));
        assert_eq!(s.queued_total, 4);
        let order: Vec<u16> = std::iter::from_fn(|| s.next().map(|j| j.epoch)).collect();
        // round-robin across flows, FIFO within each
        assert_eq!(order, vec![1, 3, 2, 4]);
        assert!(s.next().is_none());
    }

    #[test]
    fn hpu_unconstrained_never_saturates() {
        let mut s = HpuSched::default();
        assert!(!s.saturated());
        s.busy = 10_000;
        assert!(!s.saturated(), "units == 0 means no constraint");
        let c = HpuSched { units: 2, busy: 2, ..Default::default() };
        assert!(c.saturated());
    }
}
