//! Per-card state: output-port FIFOs, the per-epoch engine table, the
//! fragment reassembler, timing registers and traffic counters.
//!
//! The engine table is the (comm_id, collective_state) store the paper's
//! SSVI sketches: keyed by epoch (the low half of comm_id), instances
//! created on demand and retired on completion, with a hard capacity that
//! models the card's limited resources.

use std::collections::HashMap;

use crate::net::{PortNo, Rank};
use crate::sim::SimTime;

use super::engine::CollEngine;
use super::reassembly::Reassembler;
use super::registers::Registers;

/// Default cap on simultaneous collective state machines per card.  The
/// sequential ACK protocol guarantees <= 2 live epochs; recursive
/// doubling / binomial pipelining stays within a handful.  Exceeding this
/// means flow control is broken, and the card would have dropped packets.
pub const MAX_LIVE_ENGINES: usize = 8;

/// Reassembly budget: in-progress multi-fragment messages per card.
pub const MAX_REASM_MSGS: usize = 32;

pub struct Nic {
    pub rank: Rank,
    /// Per-port output serialization horizon.
    ports_busy: Vec<SimTime>,
    /// Live collective state machines, keyed by comm_id
    /// ((communicator << 16) | epoch) — the paper SSVI's
    /// (comm_ID, collective_state) tuple store.
    pub engines: HashMap<u32, Box<dyn CollEngine>>,
    /// Fragment reassembly keyed (src, msg_type code, step, epoch).
    pub reasm: Reassembler<(Rank, u16, u16, u16)>,
    pub regs: Registers,
    pub frames_tx: u64,
    pub bytes_tx: u64,
    pub frames_forwarded: u64,
    /// High-water mark of simultaneous engines (buffer-pressure metric).
    pub max_live_engines_seen: usize,
}

impl Nic {
    pub fn new(rank: Rank, ports: usize) -> Nic {
        Nic {
            rank,
            ports_busy: vec![SimTime::ZERO; ports],
            engines: HashMap::new(),
            reasm: Reassembler::new(MAX_REASM_MSGS),
            regs: Registers::new(),
            frames_tx: 0,
            bytes_tx: 0,
            frames_forwarded: 0,
            max_live_engines_seen: 0,
        }
    }

    /// Reserve the output port for one frame: returns the moment the last
    /// bit leaves the card.  `ready_at` is when the frame is ready to go
    /// (engine pipeline exit / forwarding decision done); transmission
    /// starts when both the frame and the port are ready.
    pub fn tx_reserve(&mut self, port: PortNo, ready_at: SimTime, tx_ns: u64) -> SimTime {
        let p = port as usize;
        assert!(p < self.ports_busy.len(), "port {port} out of range");
        let start = self.ports_busy[p].max(ready_at);
        let end = start + tx_ns;
        self.ports_busy[p] = end;
        self.frames_tx += 1;
        end
    }

    pub fn note_bytes(&mut self, bytes: usize) {
        self.bytes_tx += bytes as u64;
    }

    /// Track the engine-table high-water mark and enforce the cap.
    pub fn check_engine_pressure(&mut self) {
        self.max_live_engines_seen = self.max_live_engines_seen.max(self.engines.len());
        assert!(
            self.engines.len() <= MAX_LIVE_ENGINES,
            "NIC {} exceeded {} live collective engines — flow control failed",
            self.rank,
            MAX_LIVE_ENGINES
        );
    }

    /// Retire completed engines.
    pub fn gc_engines(&mut self) {
        self.engines.retain(|_, e| !e.done());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn port_fifo_serializes() {
        let mut n = Nic::new(0, 4);
        // two frames ready at the same instant on one port queue up
        let end1 = n.tx_reserve(1, SimTime::ns(100), 500);
        let end2 = n.tx_reserve(1, SimTime::ns(100), 500);
        assert_eq!(end1.as_ns(), 600);
        assert_eq!(end2.as_ns(), 1100);
        // a different port is independent
        let end3 = n.tx_reserve(2, SimTime::ns(100), 500);
        assert_eq!(end3.as_ns(), 600);
        assert_eq!(n.frames_tx, 3);
    }

    #[test]
    fn idle_port_starts_at_ready() {
        let mut n = Nic::new(0, 4);
        n.tx_reserve(0, SimTime::ns(0), 100);
        let end = n.tx_reserve(0, SimTime::ns(10_000), 100);
        assert_eq!(end.as_ns(), 10_100, "idle port does not delay");
    }

    #[test]
    #[should_panic]
    fn bad_port_panics() {
        let mut n = Nic::new(0, 2);
        n.tx_reserve(5, SimTime::ZERO, 1);
    }
}
