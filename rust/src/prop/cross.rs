//! Cross-validation property: for random (p <= 32, algorithm, Scan or
//! Exscan, op, dtype, topology preset), the software path, the offload
//! path and the `oracle_prefix` left fold must agree elementwise on
//! every rank.
//!
//! This triangulates the three implementations against each other over
//! the whole new topology space: a cost-model bug can shift latencies
//! without tripping this, but any *semantic* divergence — a wrong fold
//! order, a dropped fragment on a multi-hop route, a switch misdelivery —
//! breaks the agreement somewhere in the random space.

use std::rc::Rc;

use crate::cluster::Cluster;
use crate::config::{EngineKind, ExecPath, ExpConfig};
use crate::data::{Dtype, Op, Payload};
use crate::packet::{AlgoType, CollType};
use crate::prop::{choose, for_each_case, vec_i32};
use crate::runtime::engine::oracle_prefix;
use crate::runtime::{make_engine, Compute};
use crate::sim::SplitMix64;

/// Random experiment: cluster size, algorithm, collective flavor,
/// op x dtype, topology preset — everything the agreement must hold over.
fn random_case(rng: &mut SplitMix64) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.algo = *choose(rng, &AlgoType::ALL);
    cfg.coll = *choose(rng, &[CollType::Scan, CollType::Scan, CollType::Exscan]);
    cfg.p = match cfg.algo {
        AlgoType::Sequential => *choose(rng, &[2usize, 3, 5, 9, 17, 32]),
        _ => *choose(rng, &[2usize, 4, 8, 16, 32]),
    };
    // any preset valid for this p, hierarchical ones included
    let mut topos: Vec<&str> = vec!["auto", "chain", "star:3", "fattree"];
    if cfg.p >= 3 {
        topos.push("ring");
    }
    if crate::util::is_pow2(cfg.p) {
        topos.push("hypercube");
    }
    cfg.topology = choose(rng, &topos).to_string();
    cfg.dtype = *choose(rng, &Dtype::ALL);
    cfg.op = loop {
        let op = *choose(rng, &Op::ALL);
        if op.valid_for(cfg.dtype) {
            break op;
        }
    };
    let elems = *choose(rng, &[1usize, 5, 33]);
    cfg.msg_bytes = elems * cfg.dtype.size();
    cfg.seed = rng.next_u64();
    cfg.cost.start_jitter_ns = *choose(rng, &[0u64, 5_000, 100_000]);
    cfg.verify = false; // the TEST does the comparing, not the cluster
    cfg
}

/// One contribution per rank, well-conditioned for the op (products stay
/// near 1.0 so float tolerances hold over 32 ranks).
fn random_contributions(rng: &mut SplitMix64, cfg: &ExpConfig) -> Vec<Payload> {
    let n = cfg.msg_elems();
    (0..cfg.p)
        .map(|_| match cfg.dtype {
            Dtype::I32 => Payload::from_i32(&vec_i32(rng, n, 9)),
            Dtype::F32 => Payload::from_f32(
                &(0..n)
                    .map(|_| {
                        if cfg.op == Op::Prod {
                            0.9 + 0.2 * rng.next_f64() as f32
                        } else {
                            (rng.next_f64() * 8.0 - 4.0) as f32
                        }
                    })
                    .collect::<Vec<_>>(),
            ),
            Dtype::F64 => Payload::from_f64(
                &(0..n)
                    .map(|_| {
                        if cfg.op == Op::Prod {
                            0.9 + 0.2 * rng.next_f64()
                        } else {
                            rng.next_f64() * 8.0 - 4.0
                        }
                    })
                    .collect::<Vec<_>>(),
            ),
        })
        .collect()
}

/// Elementwise agreement: exact for integers, association-order rounding
/// tolerance for floats (the tree algorithms fold in a different order
/// than the oracle's left fold).
fn assert_agree(got: &Payload, want: &Payload, what: &str) {
    assert_eq!(got.dtype(), want.dtype(), "{what}: dtype");
    assert_eq!(got.len(), want.len(), "{what}: length");
    match got.dtype() {
        Dtype::I32 => assert_eq!(got.to_i32(), want.to_i32(), "{what}"),
        Dtype::F32 => {
            for (i, (g, w)) in got.to_f32().iter().zip(want.to_f32().iter()).enumerate() {
                let tol = 1e-4f32.max(w.abs() * 1e-4);
                assert!((g - w).abs() <= tol, "{what} elem {i}: {g} vs {w}");
            }
        }
        Dtype::F64 => {
            for (i, (g, w)) in got.to_f64().iter().zip(want.to_f64().iter()).enumerate() {
                let tol = 1e-10f64.max(w.abs() * 1e-10);
                assert!((g - w).abs() <= tol, "{what} elem {i}: {g} vs {w}");
            }
        }
    }
}

/// Oracle result for rank `r`: exactly the `oracle_prefix` the verify
/// path trusts, inclusive or exclusive per the collective — NOT a local
/// re-derivation that could drift from it.
fn oracle_for_rank(
    compute: &dyn Compute,
    contribs: &[Payload],
    cfg: &ExpConfig,
    r: usize,
) -> Payload {
    oracle_prefix(compute, contribs, cfg.op, cfg.coll.inclusive(), r).expect("oracle")
}

#[test]
fn handler_programs_agree_with_sw_and_oracle() {
    // Every handler-VM program (scan, exscan, allreduce, bcast, barrier)
    // against the software path and the reduction/prefix oracles, over
    // random p <= 32 x dtype x op x topology.  Values must agree
    // (exactly for integers, association-tolerance for floats);
    // latencies are free to differ.
    for_each_case(40, 0x5919_C0DE, |rng| {
        let mut cfg = ExpConfig::default();
        cfg.algo = AlgoType::RecursiveDoubling;
        cfg.coll = *choose(rng, &CollType::HANDLER_SET);
        cfg.p = *choose(rng, &[2usize, 4, 8, 16, 32]);
        let mut topos: Vec<&str> = vec!["auto", "chain", "star:3", "fattree", "hypercube"];
        if cfg.p >= 3 {
            topos.push("ring");
        }
        cfg.topology = choose(rng, &topos).to_string();
        cfg.dtype = *choose(rng, &Dtype::ALL);
        cfg.op = loop {
            let op = *choose(rng, &Op::ALL);
            if op.valid_for(cfg.dtype) {
                break op;
            }
        };
        let elems =
            if cfg.coll == CollType::Barrier { 0 } else { *choose(rng, &[1usize, 5, 33]) };
        cfg.msg_bytes = elems * cfg.dtype.size();
        cfg.seed = rng.next_u64();
        cfg.cost.start_jitter_ns = *choose(rng, &[0u64, 5_000, 100_000]);
        cfg.verify = false; // the TEST does the comparing, not the cluster

        let compute = make_engine(EngineKind::Native, "artifacts");
        let contribs: Vec<Payload> = if cfg.coll == CollType::Barrier {
            (0..cfg.p).map(|_| Payload::identity(cfg.dtype, cfg.op, 0)).collect()
        } else {
            random_contributions(rng, &cfg)
        };

        let run_path = |handler: bool| -> Vec<Payload> {
            let mut c = cfg.clone();
            // handler vs pure software baseline
            c.path = if handler { ExecPath::Handler } else { ExecPath::Sw };
            let (results, _) = Cluster::scan_once(c, Rc::clone(&compute), contribs.clone())
                .unwrap_or_else(|e| {
                    panic!(
                        "handler={handler} {:?} on {} p={}: {e}",
                        cfg.coll, cfg.topology, cfg.p
                    )
                });
            results
        };
        let hd = run_path(true);
        let sw = run_path(false);

        let ctx = format!(
            "handler {:?} {}x{} {:?} {:?} on {}",
            cfg.coll,
            cfg.p,
            cfg.msg_elems(),
            cfg.op,
            cfg.dtype,
            cfg.topology
        );
        for r in 0..cfg.p {
            let want = match cfg.coll {
                CollType::Scan | CollType::Exscan => oracle_for_rank(&*compute, &contribs, &cfg, r),
                CollType::Allreduce => {
                    oracle_prefix(&*compute, &contribs, cfg.op, true, cfg.p - 1).expect("oracle")
                }
                // a barrier carries no data; a bcast carries the root's
                CollType::Barrier => contribs[r].clone(),
                CollType::Bcast => contribs[0].clone(),
                CollType::Reduce => unreachable!(),
            };
            assert_agree(&hd[r], &want, &format!("handler rank {r} ({ctx})"));
            assert_agree(&sw[r], &want, &format!("software rank {r} ({ctx})"));
        }
    });
}

#[test]
fn every_tenant_agrees_with_oracle_under_interference() {
    // Multi-tenant fabrics must not leak values across communicators:
    // for random tenant layouts (mixed paths, background traffic, a
    // bounded HPU pool) every tenant's scan must still bit-match the
    // oracle computed over that tenant's OWN contributions.
    use crate::cluster::Session;
    use crate::config::WorkloadSpec;

    for_each_case(24, 0x7E4A_17, |rng| {
        let n_tenants = *choose(rng, &[2usize, 3, 4]);
        let group = *choose(rng, &[2usize, 4, 8]);
        let p = n_tenants * group;

        let mut fabric = ExpConfig::default().fabric();
        fabric.p = p;
        fabric.topology = if crate::util::is_pow2(p) {
            choose(rng, &["auto", "fattree", "star:3"]).to_string()
        } else {
            choose(rng, &["fattree", "star:3"]).to_string()
        };
        fabric.seed = rng.next_u64();
        fabric.bg_flows = *choose(rng, &[0usize, 2, 4]);
        fabric.bg_msgs = 20;
        fabric.cost.hpus = *choose(rng, &[0u64, 1, 2]);
        fabric.cost.start_jitter_ns = *choose(rng, &[0u64, 5_000]);

        let mut session = Session::on_fabric(fabric.clone())
            .compute(make_engine(EngineKind::Native, "artifacts"));
        let mut specs: Vec<WorkloadSpec> = Vec::new();
        for _ in 0..n_tenants {
            let mut w = WorkloadSpec::default();
            w.path = *choose(rng, &[ExecPath::Sw, ExecPath::Fpga, ExecPath::Handler]);
            w.coll = CollType::Scan;
            w.dtype = Dtype::I32;
            w.msg_bytes = *choose(rng, &[1usize, 5, 16]) * w.dtype.size();
            if w.path != ExecPath::Handler {
                w.algo = *choose(rng, &[AlgoType::Sequential, AlgoType::RecursiveDoubling]);
            }
            session = session.tenant(group, w.clone());
            specs.push(w);
        }

        let compute = make_engine(EngineKind::Native, "artifacts");
        let contribs: Vec<Payload> = (0..p)
            .map(|r| {
                let n = specs[r / group].msg_bytes / Dtype::I32.size();
                Payload::from_i32(&vec_i32(rng, n, 9))
            })
            .collect();

        let (results, metrics) = session.scan_once(contribs.clone()).unwrap();
        assert_eq!(metrics.tenant_host.len(), n_tenants);
        for t in 0..n_tenants {
            let base = t * group;
            let mine = &contribs[base..base + group];
            for r in 0..group {
                let want =
                    oracle_prefix(&*compute, mine, specs[t].op, true, r).expect("oracle");
                assert_agree(
                    &results[base + r],
                    &want,
                    &format!(
                        "tenant {t} rank {r} ({:?} on {} with {} bg flows, {} hpus)",
                        specs[t].path, fabric.topology, fabric.bg_flows, fabric.cost.hpus
                    ),
                );
            }
        }
    });
}

#[test]
fn recovery_agrees_with_oracle_under_loss() {
    // Hostile networks must be value-invisible: under seeded random
    // per-hop loss PLUS a scheduled drop, every execution path's
    // recovered result must still bit-match the lossless oracle — the
    // timeout/retransmit layer may cost time, never change bytes.
    // I32 + Sum keeps the match exact (no float association slack).
    let mut total_retransmits = 0u64;
    let mut total_timeouts = 0u64;
    for_each_case(24, 0xFA17_5EED, |rng| {
        let mut cfg = ExpConfig::default();
        cfg.algo = AlgoType::RecursiveDoubling;
        cfg.coll = *choose(rng, &[CollType::Scan, CollType::Exscan]);
        cfg.path = *choose(rng, &[ExecPath::Sw, ExecPath::Fpga, ExecPath::Handler]);
        cfg.p = *choose(rng, &[2usize, 4, 8, 16, 32]);
        let mut topos: Vec<&str> = vec!["auto", "chain", "star:3", "fattree", "hypercube"];
        if cfg.p >= 3 {
            topos.push("ring");
        }
        cfg.topology = choose(rng, &topos).to_string();
        cfg.dtype = Dtype::I32;
        cfg.op = Op::Sum;
        cfg.msg_bytes = *choose(rng, &[1usize, 5, 33]) * cfg.dtype.size();
        cfg.seed = rng.next_u64();
        cfg.cost.start_jitter_ns = *choose(rng, &[0u64, 5_000]);
        cfg.verify = false; // the TEST does the comparing, not the cluster
        // the hostile part: random loss and one scheduled wildcard drop.
        // max_retries = 8 puts give-up ~loss^9 per txn out of reach, so
        // the fixed-seed run always recovers.
        cfg.loss = *choose(rng, &[0.01, 0.03, 0.08]);
        cfg.cost.max_retries = 8;
        let victim = rng.next_below(cfg.p as u64) as usize;
        cfg.drop_spec = format!("{victim}->*:{}", 1 + rng.next_below(3));

        let compute = make_engine(EngineKind::Native, "artifacts");
        let contribs = random_contributions(rng, &cfg);
        let (results, metrics) =
            Cluster::scan_once(cfg.clone(), Rc::clone(&compute), contribs.clone())
                .unwrap_or_else(|e| {
                    panic!(
                        "{:?}/{:?} on {} p={} loss={} drop={:?}: {e}",
                        cfg.path, cfg.coll, cfg.topology, cfg.p, cfg.loss, cfg.drop_spec
                    )
                });
        total_retransmits += metrics.retransmits;
        total_timeouts += metrics.timeouts_fired;

        let ctx = format!(
            "{:?}/{:?} {}x{} on {} loss={} drop={:?}",
            cfg.path,
            cfg.coll,
            cfg.p,
            cfg.msg_elems(),
            cfg.topology,
            cfg.loss,
            cfg.drop_spec
        );
        for r in 0..cfg.p {
            let want = oracle_for_rank(&*compute, &contribs, &cfg, r);
            assert_agree(&results[r], &want, &format!("recovered rank {r} ({ctx})"));
        }
    });
    // the property is vacuous if nothing was ever dropped — the random
    // space must actually exercise the recovery machinery
    assert!(total_retransmits > 0, "hostile cases never retransmitted");
    assert!(total_timeouts >= total_retransmits, "every resend follows a timer expiry");
}

#[test]
fn survivors_agree_with_shrunk_oracle() {
    // Fail-stop degradation must be ULFM-shrink exact: for random
    // (victim rank, path, algorithm, topology, tenant layout) with the
    // victim fail-stopping before its first contribution, every
    // survivor's result must bit-match the oracle prefix computed over
    // the survivor contributions ONLY, in original rank order — and a
    // tenant the victim does not belong to must keep its full-group
    // values.  The plans are crash-only (no loss), so the detector must
    // never evict a healthy rank.  I32 + Sum keeps the match exact.
    let mut total_crashes = 0u64;
    let mut total_degraded = 0u64;
    for_each_case(20, 0xDEAD_5CAB, |rng| {
        let mut cfg = ExpConfig::default();
        cfg.path = *choose(rng, &[ExecPath::Sw, ExecPath::Fpga, ExecPath::Handler]);
        cfg.algo = if cfg.path == ExecPath::Handler {
            AlgoType::RecursiveDoubling // the handler VM brings its own program
        } else {
            *choose(rng, &[AlgoType::RecursiveDoubling, AlgoType::Sequential])
        };
        cfg.coll = *choose(rng, &[CollType::Scan, CollType::Exscan]);
        cfg.p = *choose(rng, &[4usize, 8, 16]);
        cfg.tenants = if cfg.p >= 8 { *choose(rng, &[1usize, 2]) } else { 1 };
        // rank death never partitions these fabrics: hosts hang off
        // switches (fattree, star) or a >=2-connected host graph
        // (hypercube at p >= 4)
        cfg.topology = choose(rng, &["hypercube", "fattree", "star:3"]).to_string();
        cfg.dtype = Dtype::I32;
        cfg.op = Op::Sum;
        cfg.msg_bytes = *choose(rng, &[1usize, 5, 16]) * cfg.dtype.size();
        cfg.seed = rng.next_u64();
        cfg.cost.start_jitter_ns = *choose(rng, &[0u64, 5_000]);
        cfg.iters = 1; // injection covers epoch 0 only
        cfg.warmup = 0;
        cfg.verify = false; // the TEST does the comparing, not the cluster
        let victim = rng.next_below(cfg.p as u64) as usize;
        cfg.crash_spec = format!("rank:{victim}@epoch:0");

        let compute = make_engine(EngineKind::Native, "artifacts");
        let contribs = random_contributions(rng, &cfg);
        let mut cluster = Cluster::new(cfg.clone(), Rc::clone(&compute));
        cluster.injected = Some(contribs.clone());
        let ctx = format!(
            "{:?}/{:?}/{:?} p={} tenants={} on {} victim={victim}",
            cfg.path, cfg.algo, cfg.coll, cfg.p, cfg.tenants, cfg.topology
        );
        let metrics = cluster.run().unwrap_or_else(|e| panic!("{ctx}: {e}"));
        assert_eq!(metrics.crashes, 1, "the scheduled crash fires ({ctx})");
        assert_eq!(
            metrics.false_suspicions, 0,
            "a crash-only plan must never evict a healthy rank ({ctx})"
        );
        total_crashes += metrics.crashes;
        total_degraded += metrics.degraded_completions;

        let gsize = cfg.p / cfg.tenants;
        for r in 0..cfg.p {
            if r == victim {
                assert!(
                    cluster.results[r].is_none(),
                    "a dead rank returns nothing ({ctx})"
                );
                continue;
            }
            // the survivor group of r's tenant, original rank order —
            // for the victim's tenant this is the shrunk group, for any
            // other tenant it is the full group
            let base = (r / gsize) * gsize;
            let live: Vec<usize> =
                (base..base + gsize).filter(|&g| g != victim).collect();
            let present: Vec<Payload> =
                live.iter().map(|&g| contribs[g].clone()).collect();
            let sidx = live.iter().position(|&g| g == r).expect("r survives");
            let want =
                oracle_prefix(&*compute, &present, cfg.op, cfg.coll.inclusive(), sidx)
                    .expect("survivor oracle");
            let got = cluster.results[r]
                .as_ref()
                .unwrap_or_else(|| panic!("survivor rank {r} never completed ({ctx})"));
            assert_agree(got, &want, &format!("survivor rank {r} ({ctx})"));
        }
    });
    // the random space must actually exercise the degradation machinery
    assert_eq!(total_crashes, 20, "every case schedules exactly one crash");
    assert!(total_degraded > 0, "no case ever completed a shrunk epoch");
}

#[test]
fn software_offload_and_oracle_agree_on_every_rank() {
    for_each_case(40, 0xC0_55A1, |rng| {
        let cfg = random_case(rng);
        let compute = make_engine(EngineKind::Native, "artifacts");
        let contribs = random_contributions(rng, &cfg);

        let run_path = |offloaded: bool| -> Vec<Payload> {
            let mut c = cfg.clone();
            c.path = if offloaded { ExecPath::Fpga } else { ExecPath::Sw };
            let (results, _) = Cluster::scan_once(c, Rc::clone(&compute), contribs.clone())
                .unwrap_or_else(|e| {
                    panic!("{} on {} p={}: {e}", cfg.series_name(), cfg.topology, cfg.p)
                });
            results
        };
        let sw = run_path(false);
        let nf = run_path(true);

        let ctx = format!(
            "{:?}/{:?} {}x{} {:?} {:?} on {}",
            cfg.algo,
            cfg.coll,
            cfg.p,
            cfg.msg_elems(),
            cfg.op,
            cfg.dtype,
            cfg.topology
        );
        for r in 0..cfg.p {
            let want = oracle_for_rank(&*compute, &contribs, &cfg, r);
            assert_agree(&sw[r], &want, &format!("software rank {r} ({ctx})"));
            assert_agree(&nf[r], &want, &format!("offload rank {r} ({ctx})"));
        }
    });
}
