//! Quickcheck-lite: a deterministic property-test runner.
//!
//! The offline build has no proptest crate; this gives the pieces the
//! invariant tests need — a seeded case generator driving a closure N
//! times, with the failing case's seed printed so any failure replays
//! exactly.

use std::sync::Mutex;

use crate::sim::SplitMix64;

#[cfg(test)]
pub mod cross;
#[cfg(test)]
pub mod verifier;

/// Refcount for the global panic-hook suppression: `for_each_case` probes
/// cases under `catch_unwind`, and without this every *expected* failure
/// (should_panic-style probes inside properties) would spew the default
/// hook's backtrace.  Refcounted because the test harness runs many
/// property tests concurrently and the hook is process-global.
static HOOK_SUPPRESSIONS: Mutex<usize> = Mutex::new(0);

/// Whatever hook was installed before suppression began; reinstalled
/// exactly (not the std default) when the last suppressor exits.
type PanicHook = Box<dyn Fn(&std::panic::PanicHookInfo<'_>) + Send + Sync>;
static STASHED_HOOK: Mutex<Option<PanicHook>> = Mutex::new(None);

thread_local! {
    /// file:line:col of this thread's most recent suppressed panic — the
    /// hook normally prints it, so the failure report must recover it.
    /// Thread-local so concurrent property tests can't cross-pollute.
    static LAST_PANIC_LOC: std::cell::RefCell<Option<String>> =
        const { std::cell::RefCell::new(None) };
    /// Probe depth of `for_each_case` on THIS thread.  Only panics on a
    /// probing thread are expected and silenced; a panic on any other
    /// thread (an unrelated test running concurrently, a sweep worker)
    /// is forwarded to the stashed hook so its diagnostics survive.
    static PROBING_HERE: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

fn suppress_panic_hook() {
    PROBING_HERE.with(|d| d.set(d.get() + 1));
    let mut depth = HOOK_SUPPRESSIONS.lock().unwrap_or_else(|e| e.into_inner());
    if *depth == 0 {
        // stash the installed hook and replace it with a recorder that,
        // for probing threads, keeps only the panic location; payloads
        // still propagate through catch_unwind untouched.  take_hook
        // runs before the stash lock is held (see restore for why the
        // two locks must never nest).
        let installed = std::panic::take_hook();
        *STASHED_HOOK.lock().unwrap_or_else(|e| e.into_inner()) = Some(installed);
        std::panic::set_hook(Box::new(|info| {
            if PROBING_HERE.with(|d| d.get()) > 0 {
                let loc = info
                    .location()
                    .map(|l| format!("{}:{}:{}", l.file(), l.line(), l.column()));
                LAST_PANIC_LOC.with(|slot| *slot.borrow_mut() = loc);
            } else if let Some(prev) =
                STASHED_HOOK.lock().unwrap_or_else(|e| e.into_inner()).as_ref()
            {
                prev(info);
            }
        }));
    }
    *depth += 1;
}

fn restore_panic_hook() {
    PROBING_HERE.with(|d| d.set(d.get() - 1));
    let mut depth = HOOK_SUPPRESSIONS.lock().unwrap_or_else(|e| e.into_inner());
    *depth -= 1;
    if *depth == 0 {
        // drop our recorder and put the stashed hook back.  Take the
        // stash in its own statement so the mutex guard is released
        // BEFORE set_hook touches std's hook lock — holding both would
        // deadlock against the recorder, which runs under std's lock and
        // takes STASHED_HOOK.
        drop(std::panic::take_hook());
        let prev = STASHED_HOOK.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(prev) = prev {
            std::panic::set_hook(prev);
        }
    }
}

/// Run `prop` against `n` generated cases.  On panic, the case index and
/// derived seed are attached so the failure is reproducible with
/// `replay_case`.  The default panic hook is suppressed while probing, so
/// expected-failure properties don't spew backtraces; the one failure
/// that matters is re-raised (with the hook restored) after its replay
/// seed is printed.
pub fn for_each_case(n: usize, master_seed: u64, mut prop: impl FnMut(&mut SplitMix64)) {
    let mut master = SplitMix64::new(master_seed);
    suppress_panic_hook();
    let mut failure = None;
    for case in 0..n {
        let case_seed = master.next_u64();
        let mut rng = SplitMix64::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(payload) = result {
            failure = Some((case, case_seed, payload));
            break;
        }
    }
    restore_panic_hook();
    if let Some((case, case_seed, payload)) = failure {
        // the hook was suppressed when the panic fired, so surface the
        // message here — resume_unwind won't invoke the hook either
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "<non-string panic payload>".to_string());
        let loc = LAST_PANIC_LOC
            .with(|slot| slot.borrow_mut().take())
            .map(|l| format!(" at {l}"))
            .unwrap_or_default();
        eprintln!(
            "property failed at case {case}/{n}: {msg}{loc}\n  \
             replay with replay_case({case_seed:#x})"
        );
        std::panic::resume_unwind(payload);
    }
}

/// Re-run a single failing case by its printed seed.
pub fn replay_case(case_seed: u64, mut prop: impl FnMut(&mut SplitMix64)) {
    let mut rng = SplitMix64::new(case_seed);
    prop(&mut rng);
}

/// Pick one element of a slice.
pub fn choose<'a, T>(rng: &mut SplitMix64, items: &'a [T]) -> &'a T {
    &items[rng.next_below(items.len() as u64) as usize]
}

/// Random i32 vector of length `n` with entries in [-bound, bound].
pub fn vec_i32(rng: &mut SplitMix64, n: usize, bound: i64) -> Vec<i32> {
    (0..n).map(|_| rng.range_i64(-bound, bound) as i32).collect()
}

/// A random permutation of 0..n (Fisher-Yates).
pub fn permutation(rng: &mut SplitMix64, n: usize) -> Vec<usize> {
    let mut v: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        v.swap(i, j);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        for_each_case(25, 1, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn deterministic_cases() {
        let mut a = Vec::new();
        for_each_case(5, 42, |rng| a.push(rng.next_u64()));
        let mut b = Vec::new();
        for_each_case(5, 42, |rng| b.push(rng.next_u64()));
        assert_eq!(a, b);
    }

    #[test]
    fn permutation_is_permutation() {
        for_each_case(20, 7, |rng| {
            let n = 1 + rng.next_below(20) as usize;
            let mut p = permutation(rng, n);
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>());
        });
    }

    #[test]
    #[should_panic]
    fn failure_propagates() {
        let mut case = 0;
        for_each_case(10, 3, |_| {
            case += 1;
            assert!(case < 5, "fails at the fifth case");
        });
    }

    /// The failing property used by the replay round-trip below: fails
    /// whenever the case's first draw is divisible by 3.
    fn flaky(rng: &mut SplitMix64) {
        let v = rng.next_u64();
        assert!(v % 3 != 0, "divisible by three: {v}");
    }

    #[test]
    fn replay_round_trips_the_failing_seed() {
        // derive case seeds exactly the way for_each_case does and find
        // the first failing one (P(all 64 pass) = (2/3)^64 ~ 0)
        let mut master = SplitMix64::new(0xC0FFEE);
        let mut seeds = Vec::new();
        let mut failing = None;
        for i in 0..64 {
            let s = master.next_u64();
            seeds.push(s);
            if SplitMix64::new(s).next_u64() % 3 == 0 {
                failing = Some((i, s));
                break;
            }
        }
        let (idx, seed) = failing.expect("a failing case within 64");

        // the runner must fail at exactly that case...
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for_each_case(idx + 10, 0xC0FFEE, flaky)
        }));
        assert!(hit.is_err(), "for_each_case must propagate the failure");

        // ...the printed seed must reproduce it standalone...
        let replayed =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| replay_case(seed, flaky)));
        assert!(replayed.is_err(), "replay_case({seed:#x}) must reproduce the failure");

        // ...and every earlier seed must replay clean.
        for &s in &seeds[..idx] {
            replay_case(s, flaky);
        }
    }

    #[test]
    fn hook_suppression_survives_nesting() {
        // nested runners share the process-global hook; suppression must
        // refcount cleanly and failures must still propagate afterwards
        for_each_case(3, 11, |_| {
            for_each_case(2, 12, |_| {});
        });
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for_each_case(2, 13, |_| panic!("still propagates"))
        }));
        assert!(res.is_err());
    }
}
