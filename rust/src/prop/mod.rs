//! Quickcheck-lite: a deterministic property-test runner.
//!
//! The offline build has no proptest crate; this gives the pieces the
//! invariant tests need — a seeded case generator driving a closure N
//! times, with the failing case's seed printed so any failure replays
//! exactly.

use crate::sim::SplitMix64;

/// Run `prop` against `n` generated cases.  On panic, the case index and
/// derived seed are attached so the failure is reproducible with
/// `replay_case`.
pub fn for_each_case(n: usize, master_seed: u64, mut prop: impl FnMut(&mut SplitMix64)) {
    let mut master = SplitMix64::new(master_seed);
    for case in 0..n {
        let case_seed = master.next_u64();
        let mut rng = SplitMix64::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(payload) = result {
            eprintln!(
                "property failed at case {case}/{n}: replay with replay_case({case_seed:#x})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Re-run a single failing case by its printed seed.
pub fn replay_case(case_seed: u64, mut prop: impl FnMut(&mut SplitMix64)) {
    let mut rng = SplitMix64::new(case_seed);
    prop(&mut rng);
}

/// Pick one element of a slice.
pub fn choose<'a, T>(rng: &mut SplitMix64, items: &'a [T]) -> &'a T {
    &items[rng.next_below(items.len() as u64) as usize]
}

/// Random i32 vector of length `n` with entries in [-bound, bound].
pub fn vec_i32(rng: &mut SplitMix64, n: usize, bound: i64) -> Vec<i32> {
    (0..n).map(|_| rng.range_i64(-bound, bound) as i32).collect()
}

/// A random permutation of 0..n (Fisher-Yates).
pub fn permutation(rng: &mut SplitMix64, n: usize) -> Vec<usize> {
    let mut v: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        v.swap(i, j);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        for_each_case(25, 1, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn deterministic_cases() {
        let mut a = Vec::new();
        for_each_case(5, 42, |rng| a.push(rng.next_u64()));
        let mut b = Vec::new();
        for_each_case(5, 42, |rng| b.push(rng.next_u64()));
        assert_eq!(a, b);
    }

    #[test]
    fn permutation_is_permutation() {
        for_each_case(20, 7, |rng| {
            let n = 1 + rng.next_below(20) as usize;
            let mut p = permutation(rng, n);
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>());
        });
    }

    #[test]
    #[should_panic]
    fn failure_propagates() {
        let mut case = 0;
        for_each_case(10, 3, |_| {
            case += 1;
            assert!(case < 5, "fails at the fifth case");
        });
    }
}
