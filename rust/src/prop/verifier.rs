//! Verifier-vs-VM agreement properties.
//!
//! Two directions, per the verifier's soundness contract:
//!
//! - **accept soundness** — for random *well-formed* programs (drawn
//!   from a generator that respects the machine's invariants by
//!   construction), the verifier must accept, and the VM must then run
//!   random activations without tripping a single dynamic assert;
//!   moreover each activation must retire no more instructions than the
//!   verifier's worst-case bound for its entry;
//! - **reject completeness over known classes** — mutating a
//!   well-formed program with a fault of a known invariant class
//!   (uninit read, scratch OOB, missing halt, budget blowup, dtype
//!   mismatch, unbounded loop, bad target) must make the verifier
//!   reject with exactly that class among its findings.  Every
//!   mutation here is *structural* — the witness is the appended
//!   ill-formed block itself — which is the "reject reason is
//!   structural" arm of the contract.

use crate::config::CostModel;
use crate::data::{Dtype, Op, Payload};
use crate::fpga::engine::EngineCtx;
use crate::nic::verify::{verify, RejectReason};
use crate::nic::vm::{
    run, Activation, AluOp, Asm, EnvVal, Flow, Instr, Program, Reg, MAX_STEPS,
};
use crate::packet::{AlgoType, CollPacket, CollType, MsgType, NodeType};
use crate::prop::{choose, for_each_case};
use crate::runtime::NativeEngine;
use crate::sim::{OffloadRequest, SplitMix64};

/// Register conventions for generated programs.  r5 always holds the
/// pristine packet payload (so the payload pool is never empty), r14 is
/// NEVER written — the uninit-read mutation depends on that.
const POOL: [Reg; 5] = [0, 1, 2, 3, 4];
const PKT: Reg = 5;
const LOOP_I: Reg = 10;
const LOOP_ONE: Reg = 11;
const LOOP_LIM: Reg = 12;
const TMP: Reg = 13;
const NEVER: Reg = 14;

/// Tracks which registers the generated program has definitely
/// initialized (on every path), split by abstract type.
struct Gen {
    asm: Asm,
    ints: Vec<Reg>,
    vecs: Vec<Reg>,
}

impl Gen {
    fn int(&self, rng: &mut SplitMix64) -> Reg {
        *choose(rng, &self.ints)
    }
    fn vec(&self, rng: &mut SplitMix64) -> Reg {
        *choose(rng, &self.vecs)
    }
    /// A destination: overwrite a pool register (possibly changing its
    /// type), keeping the tracking lists consistent.  Never retires the
    /// last initialized integer — operand selection must always have
    /// something to draw from (r5 keeps the payload pool nonempty).
    fn fresh(&mut self, rng: &mut SplitMix64, is_vec: bool) -> Reg {
        let dst = loop {
            let d = *choose(rng, &POOL);
            if is_vec && self.ints.len() == 1 && self.ints[0] == d {
                continue;
            }
            break d;
        };
        self.ints.retain(|&r| r != dst);
        self.vecs.retain(|&r| r != dst);
        if is_vec {
            self.vecs.push(dst);
        } else {
            self.ints.push(dst);
        }
        dst
    }

    /// One safe instruction.  `in_block` suppresses writes to registers
    /// that are not yet initialized on the other path of a branch.
    fn safe_instr(&mut self, rng: &mut SplitMix64, in_block: bool) {
        // inside a conditionally-skipped block only overwrite registers
        // that are ALREADY initialized, so the join stays initialized
        let pick_dst = |g: &mut Gen, rng: &mut SplitMix64, is_vec: bool| -> Option<Reg> {
            if !in_block {
                return Some(g.fresh(rng, is_vec));
            }
            let pool = if is_vec { &g.vecs } else { &g.ints };
            if pool.is_empty() {
                None
            } else {
                Some(*choose(rng, pool))
            }
        };
        match rng.next_below(10) {
            0 | 1 => {
                if let Some(dst) = pick_dst(self, rng, false) {
                    let val = rng.range_i64(-4, 64);
                    self.asm.imm(dst, val);
                }
            }
            2 => {
                if let Some(dst) = pick_dst(self, rng, false) {
                    let what = *choose(
                        rng,
                        &[EnvVal::Rank, EnvVal::P, EnvVal::Inclusive, EnvVal::PktStep,
                          EnvVal::PktSrc, EnvVal::PktKind],
                    );
                    self.asm.env(dst, what);
                }
            }
            3 | 4 => {
                let (a, b) = (self.int(rng), self.int(rng));
                let op = *choose(
                    rng,
                    &[AluOp::Add, AluOp::Sub, AluOp::Xor, AluOp::And, AluOp::Lt, AluOp::Eq],
                );
                if let Some(dst) = pick_dst(self, rng, false) {
                    self.asm.alu(op, dst, a, b);
                }
            }
            5 => {
                // shift by a fresh small immediate — the only shift the
                // generator emits, so the amount is provably in range
                let a = self.int(rng);
                self.asm.imm(TMP, rng.range_i64(0, 8));
                if let Some(dst) = pick_dst(self, rng, false) {
                    self.asm.alu(*choose(rng, &[AluOp::Shl, AluOp::Shr]), dst, a, TMP);
                }
            }
            6 => {
                // store any initialized value at an immediate slot
                let src = if rng.next_below(2) == 0 && !self.vecs.is_empty() {
                    self.vec(rng)
                } else {
                    self.int(rng)
                };
                self.asm.imm(TMP, rng.range_i64(0, 63));
                self.asm.st(TMP, src);
            }
            7 => {
                // load from scratch: the result's runtime type is
                // unknowable, so generated programs only probe it —
                // IsSet is the one op that's total over Val
                self.asm.imm(TMP, rng.range_i64(0, 63));
                if let Some(dst) = pick_dst(self, rng, false) {
                    self.asm.ld(dst, TMP);
                    self.asm.is_set(dst, dst);
                }
            }
            8 => {
                // shape-preserving payload ops (everything descends from
                // the packet payload, so dtypes always agree)
                let a = self.vec(rng);
                if let Some(dst) = pick_dst(self, rng, true) {
                    if rng.next_below(2) == 0 {
                        self.asm.ident_like(dst, a);
                    } else {
                        let b = self.vec(rng);
                        self.asm.combine(dst, a, b);
                    }
                }
            }
            _ => {
                let src = if rng.next_below(2) == 0 && !self.vecs.is_empty() {
                    self.vec(rng)
                } else {
                    self.int(rng)
                };
                self.asm.is_set(TMP, src);
                // inside a skipped block the write happens on only one
                // path, so TMP must NOT be marked initialized
                if !in_block {
                    self.ints.retain(|&r| r != TMP);
                    self.vecs.retain(|&r| r != TMP);
                    self.ints.push(TMP);
                }
            }
        }
    }
}

/// A random well-formed program: one entry serving both activations,
/// a prologue that initializes a payload + an integer, random safe
/// instructions, optional guarded skip-block, optional bounded counted
/// loop, optional deliver/emit, halt.
fn random_program(rng: &mut SplitMix64) -> Program {
    let mut g = Gen { asm: Asm::new(), ints: Vec::new(), vecs: Vec::new() };
    let entry = g.asm.label();
    g.asm.bind(entry);
    g.asm.ldpkt(PKT);
    g.vecs.push(PKT);
    g.asm.env(POOL[0], EnvVal::Rank);
    g.ints.push(POOL[0]);

    for _ in 0..rng.next_below(12) + 2 {
        g.safe_instr(rng, false);
    }

    if rng.next_below(2) == 0 {
        // guarded skip: jz over a couple of instructions that only
        // touch already-initialized registers
        let skip = g.asm.label();
        let cond = g.int(rng);
        g.asm.jz(cond, skip);
        for _ in 0..rng.next_below(3) + 1 {
            g.safe_instr(rng, true);
        }
        g.asm.bind(skip);
    }

    if rng.next_below(5) < 2 {
        // bounded counted loop: i = 0; do { body; i += 1 } while i < c.
        // The verifier's Lt refinement proves i <= c, so acceptance of
        // this shape exercises exactly the machinery the shipped
        // programs' RD loops rely on.
        g.asm.imm(LOOP_I, 0);
        g.asm.imm(LOOP_ONE, 1);
        g.asm.imm(LOOP_LIM, rng.range_i64(1, 6));
        let head = g.asm.label();
        g.asm.bind(head);
        for _ in 0..rng.next_below(3) + 1 {
            g.safe_instr(rng, true);
        }
        g.asm.alu(AluOp::Add, LOOP_I, LOOP_I, LOOP_ONE);
        g.asm.alu(AluOp::Lt, TMP, LOOP_I, LOOP_LIM);
        g.asm.jnz(TMP, head);
        // the loop registers become visible to LATER instructions only:
        // had they been in `ints` during body generation, a body write
        // to LOOP_LIM (say, env P) would unbound the loop at runtime
        // while the verifier's structural budget still accepted it
        g.ints.extend([LOOP_I, LOOP_ONE, LOOP_LIM]);
        g.ints.retain(|&r| r != TMP);
        g.ints.push(TMP);
    }

    if rng.next_below(2) == 0 {
        // emit to self: rank < p on every activation, so the runtime
        // wire asserts hold by construction.  The step register comes
        // from the pool (never TMP, which holds the destination rank)
        g.asm.env(TMP, EnvVal::Rank);
        let step = g.fresh(rng, false);
        g.asm.imm(step, rng.range_i64(0, 16));
        let payload = g.vec(rng);
        g.asm.emit(TMP, MsgType::Data, step, payload);
    }
    if rng.next_below(2) == 0 {
        let payload = g.vec(rng);
        g.asm.deliver(payload);
    }
    g.asm.halt();
    g.asm.finish("prop-gen", entry, entry)
}

fn request(p: usize, rank: usize, elems: usize) -> OffloadRequest {
    OffloadRequest {
        rank,
        comm: 0,
        epoch: 0,
        comm_size: p as u16,
        coll: CollType::Scan,
        algo: AlgoType::RecursiveDoubling,
        op: Op::Sum,
        dtype: Dtype::I32,
        payload: Payload::from_i32(&(0..elems as i32).collect::<Vec<_>>()),
    }
}

fn packet(p: usize, src: usize, step: u16, elems: usize) -> CollPacket {
    CollPacket {
        comm_id: 0,
        comm_size: p as u16,
        coll_type: CollType::Scan,
        algo_type: AlgoType::RecursiveDoubling,
        node_type: NodeType::Generic,
        msg_type: MsgType::Data,
        step,
        rank: src as u16,
        root: 0,
        operation: Op::Sum,
        data_type: Dtype::I32,
        count: elems as u32,
        frag_idx: 0,
        frag_total: 1,
        tag: 0,
        payload: Payload::from_i32(&vec![1; elems]),
    }
}

#[test]
fn accepted_programs_never_trip_the_vm() {
    let compute = NativeEngine::new();
    let cost = CostModel::default();
    for_each_case(60, 0x5EC5_CAFE, |rng| {
        let prog = random_program(rng);
        let report = verify(&prog).unwrap_or_else(|rs| {
            let lines: Vec<String> = rs.iter().map(|r| r.to_string()).collect();
            panic!("generated program rejected:\n{}\n{:#?}", lines.join("\n"), prog.code)
        });
        assert!(report.on_request_bound <= MAX_STEPS);
        assert!(report.on_packet_bound <= MAX_STEPS);
        assert!(report.on_timer_bound <= MAX_STEPS);

        // random environment; every activation must run assert-free and
        // within the statically computed instruction bound
        // 65535 is the largest p the u16 wire header can carry
        let p = *choose(rng, &[1usize, 2, 8, 65535]);
        let rank = rng.next_below(p as u64) as usize;
        let elems = *choose(rng, &[1usize, 4]);
        let mut flow = Flow::new();
        let mut activate = |act: Activation, bound: usize| {
            let mut ctx = EngineCtx {
                rank,
                p,
                inclusive: true,
                op: Op::Sum,
                coll: CollType::Scan,
                epoch: 0,
                compute: &compute,
                cost: &cost,
                cycles: 0,
                combine_cycles: 0,
                instrs: 0,
                stalls: 0,
            };
            run(&prog, &mut flow, &mut ctx, act);
            assert!(
                ctx.instrs as usize <= bound,
                "activation retired {} instrs, static bound is {bound}",
                ctx.instrs
            );
        };
        let req = request(p, rank, elems);
        activate(Activation::Request(&req), report.on_request_bound);
        for _ in 0..3 {
            let pkt = packet(p, rng.next_below(p as u64) as usize,
                             rng.next_below(17) as u16, elems);
            activate(Activation::Packet(&pkt), report.on_packet_bound);
        }
        // the retransmit-timer entry (the auto-appended standard policy
        // here) must respect its own bound on both sides of the budget
        let retries = rng.next_below(5) as u32;
        activate(Activation::Timer { retries, max_retries: 3 }, report.on_timer_bound);
    });
}

/// Append an ill-formed block of a known class and point `on_request`
/// at it (appending never shifts existing jump targets).  Returns the
/// class the verifier must report.
fn inject_fault(prog: &mut Program, which: u64) -> &'static str {
    let n = prog.code.len();
    match which {
        0 => {
            // r14 is never written by the generator
            prog.code.extend([
                Instr::Alu { op: AluOp::Add, dst: 0, a: NEVER, b: NEVER },
                Instr::Halt,
            ]);
            prog.on_request = n;
            "uninit-read"
        }
        1 => {
            prog.code.extend([
                Instr::Imm { dst: 0, val: 64 },
                Instr::Imm { dst: 1, val: 1 },
                Instr::St { slot: 0, src: 1 },
                Instr::Halt,
            ]);
            prog.on_request = n;
            "scratch-oob"
        }
        2 => {
            // the appended tail IS the last instruction, and falls off
            prog.code.push(Instr::Imm { dst: 0, val: 1 });
            prog.on_request = n;
            "missing-halt"
        }
        3 => {
            // counted loop with a 300-instruction body: the per-back-edge
            // trip allowance makes the bound blow past MAX_STEPS
            prog.code.push(Instr::Imm { dst: 0, val: 0 });
            prog.code.push(Instr::Imm { dst: 1, val: 1 });
            let head = prog.code.len();
            for _ in 0..300 {
                prog.code.push(Instr::Alu { op: AluOp::Add, dst: 0, a: 0, b: 1 });
            }
            prog.code.push(Instr::Env { dst: 2, what: EnvVal::P });
            prog.code.push(Instr::Alu { op: AluOp::Lt, dst: 3, a: 0, b: 2 });
            prog.code.push(Instr::Jnz { cond: 3, to: head });
            prog.code.push(Instr::Halt);
            prog.on_request = n;
            "budget"
        }
        4 => {
            prog.code.extend([
                Instr::Imm { dst: 0, val: 1 },
                Instr::Imm { dst: 1, val: 2 },
                Instr::Combine { dst: 2, a: 0, b: 1 },
                Instr::Halt,
            ]);
            prog.on_request = n;
            "dtype-mismatch"
        }
        5 => {
            // self-loop with no exit
            prog.code.push(Instr::Jmp { to: n });
            prog.on_request = n;
            "no-termination"
        }
        _ => {
            prog.code.extend([Instr::Jmp { to: n + 999 }, Instr::Halt]);
            prog.on_request = n;
            "bad-target"
        }
    }
}

#[test]
fn injected_faults_are_rejected_with_their_class() {
    for_each_case(70, 0xBAD_5EED, |rng| {
        let mut prog = random_program(rng);
        let which = rng.next_below(7);
        let class = inject_fault(&mut prog, which);
        match verify(&prog) {
            Ok(_) => panic!("fault class {class} not detected"),
            Err(rs) => assert!(
                rs.iter().any(|r| r.class() == class),
                "expected class {class}, got {:?}",
                rs.iter().map(RejectReason::class).collect::<Vec<_>>()
            ),
        }
    });
}
