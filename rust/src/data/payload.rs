//! Typed byte payloads — the scan data as it sits on the wire.
//!
//! A payload is little-endian bytes plus its [`Dtype`]; this is exactly the
//! datagram body the NetFPGA streamed through its adder pipeline.  All
//! element access converts at the boundary, so payloads can be sliced,
//! chunked for MTU segmentation, and handed to either compute engine
//! (native Rust or the compiled XLA artifact) without copying per element.

use std::rc::Rc;

use super::arena::AlignedBuf;
use super::{Dtype, Op};

/// SSPerf notes (EXPERIMENTS.md SSPerf has the iteration log):
///
/// - payloads are copy-on-write (`Rc<AlignedBuf>`): the scan state
///   machines clone payloads liberally (every send, buffer, fold input);
///   with plain `Vec<u8>` those deep copies were the top simulator cost
///   at multi-KB message sizes.  `clone()` is a refcount bump.
/// - `slice()` is a zero-copy *window* (offset+len into the shared
///   backing): MTU fragmentation of an N-byte message used to copy all N
///   bytes again; now it is O(fragments).
/// - the backing is an 8-byte-aligned pooled arena buffer
///   (`data::arena`): dropped payloads recycle their storage through a
///   thread-local free list, and element-aligned windows expose
///   **zero-copy typed views** (`as_i32`/`as_f32`/`as_f64`) — the
///   combine datapath folds in place over them instead of allocating
///   four `Vec`s per call (decode x2, result, re-encode).
///
/// Alignment contract for payload producers: every constructor places
/// data at an 8-byte-aligned base, and `slice()` windows are element
/// multiples, so typed views are always aligned in practice.  Code that
/// somehow holds an unaligned window (hand-built wire slices) still
/// works: typed *reads* fall back to copying (`to_i32` et al.), and
/// `as_mut_*` first materializes the window into a fresh aligned buffer.
#[derive(Clone)]
pub struct Payload {
    dtype: Dtype,
    buf: Rc<AlignedBuf>,
    /// window into `buf` (byte offset / byte length)
    off: usize,
    len_b: usize,
}

impl PartialEq for Payload {
    fn eq(&self, other: &Self) -> bool {
        if self.dtype != other.dtype || self.len_b != other.len_b {
            return false;
        }
        // pointer+window fast path: clones of the same backing with the
        // same window are equal without touching the bytes — the verify
        // pass compares cloned results constantly.
        if Rc::ptr_eq(&self.buf, &other.buf) && self.off == other.off {
            return true;
        }
        self.bytes() == other.bytes()
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Payload({} x{})", self.dtype.name(), self.len())
    }
}

impl Payload {
    pub fn from_bytes(dtype: Dtype, bytes: Vec<u8>) -> Self {
        assert!(
            bytes.len() % dtype.size() == 0,
            "payload length {} not a multiple of element size {}",
            bytes.len(),
            dtype.size()
        );
        let len_b = bytes.len();
        Payload { dtype, buf: Rc::new(AlignedBuf::copy_from(&bytes)), off: 0, len_b }
    }

    /// Zero-filled payload of `n` elements (arena-backed, pooled).  The
    /// streaming reassembler writes fragments into one of these.
    pub fn zeroed(dtype: Dtype, n: usize) -> Self {
        let len_b = n * dtype.size();
        Payload { dtype, buf: Rc::new(AlignedBuf::zeroed(len_b)), off: 0, len_b }
    }

    pub fn from_i32(v: &[i32]) -> Self {
        let mut buf = AlignedBuf::scratch(v.len() * 4);
        for (dst, x) in buf.bytes_mut().chunks_exact_mut(4).zip(v) {
            dst.copy_from_slice(&x.to_le_bytes());
        }
        let len_b = v.len() * 4;
        Payload { dtype: Dtype::I32, buf: Rc::new(buf), off: 0, len_b }
    }

    pub fn from_f32(v: &[f32]) -> Self {
        let mut buf = AlignedBuf::scratch(v.len() * 4);
        for (dst, x) in buf.bytes_mut().chunks_exact_mut(4).zip(v) {
            dst.copy_from_slice(&x.to_le_bytes());
        }
        let len_b = v.len() * 4;
        Payload { dtype: Dtype::F32, buf: Rc::new(buf), off: 0, len_b }
    }

    pub fn from_f64(v: &[f64]) -> Self {
        let mut buf = AlignedBuf::scratch(v.len() * 8);
        for (dst, x) in buf.bytes_mut().chunks_exact_mut(8).zip(v) {
            dst.copy_from_slice(&x.to_le_bytes());
        }
        let len_b = v.len() * 8;
        Payload { dtype: Dtype::F64, buf: Rc::new(buf), off: 0, len_b }
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.len_b / self.dtype.size()
    }

    pub fn is_empty(&self) -> bool {
        self.len_b == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len_b
    }

    pub fn bytes(&self) -> &[u8] {
        &self.buf.bytes()[self.off..self.off + self.len_b]
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes().to_vec()
    }

    /// True when no other payload shares this backing buffer — in-place
    /// mutation through `as_mut_*` is then copy-free.
    pub fn is_unique(&self) -> bool {
        Rc::strong_count(&self.buf) == 1
    }

    // ---------------------------------------------- zero-copy typed views

    fn typed<T>(&self) -> Option<&[T]> {
        let b = self.bytes();
        let es = std::mem::size_of::<T>();
        debug_assert_eq!(b.len() % es, 0);
        if b.as_ptr().align_offset(std::mem::align_of::<T>()) != 0 {
            return None; // unaligned window: caller falls back to copying
        }
        // SAFETY: length/alignment checked; i32/f32/f64 admit all bit
        // patterns; lifetime tied to &self.
        Some(unsafe { std::slice::from_raw_parts(b.as_ptr().cast::<T>(), b.len() / es) })
    }

    /// Unique + aligned mutable typed window.  Shared or unaligned
    /// backings are first materialized into a fresh pooled buffer
    /// (`Rc::make_mut` semantics) — steady-state folds on uniquely-owned
    /// payloads never copy and never allocate.
    fn typed_mut<T>(&mut self) -> &mut [T] {
        let es = std::mem::size_of::<T>();
        let shared = Rc::get_mut(&mut self.buf).is_none();
        let unaligned = self.bytes().as_ptr().align_offset(std::mem::align_of::<T>()) != 0;
        if shared || unaligned {
            let copy = AlignedBuf::copy_from(self.bytes());
            self.buf = Rc::new(copy);
            self.off = 0;
        }
        let (off, len_b) = (self.off, self.len_b);
        let buf = Rc::get_mut(&mut self.buf).expect("unique after materialization");
        let b = &mut buf.bytes_mut()[off..off + len_b];
        debug_assert_eq!(b.as_ptr().align_offset(std::mem::align_of::<T>()), 0);
        // SAFETY: as in `typed`, with exclusivity through &mut self.
        unsafe { std::slice::from_raw_parts_mut(b.as_mut_ptr().cast::<T>(), b.len() / es) }
    }

    /// Zero-copy `&[i32]` view; `None` for an unaligned window (use
    /// `to_i32` there).  Panics on dtype mismatch.
    pub fn try_as_i32(&self) -> Option<&[i32]> {
        assert_eq!(self.dtype, Dtype::I32);
        self.typed::<i32>()
    }

    pub fn try_as_f32(&self) -> Option<&[f32]> {
        assert_eq!(self.dtype, Dtype::F32);
        self.typed::<f32>()
    }

    pub fn try_as_f64(&self) -> Option<&[f64]> {
        assert_eq!(self.dtype, Dtype::F64);
        self.typed::<f64>()
    }

    /// Zero-copy `&[i32]` view of an aligned window (the structural
    /// invariant; see the alignment contract above).
    pub fn as_i32(&self) -> &[i32] {
        self.try_as_i32().expect("unaligned i32 window")
    }

    pub fn as_f32(&self) -> &[f32] {
        self.try_as_f32().expect("unaligned f32 window")
    }

    pub fn as_f64(&self) -> &[f64] {
        self.try_as_f64().expect("unaligned f64 window")
    }

    /// In-place mutable `&mut [i32]` view (unique-ownership check; copies
    /// once when shared).  Panics on dtype mismatch.
    pub fn as_mut_i32(&mut self) -> &mut [i32] {
        assert_eq!(self.dtype, Dtype::I32);
        self.typed_mut::<i32>()
    }

    pub fn as_mut_f32(&mut self) -> &mut [f32] {
        assert_eq!(self.dtype, Dtype::F32);
        self.typed_mut::<f32>()
    }

    pub fn as_mut_f64(&mut self) -> &mut [f64] {
        assert_eq!(self.dtype, Dtype::F64);
        self.typed_mut::<f64>()
    }

    /// Test-only: a window at an arbitrary BYTE offset into a copy of
    /// `bytes`.  No public constructor can produce a sub-element-aligned
    /// window (slice() moves in element multiples), so this is how the
    /// unaligned fallbacks stay reachable and tested.
    #[cfg(test)]
    pub(crate) fn misaligned_for_test(dtype: Dtype, bytes: &[u8], byte_off: usize) -> Payload {
        assert!(byte_off <= bytes.len() && (bytes.len() - byte_off) % dtype.size() == 0);
        Payload {
            dtype,
            buf: Rc::new(AlignedBuf::copy_from(bytes)),
            off: byte_off,
            len_b: bytes.len() - byte_off,
        }
    }

    /// Copy `bytes` into the window at `byte_off`.  Requires unique
    /// ownership (the streaming reassembler owns its in-progress buffers
    /// exclusively) — shared backings panic instead of silently forking.
    pub fn write_bytes_at(&mut self, byte_off: usize, bytes: &[u8]) {
        assert!(byte_off + bytes.len() <= self.len_b, "write out of window");
        let off = self.off;
        let buf = Rc::get_mut(&mut self.buf).expect("write_bytes_at needs unique ownership");
        buf.bytes_mut()[off + byte_off..off + byte_off + bytes.len()].copy_from_slice(bytes);
    }

    // --------------------------------------------------- copying accessors

    pub fn to_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, Dtype::I32);
        self.bytes().chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect()
    }

    pub fn to_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, Dtype::F32);
        self.bytes().chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
    }

    pub fn to_f64(&self) -> Vec<f64> {
        assert_eq!(self.dtype, Dtype::F64);
        self.bytes().chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect()
    }

    /// Identity-element payload of `n` elements for (op, dtype) — what the
    /// runtime pads with so fixed-block artifacts don't perturb results.
    pub fn identity(dtype: Dtype, op: Op, n: usize) -> Payload {
        match dtype {
            Dtype::I32 => Payload::from_i32(&vec![identity_i32(op); n]),
            Dtype::F32 => Payload::from_f32(&vec![identity_f32(op); n]),
            Dtype::F64 => Payload::from_f64(&vec![identity_f64(op); n]),
        }
    }

    /// Zero-copy sub-range view of elements [start, start+n) — MTU
    /// chunking shares the backing allocation.
    pub fn slice(&self, start: usize, n: usize) -> Payload {
        let es = self.dtype.size();
        assert!((start + n) * es <= self.len_b, "slice out of range");
        Payload {
            dtype: self.dtype,
            buf: self.buf.clone(),
            off: self.off + start * es,
            len_b: n * es,
        }
    }

    /// Concatenate chunks back together (one aligned buffer, one copy).
    pub fn concat(chunks: &[Payload]) -> Payload {
        assert!(!chunks.is_empty());
        let dtype = chunks[0].dtype;
        let total: usize = chunks.iter().map(|c| c.byte_len()).sum();
        let mut buf = AlignedBuf::scratch(total);
        let mut at = 0;
        for c in chunks {
            assert_eq!(c.dtype, dtype);
            buf.bytes_mut()[at..at + c.byte_len()].copy_from_slice(c.bytes());
            at += c.byte_len();
        }
        Payload { dtype, buf: Rc::new(buf), off: 0, len_b: total }
    }

    /// Extend to `n` elements with the op identity (in place;
    /// materializes the window).
    pub fn pad_to(&mut self, op: Op, n: usize) {
        let cur = self.len();
        if cur < n {
            let pad = Payload::identity(self.dtype, op, n - cur);
            *self = Payload::concat(&[self.clone(), pad]);
        }
    }

    /// Truncate to `n` elements (in place; O(1) — shrinks the window).
    pub fn truncate(&mut self, n: usize) {
        let want = n * self.dtype.size();
        assert!(want <= self.len_b, "truncate cannot grow");
        self.len_b = want;
    }
}

pub fn identity_i32(op: Op) -> i32 {
    match op {
        Op::Sum | Op::Bor | Op::Bxor => 0,
        Op::Prod => 1,
        Op::Max => i32::MIN,
        Op::Min => i32::MAX,
        Op::Band => -1,
    }
}

pub fn identity_f32(op: Op) -> f32 {
    match op {
        Op::Sum => 0.0,
        Op::Prod => 1.0,
        Op::Max => f32::MIN,
        Op::Min => f32::MAX,
        _ => panic!("bitwise op on float payload"),
    }
}

pub fn identity_f64(op: Op) -> f64 {
    match op {
        Op::Sum => 0.0,
        Op::Prod => 1.0,
        Op::Max => f64::MIN,
        Op::Min => f64::MAX,
        _ => panic!("bitwise op on float payload"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_typed_views() {
        let p = Payload::from_i32(&[1, -2, 3]);
        assert_eq!(p.len(), 3);
        assert_eq!(p.byte_len(), 12);
        assert_eq!(p.to_i32(), vec![1, -2, 3]);

        let f = Payload::from_f64(&[1.5, -2.25]);
        assert_eq!(f.to_f64(), vec![1.5, -2.25]);
    }

    #[test]
    fn zero_copy_views_match_copying_accessors() {
        let p = Payload::from_i32(&[7, -9, 0, i32::MAX]);
        assert_eq!(p.as_i32(), p.to_i32().as_slice());
        let f = Payload::from_f32(&[0.5, -3.25]);
        assert_eq!(f.as_f32(), f.to_f32().as_slice());
        let d = Payload::from_f64(&[1e300, -2.5]);
        assert_eq!(d.as_f64(), d.to_f64().as_slice());
    }

    #[test]
    fn views_of_odd_element_windows() {
        // windows always start on element boundaries; i32 windows at odd
        // element offsets are 4-aligned (base is 8-aligned) and must
        // still view zero-copy
        let p = Payload::from_i32(&(0..9).collect::<Vec<_>>());
        let w = p.slice(1, 7);
        assert_eq!(w.as_i32(), &[1, 2, 3, 4, 5, 6, 7]);
        let f = Payload::from_f64(&[1.0, 2.0, 3.0]);
        assert_eq!(f.slice(1, 2).as_f64(), &[2.0, 3.0]);
    }

    #[test]
    fn unaligned_window_fallbacks() {
        // f64 data at byte offset +4: the zero-copy view must refuse, the
        // copying accessor must work, and as_mut_* must realign by
        // materializing into a fresh buffer
        let vals = [1.5f64, -2.5, 3.25];
        let mut raw = vec![0u8; 4];
        for v in vals {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        let p = Payload::misaligned_for_test(Dtype::F64, &raw, 4);
        assert!(p.try_as_f64().is_none(), "window at +4B cannot view as &[f64]");
        assert_eq!(p.to_f64(), vals);
        let mut q = p.clone();
        assert_eq!(q.as_mut_f64(), &vals);
        q.as_mut_f64()[0] = 9.0;
        assert!(q.try_as_f64().is_some(), "materialization realigned the window");
        assert_eq!(q.to_f64(), [9.0, -2.5, 3.25]);
        assert_eq!(p.to_f64(), vals, "original untouched");
    }

    #[test]
    fn as_mut_copies_shared_backing_once() {
        let p = Payload::from_i32(&[1, 2, 3]);
        let mut q = p.clone();
        assert!(!q.is_unique());
        q.as_mut_i32()[0] = 99;
        assert!(q.is_unique(), "mutation forked the shared backing");
        assert_eq!(p.to_i32(), vec![1, 2, 3], "original untouched");
        assert_eq!(q.to_i32(), vec![99, 2, 3]);
        // now unique: further mutation is in place (backing unchanged)
        let before = q.bytes().as_ptr();
        q.as_mut_i32()[1] = -1;
        assert_eq!(q.bytes().as_ptr(), before, "unique mutation must not copy");
        assert_eq!(q.to_i32(), vec![99, -1, 3]);
    }

    #[test]
    fn as_mut_on_window_preserves_window_contents() {
        let p = Payload::from_i32(&(0..6).collect::<Vec<_>>());
        let mut w = p.slice(2, 3); // shared with p
        w.as_mut_i32()[0] = 42;
        assert_eq!(w.to_i32(), vec![42, 3, 4]);
        assert_eq!(p.to_i32(), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn eq_fast_path_same_backing() {
        let p = Payload::from_i32(&(0..100).collect::<Vec<_>>());
        let q = p.clone();
        assert_eq!(p, q);
        assert_eq!(p.slice(10, 5), q.slice(10, 5));
        // different windows of the same backing compare by bytes
        assert_ne!(p.slice(0, 5), p.slice(10, 5));
        // equal bytes in different backings still compare equal
        assert_eq!(p, Payload::from_i32(&(0..100).collect::<Vec<_>>()));
    }

    #[test]
    fn write_bytes_at_requires_unique() {
        let mut p = Payload::zeroed(Dtype::I32, 4);
        p.write_bytes_at(4, &7i32.to_le_bytes());
        assert_eq!(p.to_i32(), vec![0, 7, 0, 0]);
        let _share = p.clone();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut p = p;
            p.write_bytes_at(0, &[1, 2, 3, 4]);
        }));
        assert!(r.is_err(), "shared backing must refuse raw writes");
    }

    #[test]
    fn slice_and_concat_inverse() {
        let p = Payload::from_i32(&(0..100).collect::<Vec<_>>());
        let a = p.slice(0, 40);
        let b = p.slice(40, 60);
        assert_eq!(Payload::concat(&[a, b]), p);
    }

    #[test]
    fn pad_then_truncate_is_identity() {
        let mut p = Payload::from_f32(&[1.0, 2.0]);
        let orig = p.clone();
        p.pad_to(Op::Sum, 8);
        assert_eq!(p.len(), 8);
        assert_eq!(p.to_f32()[2..], [0.0; 6]);
        p.truncate(2);
        assert_eq!(p, orig);
    }

    #[test]
    fn identity_values() {
        assert_eq!(Payload::identity(Dtype::I32, Op::Max, 2).to_i32(), vec![i32::MIN; 2]);
        assert_eq!(Payload::identity(Dtype::F64, Op::Prod, 1).to_f64(), vec![1.0]);
        assert_eq!(Payload::identity(Dtype::I32, Op::Band, 1).to_i32(), vec![-1]);
    }

    #[test]
    #[should_panic]
    fn misaligned_bytes_rejected() {
        Payload::from_bytes(Dtype::I32, vec![0u8; 7]);
    }
}
