//! Typed byte payloads — the scan data as it sits on the wire.
//!
//! A payload is little-endian bytes plus its [`Dtype`]; this is exactly the
//! datagram body the NetFPGA streamed through its adder pipeline.  All
//! element access converts at the boundary, so payloads can be sliced,
//! chunked for MTU segmentation, and handed to either compute engine
//! (native Rust or the compiled XLA artifact) without copying per element.

use std::rc::Rc;

use super::{Dtype, Op};

/// SSPerf notes (EXPERIMENTS.md SSPerf has the iteration log):
///
/// - payloads are copy-on-write (`Rc<Vec<u8>>`): the scan state machines
///   clone payloads liberally (every send, buffer, fold input); with
///   plain `Vec<u8>` those deep copies were the top simulator cost at
///   multi-KB message sizes.  `clone()` is a refcount bump.
/// - `slice()` is a zero-copy *window* (offset+len into the shared
///   backing): MTU fragmentation of an N-byte message used to copy all N
///   bytes again; now it is O(fragments).
#[derive(Clone)]
pub struct Payload {
    dtype: Dtype,
    bytes: Rc<Vec<u8>>,
    /// window into `bytes` (byte offset / byte length)
    off: usize,
    len_b: usize,
}

impl PartialEq for Payload {
    fn eq(&self, other: &Self) -> bool {
        self.dtype == other.dtype && self.bytes() == other.bytes()
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Payload({} x{})", self.dtype.name(), self.len())
    }
}

impl Payload {
    pub fn from_bytes(dtype: Dtype, bytes: Vec<u8>) -> Self {
        assert!(
            bytes.len() % dtype.size() == 0,
            "payload length {} not a multiple of element size {}",
            bytes.len(),
            dtype.size()
        );
        let len_b = bytes.len();
        Payload { dtype, bytes: Rc::new(bytes), off: 0, len_b }
    }

    pub fn from_i32(v: &[i32]) -> Self {
        Payload::from_bytes(Dtype::I32, v.iter().flat_map(|x| x.to_le_bytes()).collect())
    }

    pub fn from_f32(v: &[f32]) -> Self {
        Payload::from_bytes(Dtype::F32, v.iter().flat_map(|x| x.to_le_bytes()).collect())
    }

    pub fn from_f64(v: &[f64]) -> Self {
        Payload::from_bytes(Dtype::F64, v.iter().flat_map(|x| x.to_le_bytes()).collect())
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.len_b / self.dtype.size()
    }

    pub fn is_empty(&self) -> bool {
        self.len_b == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len_b
    }

    pub fn bytes(&self) -> &[u8] {
        &self.bytes[self.off..self.off + self.len_b]
    }

    pub fn into_bytes(self) -> Vec<u8> {
        if self.off == 0 && self.len_b == self.bytes.len() {
            Rc::try_unwrap(self.bytes).unwrap_or_else(|rc| (*rc).clone())
        } else {
            self.bytes().to_vec()
        }
    }

    pub fn to_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, Dtype::I32);
        self.bytes().chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect()
    }

    pub fn to_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, Dtype::F32);
        self.bytes().chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
    }

    pub fn to_f64(&self) -> Vec<f64> {
        assert_eq!(self.dtype, Dtype::F64);
        self.bytes().chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect()
    }

    /// Identity-element payload of `n` elements for (op, dtype) — what the
    /// runtime pads with so fixed-block artifacts don't perturb results.
    pub fn identity(dtype: Dtype, op: Op, n: usize) -> Payload {
        match dtype {
            Dtype::I32 => Payload::from_i32(&vec![identity_i32(op); n]),
            Dtype::F32 => Payload::from_f32(&vec![identity_f32(op); n]),
            Dtype::F64 => Payload::from_f64(&vec![identity_f64(op); n]),
        }
    }

    /// Zero-copy sub-range view of elements [start, start+n) — MTU
    /// chunking shares the backing allocation.
    pub fn slice(&self, start: usize, n: usize) -> Payload {
        let es = self.dtype.size();
        assert!((start + n) * es <= self.len_b, "slice out of range");
        Payload {
            dtype: self.dtype,
            bytes: self.bytes.clone(),
            off: self.off + start * es,
            len_b: n * es,
        }
    }

    /// Concatenate chunks back together (reassembly).
    pub fn concat(chunks: &[Payload]) -> Payload {
        assert!(!chunks.is_empty());
        let dtype = chunks[0].dtype;
        let mut bytes = Vec::with_capacity(chunks.iter().map(|c| c.byte_len()).sum());
        for c in chunks {
            assert_eq!(c.dtype, dtype);
            bytes.extend_from_slice(c.bytes());
        }
        let len_b = bytes.len();
        Payload { dtype, bytes: Rc::new(bytes), off: 0, len_b }
    }

    /// Extend to `n` elements with the op identity (in place;
    /// materializes the window).
    pub fn pad_to(&mut self, op: Op, n: usize) {
        let cur = self.len();
        if cur < n {
            let pad = Payload::identity(self.dtype, op, n - cur);
            let mut v = Vec::with_capacity(n * self.dtype.size());
            v.extend_from_slice(self.bytes());
            v.extend_from_slice(pad.bytes());
            *self = Payload::from_bytes(self.dtype, v);
        }
    }

    /// Truncate to `n` elements (in place; O(1) — shrinks the window).
    pub fn truncate(&mut self, n: usize) {
        let want = n * self.dtype.size();
        assert!(want <= self.len_b, "truncate cannot grow");
        self.len_b = want;
    }
}

pub fn identity_i32(op: Op) -> i32 {
    match op {
        Op::Sum | Op::Bor | Op::Bxor => 0,
        Op::Prod => 1,
        Op::Max => i32::MIN,
        Op::Min => i32::MAX,
        Op::Band => -1,
    }
}

pub fn identity_f32(op: Op) -> f32 {
    match op {
        Op::Sum => 0.0,
        Op::Prod => 1.0,
        Op::Max => f32::MIN,
        Op::Min => f32::MAX,
        _ => panic!("bitwise op on float payload"),
    }
}

pub fn identity_f64(op: Op) -> f64 {
    match op {
        Op::Sum => 0.0,
        Op::Prod => 1.0,
        Op::Max => f64::MIN,
        Op::Min => f64::MAX,
        _ => panic!("bitwise op on float payload"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_typed_views() {
        let p = Payload::from_i32(&[1, -2, 3]);
        assert_eq!(p.len(), 3);
        assert_eq!(p.byte_len(), 12);
        assert_eq!(p.to_i32(), vec![1, -2, 3]);

        let f = Payload::from_f64(&[1.5, -2.25]);
        assert_eq!(f.to_f64(), vec![1.5, -2.25]);
    }

    #[test]
    fn slice_and_concat_inverse() {
        let p = Payload::from_i32(&(0..100).collect::<Vec<_>>());
        let a = p.slice(0, 40);
        let b = p.slice(40, 60);
        assert_eq!(Payload::concat(&[a, b]), p);
    }

    #[test]
    fn pad_then_truncate_is_identity() {
        let mut p = Payload::from_f32(&[1.0, 2.0]);
        let orig = p.clone();
        p.pad_to(Op::Sum, 8);
        assert_eq!(p.len(), 8);
        assert_eq!(p.to_f32()[2..], [0.0; 6]);
        p.truncate(2);
        assert_eq!(p, orig);
    }

    #[test]
    fn identity_values() {
        assert_eq!(Payload::identity(Dtype::I32, Op::Max, 2).to_i32(), vec![i32::MIN; 2]);
        assert_eq!(Payload::identity(Dtype::F64, Op::Prod, 1).to_f64(), vec![1.0]);
        assert_eq!(Payload::identity(Dtype::I32, Op::Band, 1).to_i32(), vec![-1]);
    }

    #[test]
    #[should_panic]
    fn misaligned_bytes_rejected() {
        Payload::from_bytes(Dtype::I32, vec![0u8; 7]);
    }
}
