//! 8-byte-aligned, pooled payload backing — the simulator's answer to the
//! NetFPGA's preallocated line-rate buffers.
//!
//! The hot datapath (combine folds, fragment reassembly, wire buffers)
//! must not allocate in steady state: the hardware it models streams
//! payloads through fixed SRAM, and malloc churn was the dominant
//! simulator cost after the CoW-payload and calendar-queue passes
//! (EXPERIMENTS.md SSPerf).  An [`AlignedBuf`] is a `Vec<u64>` store —
//! 8-byte base alignment for free, so element-aligned windows of every
//! supported dtype can be viewed as `&[i32]`/`&[f32]`/`&[f64]` without
//! copying — whose storage is recycled through a thread-local free list
//! when the buffer drops.  One pool per thread matches the sweep runner's
//! one-`!Send`-engine-per-worker design: payloads never cross threads
//! (`Rc` enforces it), so the pool needs no locks.
//!
//! Pool policy: exact-size bins keyed by word count.  A simulation run
//! uses a small, fixed set of payload sizes (message size, MTU chunk,
//! tail chunk), so exact bins hit essentially always; total held bytes
//! are capped so pathological sweeps cannot hoard memory.

use std::cell::RefCell;
use std::collections::HashMap;

/// Cap on pooled storage per thread (in u64 words): 16 MB.  Beyond this,
/// dropped buffers free normally.
const MAX_HELD_WORDS: usize = 2 << 20;

/// Cap on buffers held per size bin — steady state needs only a handful
/// of in-flight buffers per size.
const MAX_PER_BIN: usize = 32;

#[derive(Default)]
struct Pool {
    /// Free stores keyed by their word length.
    bins: HashMap<usize, Vec<Vec<u64>>>,
    held_words: usize,
    hits: u64,
    misses: u64,
}

impl Pool {
    fn take(&mut self, words: usize) -> Option<Vec<u64>> {
        match self.bins.get_mut(&words).and_then(|bin| bin.pop()) {
            Some(v) => {
                self.held_words -= v.capacity();
                self.hits += 1;
                Some(v)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    fn give(&mut self, v: Vec<u64>) {
        let words = v.len();
        if self.held_words + v.capacity() > MAX_HELD_WORDS {
            return; // over budget: let it free
        }
        let bin = self.bins.entry(words).or_default();
        if bin.len() >= MAX_PER_BIN {
            return;
        }
        self.held_words += v.capacity();
        bin.push(v);
    }
}

thread_local! {
    static POOL: RefCell<Pool> = RefCell::new(Pool::default());
}

/// (hits, misses) of this thread's arena pool — recycling observability
/// for the zero-alloc regression tests and the microbench report.
pub fn pool_stats() -> (u64, u64) {
    POOL.with(|p| {
        let p = p.borrow();
        (p.hits, p.misses)
    })
}

/// Buffers currently parked in this thread's pool.
pub fn pool_free_buffers() -> usize {
    POOL.with(|p| p.borrow().bins.values().map(|b| b.len()).sum())
}

/// An 8-byte-aligned byte buffer backed by a pooled `Vec<u64>`.
///
/// `len_b` is the valid byte length; the word store covers it rounded up
/// to the next multiple of 8 (tail padding is zero).  On drop the word
/// store returns to the thread-local free list, so steady-state payload
/// traffic reuses storage instead of hitting the allocator.
pub struct AlignedBuf {
    words: Vec<u64>,
    len_b: usize,
}

impl AlignedBuf {
    fn with_store(len_b: usize, zero_all: bool) -> AlignedBuf {
        let words = len_b.div_ceil(8);
        // try_with: buffers dropped during thread teardown (after the
        // pool's TLS slot is destroyed) must not panic — they just free.
        let recycled = POOL.try_with(|p| p.borrow_mut().take(words)).ok().flatten();
        let v = match recycled {
            Some(mut v) => {
                if zero_all {
                    // re-zero for the zeroed() contract
                    v.iter_mut().for_each(|w| *w = 0);
                } else if let Some(last) = v.last_mut() {
                    // caller overwrites every payload byte; only the tail
                    // padding word must not leak a previous payload
                    *last = 0;
                }
                v
            }
            None => vec![0u64; words],
        };
        debug_assert_eq!(v.len(), words);
        AlignedBuf { words: v, len_b }
    }

    /// A zero-filled buffer of `len_b` bytes, recycled from the pool when
    /// a matching store is free.
    pub fn zeroed(len_b: usize) -> AlignedBuf {
        AlignedBuf::with_store(len_b, true)
    }

    /// A recycled-or-fresh buffer whose first `len_b` bytes the caller
    /// promises to overwrite entirely (constructors, concat): skips the
    /// full memset, zeroing only the tail-padding word.
    pub(crate) fn scratch(len_b: usize) -> AlignedBuf {
        AlignedBuf::with_store(len_b, false)
    }

    /// A buffer holding a copy of `bytes` (tail padding zero).
    pub fn copy_from(bytes: &[u8]) -> AlignedBuf {
        let mut b = AlignedBuf::scratch(bytes.len());
        b.bytes_mut().copy_from_slice(bytes);
        b
    }

    pub fn len_b(&self) -> usize {
        self.len_b
    }

    pub fn bytes(&self) -> &[u8] {
        // SAFETY: words owns >= len_b initialized bytes; u64 -> u8 only
        // weakens alignment; the slice lifetime is tied to &self.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr().cast::<u8>(), self.len_b) }
    }

    pub fn bytes_mut(&mut self) -> &mut [u8] {
        // SAFETY: as above, with exclusive access through &mut self.
        unsafe {
            std::slice::from_raw_parts_mut(self.words.as_mut_ptr().cast::<u8>(), self.len_b)
        }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        let v = std::mem::take(&mut self.words);
        if v.capacity() == 0 {
            return;
        }
        // ignore TLS-teardown failures: the store then frees normally
        let _ = POOL.try_with(|p| p.borrow_mut().give(v));
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedBuf({}B)", self.len_b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeroed_and_copy_roundtrip() {
        let b = AlignedBuf::zeroed(13);
        assert_eq!(b.len_b(), 13);
        assert!(b.bytes().iter().all(|&x| x == 0));
        let c = AlignedBuf::copy_from(&[1, 2, 3, 4, 5]);
        assert_eq!(c.bytes(), &[1, 2, 3, 4, 5]);
    }

    #[test]
    fn base_is_8_byte_aligned() {
        for len in [1usize, 7, 8, 9, 4096] {
            let b = AlignedBuf::zeroed(len);
            assert_eq!(b.bytes().as_ptr().align_offset(8), 0, "len {len}");
        }
    }

    #[test]
    fn mutation_sticks() {
        let mut b = AlignedBuf::zeroed(16);
        b.bytes_mut()[3] = 0xAB;
        b.bytes_mut()[15] = 0xCD;
        assert_eq!(b.bytes()[3], 0xAB);
        assert_eq!(b.bytes()[15], 0xCD);
    }

    #[test]
    fn drop_recycles_into_the_pool() {
        let (h0, _) = pool_stats();
        // an uncommon size: first alloc misses, second (after drop) hits
        let n = 6311 * 8;
        drop(AlignedBuf::zeroed(n));
        let b = AlignedBuf::zeroed(n);
        let (h1, _) = pool_stats();
        assert!(h1 > h0, "second allocation of the same size must reuse the store");
        assert!(b.bytes().iter().all(|&x| x == 0), "recycled stores are re-zeroed");
    }

    #[test]
    fn empty_buffer_ok() {
        let b = AlignedBuf::zeroed(0);
        assert!(b.bytes().is_empty());
    }
}
