//! MPI datatypes, reduction operations, and the typed byte payloads that
//! flow through the simulated network.
//!
//! Mirrors the `data_type` / `operation` fields of the paper's offload
//! packet (Fig. 1).  Payloads are raw little-endian bytes exactly as they
//! would sit in a UDP datagram; typed views convert at the edges.

pub mod arena;
pub mod payload;

pub use payload::Payload;

/// MPI datatype carried in the offload packet's `data_type` field.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Dtype {
    /// MPI_INT — the type the paper's multicast optimization requires.
    I32,
    /// MPI_FLOAT
    F32,
    /// MPI_DOUBLE
    F64,
}

impl Dtype {
    pub const ALL: [Dtype; 3] = [Dtype::I32, Dtype::F32, Dtype::F64];

    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            Dtype::I32 | Dtype::F32 => 4,
            Dtype::F64 => 8,
        }
    }

    /// Manifest / CLI name (matches the python side).
    pub fn name(self) -> &'static str {
        match self {
            Dtype::I32 => "i32",
            Dtype::F32 => "f32",
            Dtype::F64 => "f64",
        }
    }

    pub fn from_name(s: &str) -> Option<Dtype> {
        match s {
            "i32" | "int" | "MPI_INT" => Some(Dtype::I32),
            "f32" | "float" | "MPI_FLOAT" => Some(Dtype::F32),
            "f64" | "double" | "MPI_DOUBLE" => Some(Dtype::F64),
            _ => None,
        }
    }

    /// Wire enumeration for the packet's `data_type` field.
    pub fn wire_code(self) -> u16 {
        match self {
            Dtype::I32 => 1,
            Dtype::F32 => 2,
            Dtype::F64 => 3,
        }
    }

    pub fn from_wire(code: u16) -> Option<Dtype> {
        match code {
            1 => Some(Dtype::I32),
            2 => Some(Dtype::F32),
            3 => Some(Dtype::F64),
            _ => None,
        }
    }
}

/// MPI reduction op carried in the packet's `operation` field.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Op {
    Sum,
    Prod,
    Max,
    Min,
    /// Bitwise AND/OR/XOR — integer types only (like MPI_BAND etc).
    Band,
    Bor,
    Bxor,
}

impl Op {
    pub const ALL: [Op; 7] = [Op::Sum, Op::Prod, Op::Max, Op::Min, Op::Band, Op::Bor, Op::Bxor];

    pub fn name(self) -> &'static str {
        match self {
            Op::Sum => "sum",
            Op::Prod => "prod",
            Op::Max => "max",
            Op::Min => "min",
            Op::Band => "band",
            Op::Bor => "bor",
            Op::Bxor => "bxor",
        }
    }

    pub fn from_name(s: &str) -> Option<Op> {
        match s {
            "sum" | "MPI_SUM" => Some(Op::Sum),
            "prod" | "MPI_PROD" => Some(Op::Prod),
            "max" | "MPI_MAX" => Some(Op::Max),
            "min" | "MPI_MIN" => Some(Op::Min),
            "band" | "MPI_BAND" => Some(Op::Band),
            "bor" | "MPI_BOR" => Some(Op::Bor),
            "bxor" | "MPI_BXOR" => Some(Op::Bxor),
            _ => None,
        }
    }

    /// Bitwise ops are only defined on integer types.
    pub fn int_only(self) -> bool {
        matches!(self, Op::Band | Op::Bor | Op::Bxor)
    }

    pub fn valid_for(self, dt: Dtype) -> bool {
        !self.int_only() || dt == Dtype::I32
    }

    /// The paper's SSIII-C multicast optimization needs an exact inverse:
    /// only (MPI_SUM, MPI_INT) qualifies ("it is perfect for data type
    /// MPI_INT performing MPI_SUM, since subtraction is inverse of
    /// addition").
    pub fn invertible_for(self, dt: Dtype) -> bool {
        self == Op::Sum && dt == Dtype::I32
    }

    pub fn wire_code(self) -> u16 {
        match self {
            Op::Sum => 1,
            Op::Prod => 2,
            Op::Max => 3,
            Op::Min => 4,
            Op::Band => 5,
            Op::Bor => 6,
            Op::Bxor => 7,
        }
    }

    pub fn from_wire(code: u16) -> Option<Op> {
        Op::ALL.iter().copied().find(|o| o.wire_code() == code)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrip() {
        for dt in Dtype::ALL {
            assert_eq!(Dtype::from_wire(dt.wire_code()), Some(dt));
            assert_eq!(Dtype::from_name(dt.name()), Some(dt));
        }
        assert_eq!(Dtype::from_wire(0), None);
        assert_eq!(Dtype::from_name("i64"), None);
    }

    #[test]
    fn op_roundtrip() {
        for op in Op::ALL {
            assert_eq!(Op::from_wire(op.wire_code()), Some(op));
            assert_eq!(Op::from_name(op.name()), Some(op));
        }
        assert_eq!(Op::from_wire(99), None);
    }

    #[test]
    fn op_validity_matrix() {
        assert!(Op::Sum.valid_for(Dtype::F64));
        assert!(Op::Band.valid_for(Dtype::I32));
        assert!(!Op::Band.valid_for(Dtype::F32));
        assert!(Op::Sum.invertible_for(Dtype::I32));
        assert!(!Op::Sum.invertible_for(Dtype::F32), "float sum is not exactly invertible");
        assert!(!Op::Max.invertible_for(Dtype::I32), "max has no inverse");
    }

    #[test]
    fn mpi_aliases() {
        assert_eq!(Dtype::from_name("MPI_INT"), Some(Dtype::I32));
        assert_eq!(Op::from_name("MPI_SUM"), Some(Op::Sum));
    }
}
