//! The collective-offload packet format of the paper's Figure 1.
//!
//! The host informs the NetFPGA "which network-level state machine to
//! utilize" via a specially-crafted UDP datagram whose body starts with
//! this header.  All Fig. 1 fields are implemented: `comm_id`, `comm_size`,
//! `coll_type`, `algo_type`, `node_type`, `msg_type`, `rank`, `root`,
//! `operation`, `data_type`, `count` — plus two fragmentation fields and a
//! range `tag` used by the recursive-doubling multicast optimization
//! (SSIII-C, the "message tagging" of Fig. 3).
//!
//! The paper leaves `comm_id` unimplemented ("future work"); here it is
//! implemented as (communicator, epoch): the low half distinguishes
//! back-to-back invocations of the collective on the same communicator,
//! the high half distinguishes communicators (see `fpga::engine`).

use crate::data::{Dtype, Op, Payload};

/// Encoded size of the collective header in the UDP body.
pub const COLL_HDR_LEN: usize = 36;

/// `coll_type` enumeration.  The format is "intended to support a variety
/// of collective operations"; this reproduction implements Scan + Exscan
/// and enumerates the others the packet format reserves.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum CollType {
    Scan,
    Exscan,
    Barrier,
    Allreduce,
    Reduce,
    /// MPI_Bcast — handler-VM programs and the software baseline only
    /// (the paper's fixed-function datapath never implemented it).
    Bcast,
}

impl CollType {
    /// Every collective the handler VM ships a program for.
    pub const HANDLER_SET: [CollType; 5] = [
        CollType::Scan,
        CollType::Exscan,
        CollType::Allreduce,
        CollType::Bcast,
        CollType::Barrier,
    ];

    pub fn wire_code(self) -> u16 {
        match self {
            CollType::Scan => 1,
            CollType::Exscan => 2,
            CollType::Barrier => 3,
            CollType::Allreduce => 4,
            CollType::Reduce => 5,
            CollType::Bcast => 6,
        }
    }

    pub fn from_wire(v: u16) -> Option<Self> {
        match v {
            1 => Some(CollType::Scan),
            2 => Some(CollType::Exscan),
            3 => Some(CollType::Barrier),
            4 => Some(CollType::Allreduce),
            5 => Some(CollType::Reduce),
            6 => Some(CollType::Bcast),
            _ => None,
        }
    }

    /// CLI / grid-spec name.
    pub fn name(self) -> &'static str {
        match self {
            CollType::Scan => "scan",
            CollType::Exscan => "exscan",
            CollType::Barrier => "barrier",
            CollType::Allreduce => "allreduce",
            CollType::Reduce => "reduce",
            CollType::Bcast => "bcast",
        }
    }

    pub fn from_name(s: &str) -> Option<CollType> {
        match s {
            "scan" => Some(CollType::Scan),
            "exscan" => Some(CollType::Exscan),
            "barrier" => Some(CollType::Barrier),
            "allreduce" => Some(CollType::Allreduce),
            "reduce" => Some(CollType::Reduce),
            "bcast" => Some(CollType::Bcast),
            _ => None,
        }
    }

    /// Inclusive/exclusive scan — the only semantic difference between
    /// MPI_Scan and MPI_Exscan.
    pub fn inclusive(self) -> bool {
        matches!(self, CollType::Scan)
    }
}

/// `algo_type`: which hardware state machine runs the collective.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum AlgoType {
    /// Open MPI's default: rank j waits for j-1's partial, O(p) steps.
    Sequential,
    /// MPICH's default ("naive"): log2(p) pairwise exchange steps.
    RecursiveDoubling,
    /// Blelloch-style binomial tree: up-phase + down-phase.
    BinomialTree,
}

impl AlgoType {
    pub const ALL: [AlgoType; 3] =
        [AlgoType::Sequential, AlgoType::RecursiveDoubling, AlgoType::BinomialTree];

    pub fn wire_code(self) -> u16 {
        match self {
            AlgoType::Sequential => 1,
            AlgoType::RecursiveDoubling => 2,
            AlgoType::BinomialTree => 3,
        }
    }

    pub fn from_wire(v: u16) -> Option<Self> {
        match v {
            1 => Some(AlgoType::Sequential),
            2 => Some(AlgoType::RecursiveDoubling),
            3 => Some(AlgoType::BinomialTree),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AlgoType::Sequential => "sequential",
            AlgoType::RecursiveDoubling => "recursive_doubling",
            AlgoType::BinomialTree => "binomial_tree",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "sequential" | "seq" => Some(AlgoType::Sequential),
            "recursive_doubling" | "rd" => Some(AlgoType::RecursiveDoubling),
            "binomial_tree" | "binomial" | "tree" => Some(AlgoType::BinomialTree),
            _ => None,
        }
    }
}

/// `node_type`: the rank's pre-assigned role in the algorithm.  "The
/// node_type could be derived from the rank and comm_size fields in the
/// hardware, but for simplicity, we let the software assign node roles in
/// advance" — `offload::roles` does that assignment.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum NodeType {
    /// Recursive doubling: every rank runs the same machine.
    Generic,
    /// Sequential: rank 0 (sends first, receives nothing).
    Head,
    /// Sequential: interior rank.
    Mid,
    /// Sequential: rank p-1 (terminates the chain, no ACK awaited).
    Tail,
    /// Binomial: leaf (sends up once, waits for down-phase).
    Leaf,
    /// Binomial: internal node (buffers children, then up + down).
    Internal,
    /// Binomial: root (highest rank; turns the tree around).
    Root,
}

impl NodeType {
    pub fn wire_code(self) -> u16 {
        match self {
            NodeType::Generic => 0,
            NodeType::Head => 1,
            NodeType::Mid => 2,
            NodeType::Tail => 3,
            NodeType::Leaf => 4,
            NodeType::Internal => 5,
            NodeType::Root => 6,
        }
    }

    pub fn from_wire(v: u16) -> Option<Self> {
        match v {
            0 => Some(NodeType::Generic),
            1 => Some(NodeType::Head),
            2 => Some(NodeType::Mid),
            3 => Some(NodeType::Tail),
            4 => Some(NodeType::Leaf),
            5 => Some(NodeType::Internal),
            6 => Some(NodeType::Root),
            _ => None,
        }
    }
}

/// `msg_type`: "needed when NetFPGAs communicate between each other ...
/// what the packet means" — the metadata of inter-NIC packets.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MsgType {
    /// Host -> own NIC: offload this collective.
    HostRequest,
    /// NIC -> NIC: a partial-scan payload.
    Data,
    /// NIC -> NIC: flow-control acknowledgment (sequential algorithm,
    /// SSIII-B: rank j returns only after the ACK from rank j+1).
    Ack,
    /// NIC -> host: the rank's final scan outcome (+ elapsed time).
    Result,
    /// NIC -> NIC multicast: tagged cumulative payload covering a rank
    /// range (SSIII-C optimization); receivers may subtract their own
    /// cached contribution.
    CumTagged,
    /// NIC -> NIC: binomial down-phase prefix.
    Down,
}

impl MsgType {
    pub fn wire_code(self) -> u16 {
        match self {
            MsgType::HostRequest => 1,
            MsgType::Data => 2,
            MsgType::Ack => 3,
            MsgType::Result => 4,
            MsgType::CumTagged => 5,
            MsgType::Down => 6,
        }
    }

    pub fn from_wire(v: u16) -> Option<Self> {
        match v {
            1 => Some(MsgType::HostRequest),
            2 => Some(MsgType::Data),
            3 => Some(MsgType::Ack),
            4 => Some(MsgType::Result),
            5 => Some(MsgType::CumTagged),
            6 => Some(MsgType::Down),
            _ => None,
        }
    }
}

/// The decoded collective packet: Fig. 1 header + payload chunk.
#[derive(Clone, Debug)]
pub struct CollPacket {
    /// (communicator << 16) | epoch — see module docs.
    pub comm_id: u32,
    pub comm_size: u16,
    pub coll_type: CollType,
    pub algo_type: AlgoType,
    pub node_type: NodeType,
    pub msg_type: MsgType,
    /// Algorithm step this packet belongs to (recursive-doubling stage /
    /// tree level) — inter-NIC metadata like `msg_type`.
    pub step: u16,
    /// Sender rank (for HostRequest: the requesting rank).
    pub rank: u16,
    /// Unused for MPI_Scan (it has no root); kept per Fig. 1.
    pub root: u16,
    pub operation: Op,
    pub data_type: Dtype,
    /// Total element count of the *message* (not of this fragment).
    pub count: u32,
    /// Fragment index / total for messages larger than one MTU.
    pub frag_idx: u16,
    pub frag_total: u16,
    /// CumTagged: covered rank range, (lo | hi << 16).  Otherwise 0.
    pub tag: u32,
    /// This fragment's payload elements (empty for Ack).
    pub payload: Payload,
}

impl CollPacket {
    pub fn comm(&self) -> u16 {
        (self.comm_id >> 16) as u16
    }

    pub fn epoch(&self) -> u16 {
        (self.comm_id & 0xFFFF) as u16
    }

    pub fn make_comm_id(comm: u16, epoch: u16) -> u32 {
        ((comm as u32) << 16) | epoch as u32
    }

    /// Range covered by a CumTagged payload.
    pub fn tag_range(&self) -> (u16, u16) {
        ((self.tag & 0xFFFF) as u16, (self.tag >> 16) as u16)
    }

    pub fn make_tag(lo: u16, hi: u16) -> u32 {
        (lo as u32) | ((hi as u32) << 16)
    }

    /// Encoded UDP-body length (header + payload bytes).
    pub fn encoded_len(&self) -> usize {
        COLL_HDR_LEN + self.payload.byte_len()
    }

    /// Serialize to the UDP body (the exact on-wire layout of Fig. 1's
    /// collective fields, big-endian like the protocol headers).
    pub fn emit(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.comm_id.to_be_bytes());
        out.extend_from_slice(&self.comm_size.to_be_bytes());
        out.extend_from_slice(&self.coll_type.wire_code().to_be_bytes());
        out.extend_from_slice(&self.algo_type.wire_code().to_be_bytes());
        out.extend_from_slice(&self.node_type.wire_code().to_be_bytes());
        out.extend_from_slice(&self.msg_type.wire_code().to_be_bytes());
        out.extend_from_slice(&self.step.to_be_bytes());
        out.extend_from_slice(&self.rank.to_be_bytes());
        out.extend_from_slice(&self.root.to_be_bytes());
        out.extend_from_slice(&self.operation.wire_code().to_be_bytes());
        out.extend_from_slice(&self.data_type.wire_code().to_be_bytes());
        out.extend_from_slice(&self.count.to_be_bytes());
        out.extend_from_slice(&self.frag_idx.to_be_bytes());
        out.extend_from_slice(&self.frag_total.to_be_bytes());
        out.extend_from_slice(&self.tag.to_be_bytes());
        out.extend_from_slice(self.payload.bytes());
    }

    /// Parse a UDP body.  Returns None on any malformed field — the
    /// NetFPGA must never act on a packet it cannot fully decode.
    pub fn parse(b: &[u8]) -> Option<CollPacket> {
        if b.len() < COLL_HDR_LEN {
            return None;
        }
        let u16at = |i: usize| u16::from_be_bytes([b[i], b[i + 1]]);
        let u32at = |i: usize| u32::from_be_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]]);
        let data_type = Dtype::from_wire(u16at(22))?;
        let payload_bytes = &b[COLL_HDR_LEN..];
        if payload_bytes.len() % data_type.size() != 0 {
            return None;
        }
        Some(CollPacket {
            comm_id: u32at(0),
            comm_size: u16at(4),
            coll_type: CollType::from_wire(u16at(6))?,
            algo_type: AlgoType::from_wire(u16at(8))?,
            node_type: NodeType::from_wire(u16at(10))?,
            msg_type: MsgType::from_wire(u16at(12))?,
            step: u16at(14),
            rank: u16at(16),
            root: u16at(18),
            operation: Op::from_wire(u16at(20)).filter(|op| {
                // reject op/dtype pairs the hardware has no datapath for
                op.valid_for(data_type)
            })?,
            data_type,
            count: u32at(24),
            frag_idx: u16at(28),
            frag_total: u16at(30),
            tag: u32at(32),
            payload: Payload::from_bytes(data_type, payload_bytes.to_vec()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CollPacket {
        CollPacket {
            comm_id: CollPacket::make_comm_id(1, 42),
            comm_size: 8,
            coll_type: CollType::Scan,
            algo_type: AlgoType::RecursiveDoubling,
            node_type: NodeType::Generic,
            msg_type: MsgType::Data,
            step: 2,
            rank: 3,
            root: 0,
            operation: Op::Sum,
            data_type: Dtype::I32,
            count: 4,
            frag_idx: 0,
            frag_total: 1,
            tag: 0,
            payload: Payload::from_i32(&[1, 2, 3, 4]),
        }
    }

    #[test]
    fn emit_parse_roundtrip() {
        let pkt = sample();
        let mut buf = Vec::new();
        pkt.emit(&mut buf);
        assert_eq!(buf.len(), pkt.encoded_len());
        let back = CollPacket::parse(&buf).unwrap();
        assert_eq!(back.comm_id, pkt.comm_id);
        assert_eq!(back.algo_type, pkt.algo_type);
        assert_eq!(back.msg_type, pkt.msg_type);
        assert_eq!(back.step, pkt.step);
        assert_eq!(back.rank, pkt.rank);
        assert_eq!(back.payload, pkt.payload);
    }

    #[test]
    fn comm_epoch_packing() {
        let id = CollPacket::make_comm_id(7, 0xBEEF);
        let mut pkt = sample();
        pkt.comm_id = id;
        assert_eq!(pkt.comm(), 7);
        assert_eq!(pkt.epoch(), 0xBEEF);
    }

    #[test]
    fn tag_range_packing() {
        let mut pkt = sample();
        pkt.tag = CollPacket::make_tag(0, 1);
        assert_eq!(pkt.tag_range(), (0, 1));
    }

    #[test]
    fn truncated_rejected() {
        let pkt = sample();
        let mut buf = Vec::new();
        pkt.emit(&mut buf);
        assert!(CollPacket::parse(&buf[..COLL_HDR_LEN - 1]).is_none());
    }

    #[test]
    fn bad_enum_rejected() {
        let pkt = sample();
        let mut buf = Vec::new();
        pkt.emit(&mut buf);
        buf[7] = 99; // coll_type
        assert!(CollPacket::parse(&buf).is_none());
    }

    #[test]
    fn invalid_op_dtype_pair_rejected() {
        let mut pkt = sample();
        pkt.operation = Op::Band;
        pkt.data_type = Dtype::F32;
        pkt.payload = Payload::from_f32(&[1.0]);
        let mut buf = Vec::new();
        pkt.emit(&mut buf);
        assert!(CollPacket::parse(&buf).is_none(), "BAND on float has no hardware datapath");
    }

    #[test]
    fn misaligned_payload_rejected() {
        let pkt = sample();
        let mut buf = Vec::new();
        pkt.emit(&mut buf);
        buf.push(0xAB); // payload no longer multiple of 4
        assert!(CollPacket::parse(&buf).is_none());
    }
}
