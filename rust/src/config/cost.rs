//! The calibrated cost model: every place the simulation charges time.
//!
//! Defaults are 2014-era numbers for the paper's testbed (i5-2400 hosts,
//! 1 GbE, first-gen NetFPGA with an *unoptimized* host driver — the paper
//! explicitly notes it lacks zero-copy, interrupt coalescing, pre-allocated
//! buffers and memory registration).  The relative shapes of Figs. 4-7
//! depend on the ratios, not the absolute values; DESIGN.md documents the
//! calibration reasoning.

/// All tunable time constants.  Loaded from the `[cost]` section of an
/// experiment TOML (see `config::toml`), every field overridable.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    // ---- wire ----
    /// Link speed in bits/s (1 GbE).
    pub link_bandwidth_bps: u64,
    /// Propagation + PHY latency per hop, ns.
    pub link_prop_ns: u64,

    // ---- host software stack (the Open MPI / TCP baseline path) ----
    /// Fixed per-message send-side cost: syscall, TCP/IP stack, MPI
    /// matching.
    pub sw_send_overhead_ns: u64,
    /// Fixed per-message receive-side cost (interrupt, stack climb, MPI
    /// matching).
    pub sw_recv_overhead_ns: u64,
    /// Per-byte copy cost through the stack (user->kernel->wire and back).
    pub sw_copy_ns_per_byte: f64,
    /// Fixed cost of one reduction call on the host CPU.
    pub host_combine_base_ns: u64,
    /// Per-byte cost of the reduction on the host CPU.
    pub host_combine_ns_per_byte: f64,

    // ---- host <-> NetFPGA crossing (the unoptimized driver) ----
    /// Fixed cost to push an offload request down to the card.
    pub offload_crossing_ns: u64,
    /// Fixed cost for the result packet to climb back to user space.
    pub result_crossing_ns: u64,
    /// Per-byte DMA cost of either crossing.
    pub crossing_ns_per_byte: f64,

    // ---- NetFPGA datapath (125 MHz = 8 ns/cycle) ----
    /// Ingress-to-egress latency of the user-data-path pipeline, cycles.
    pub nic_pipeline_cycles: u64,
    /// Cycles to process 8 payload bytes in the combine datapath (64-bit
    /// adder at line rate = 1).
    pub nic_combine_cycles_per_8b: u64,
    /// Store-and-forward decision latency for transit (non-collective)
    /// frames, cycles.
    pub nic_fwd_cycles: u64,
    /// Cycles to generate one outgoing packet (header assembly, buffer
    /// hand-off).  A multicast generates ONE packet for many ports —
    /// "it does not need to generate separate messages for both ranks"
    /// (SSIII-C) — which is exactly the saving this constant surfaces.
    pub nic_pkt_gen_cycles: u64,

    // ---- handler VM (sPIN-style programmable per-packet programs) ----
    /// Cycles charged per executed VM instruction (the handler core runs
    /// in the same 125 MHz domain as the fixed-function pipeline).
    pub handler_instr_cycles: u64,
    /// Cycles per 8 payload bytes moved by the VM (scratchpad stores,
    /// frame emission, host delivery).  Combine work is charged through
    /// `nic_combine_cycles` — the VM's ALU IS the fixed-function
    /// datapath, so compute costs stay identical across both paths.
    pub handler_copy_cycles_per_8b: u64,
    /// Handler processing units per card (sPIN's bounded HPU pool).
    /// Each handler activation occupies one unit for its full duration;
    /// when all are busy, activations queue (FIFO within a flow,
    /// round-robin across flows) and the wait is charged as queueing
    /// delay.  0 = unconstrained: activations never queue, which keeps
    /// the pre-HPU event schedule byte-identical.
    pub hpus: u64,

    // ---- NIC reliability protocol (lossy runs only) ----
    /// Retransmit timer for an unacked reliable frame, ns.  Only armed
    /// when the fault plan is lossy (`loss > 0` or a drop schedule is
    /// set); fault-free runs schedule no timers at all.
    pub timeout_ns: u64,
    /// Retransmissions before the NIC gives up on a frame and the run
    /// fails with a named `(coll, rank, epoch)` error.
    pub max_retries: u32,
    /// Exponential backoff base: the nth retransmit timer is
    /// `timeout_ns * timeout_backoff^n`.
    pub timeout_backoff: f64,

    // ---- NIC liveness protocol (crash-scheduled runs only) ----
    /// Heartbeat probe period, ns.  Each rank's NIC monitors its
    /// communicator-ring successor; a probe is only sent when nothing
    /// has been heard from the peer for a full period (receptions and
    /// transport acks piggyback as liveness evidence).  Only armed when
    /// the fault plan schedules crashes; fault-free runs schedule no
    /// probe timers at all.
    pub probe_interval_ns: u64,
    /// Global no-progress watchdog, ns: if no rank completes an
    /// iteration for this long under an armed fault plan, the run fails
    /// with a named `watchdog:` error instead of hanging.  Sized well
    /// above the worst full retransmit-backoff chain.
    pub watchdog_ns: u64,

    // ---- inter-switch fabric (hierarchical topologies) ----
    /// Store-and-forward latency of one switch hop (lookup + buffer),
    /// ns.  Wire serialization and trunk contention are charged
    /// separately per port, so this is processing latency only.
    pub switch_fwd_ns: u64,

    // ---- benchmark driver ----
    /// Host compute gap between back-to-back MPI_Scan calls.
    pub host_call_gap_ns: u64,
    /// Max random skew of each rank's first call (uniform [0, jitter]).
    pub start_jitter_ns: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            link_bandwidth_bps: 1_000_000_000,
            link_prop_ns: 500,
            sw_send_overhead_ns: 20_000,
            sw_recv_overhead_ns: 20_000,
            sw_copy_ns_per_byte: 2.0,
            host_combine_base_ns: 500,
            host_combine_ns_per_byte: 0.5,
            offload_crossing_ns: 28_000,
            result_crossing_ns: 28_000,
            crossing_ns_per_byte: 4.0,
            nic_pipeline_cycles: 24,
            nic_combine_cycles_per_8b: 1,
            nic_fwd_cycles: 16,
            nic_pkt_gen_cycles: 12,
            handler_instr_cycles: 1,
            handler_copy_cycles_per_8b: 1,
            hpus: 0,
            timeout_ns: 100_000,
            max_retries: 3,
            timeout_backoff: 2.0,
            probe_interval_ns: 50_000,
            watchdog_ns: 500_000_000,
            switch_fwd_ns: 1_000,
            host_call_gap_ns: 2_000,
            start_jitter_ns: 5_000,
        }
    }
}

impl CostModel {
    /// Wire serialization time for `bytes` on-wire bytes (including frame
    /// overhead), ns.  1 GbE = 8 ns/byte.
    pub fn tx_ns(&self, wire_bytes: usize) -> u64 {
        let total = (wire_bytes + crate::net::WIRE_OVERHEAD_BYTES) as u64;
        total * 8_000_000_000 / self.link_bandwidth_bps
    }

    /// Retransmit timer for a frame that has already been retransmitted
    /// `retries` times (exponential backoff).
    pub fn retx_timeout_ns(&self, retries: u32) -> u64 {
        (self.timeout_ns as f64 * self.timeout_backoff.powi(retries as i32)).max(1.0) as u64
    }

    /// Host-side cost to hand one message of `bytes` to the stack.
    pub fn sw_send_ns(&self, bytes: usize) -> u64 {
        self.sw_send_overhead_ns + (bytes as f64 * self.sw_copy_ns_per_byte) as u64
    }

    /// Host-side cost to receive one message of `bytes` from the stack.
    pub fn sw_recv_ns(&self, bytes: usize) -> u64 {
        self.sw_recv_overhead_ns + (bytes as f64 * self.sw_copy_ns_per_byte) as u64
    }

    /// Host CPU reduction cost.
    pub fn host_combine_ns(&self, bytes: usize) -> u64 {
        self.host_combine_base_ns + (bytes as f64 * self.host_combine_ns_per_byte) as u64
    }

    /// Host -> NIC offload crossing for a request of `bytes` payload.
    pub fn offload_ns(&self, bytes: usize) -> u64 {
        self.offload_crossing_ns + (bytes as f64 * self.crossing_ns_per_byte) as u64
    }

    /// NIC -> host result crossing for `bytes` payload.
    pub fn result_ns(&self, bytes: usize) -> u64 {
        self.result_crossing_ns + (bytes as f64 * self.crossing_ns_per_byte) as u64
    }

    /// NetFPGA combine cycles for `bytes` of payload (64-bit datapath).
    pub fn nic_combine_cycles(&self, bytes: usize) -> u64 {
        (bytes as u64).div_ceil(8) * self.nic_combine_cycles_per_8b
    }

    /// Handler-VM cycles to move `bytes` of payload (store / emit /
    /// deliver through the 64-bit scratchpad port).
    pub fn handler_copy_cycles(&self, bytes: usize) -> u64 {
        (bytes as u64).div_ceil(8) * self.handler_copy_cycles_per_8b
    }

    /// Apply one `key = value` override from the `[cost]` TOML section.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        let as_u64 =
            || value.parse::<u64>().map_err(|e| format!("cost.{key}: bad integer: {e}"));
        let as_f64 =
            || value.parse::<f64>().map_err(|e| format!("cost.{key}: bad float: {e}"));
        match key {
            "link_bandwidth_bps" => self.link_bandwidth_bps = as_u64()?,
            "link_prop_ns" => self.link_prop_ns = as_u64()?,
            "sw_send_overhead_ns" => self.sw_send_overhead_ns = as_u64()?,
            "sw_recv_overhead_ns" => self.sw_recv_overhead_ns = as_u64()?,
            "sw_copy_ns_per_byte" => self.sw_copy_ns_per_byte = as_f64()?,
            "host_combine_base_ns" => self.host_combine_base_ns = as_u64()?,
            "host_combine_ns_per_byte" => self.host_combine_ns_per_byte = as_f64()?,
            "offload_crossing_ns" => self.offload_crossing_ns = as_u64()?,
            "result_crossing_ns" => self.result_crossing_ns = as_u64()?,
            "crossing_ns_per_byte" => self.crossing_ns_per_byte = as_f64()?,
            "nic_pipeline_cycles" => self.nic_pipeline_cycles = as_u64()?,
            "nic_combine_cycles_per_8b" => self.nic_combine_cycles_per_8b = as_u64()?,
            "nic_fwd_cycles" => self.nic_fwd_cycles = as_u64()?,
            "nic_pkt_gen_cycles" => self.nic_pkt_gen_cycles = as_u64()?,
            "handler_instr_cycles" => self.handler_instr_cycles = as_u64()?,
            "handler_copy_cycles_per_8b" => self.handler_copy_cycles_per_8b = as_u64()?,
            "hpus" => self.hpus = as_u64()?,
            "timeout_ns" => self.timeout_ns = as_u64()?,
            "max_retries" => {
                self.max_retries =
                    value.parse().map_err(|e| format!("cost.{key}: bad integer: {e}"))?
            }
            "timeout_backoff" => self.timeout_backoff = as_f64()?,
            "probe_interval_ns" => self.probe_interval_ns = as_u64()?,
            "watchdog_ns" => self.watchdog_ns = as_u64()?,
            "switch_fwd_ns" => self.switch_fwd_ns = as_u64()?,
            "host_call_gap_ns" => self.host_call_gap_ns = as_u64()?,
            "start_jitter_ns" => self.start_jitter_ns = as_u64()?,
            _ => return Err(format!("unknown cost key: {key}")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gbe_is_8ns_per_byte() {
        let c = CostModel::default();
        // 100 bytes + 24 overhead = 124 bytes = 992 ns
        assert_eq!(c.tx_ns(100), 992);
    }

    #[test]
    fn combine_cycles_line_rate() {
        let c = CostModel::default();
        assert_eq!(c.nic_combine_cycles(8), 1);
        assert_eq!(c.nic_combine_cycles(9), 2);
        assert_eq!(c.nic_combine_cycles(1432), 179);
    }

    #[test]
    fn crossing_dominated_by_fixed_cost_at_small_sizes() {
        let c = CostModel::default();
        assert!(c.offload_ns(4) < c.offload_ns(4096));
        assert!(c.offload_ns(4) > 28_000);
    }

    #[test]
    fn retx_backoff_is_exponential() {
        let mut c = CostModel::default();
        c.set("timeout_ns", "1000").unwrap();
        c.set("timeout_backoff", "2.0").unwrap();
        c.set("max_retries", "5").unwrap();
        assert_eq!(c.max_retries, 5);
        assert_eq!(c.retx_timeout_ns(0), 1000);
        assert_eq!(c.retx_timeout_ns(1), 2000);
        assert_eq!(c.retx_timeout_ns(3), 8000);
    }

    #[test]
    fn set_overrides() {
        let mut c = CostModel::default();
        c.set("link_prop_ns", "1000").unwrap();
        assert_eq!(c.link_prop_ns, 1000);
        c.set("sw_copy_ns_per_byte", "3.5").unwrap();
        assert_eq!(c.sw_copy_ns_per_byte, 3.5);
        assert!(c.set("bogus", "1").is_err());
        assert!(c.set("link_prop_ns", "abc").is_err());
    }
}
