//! A minimal TOML-subset parser (sections, key = value, comments).
//!
//! The offline build has no serde/toml crates, and experiment configs only
//! need flat `[section] key = value` files, so we parse exactly that:
//! bare/quoted strings, integers, floats, booleans, and single-line
//! arrays of scalars (`sizes = [4, 64, 1024]` — what grid specs need).
//! Anything fancier (nested arrays, tables-in-tables, dates) is rejected
//! loudly.

use std::collections::BTreeMap;

/// Parsed file: section -> key -> raw value string (quotes stripped).
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new(); // top-level
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() || name.contains('[') {
                    return Err(format!("line {}: bad section name", lineno + 1));
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = k.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = unquote(v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            let dup = doc
                .sections
                .entry(section.clone())
                .or_default()
                .insert(key.to_string(), value);
            if dup.is_some() {
                return Err(format!("line {}: duplicate key {key}", lineno + 1));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn section(&self, section: &str) -> impl Iterator<Item = (&str, &str)> {
        self.sections
            .get(section)
            .into_iter()
            .flat_map(|m| m.iter().map(|(k, v)| (k.as_str(), v.as_str())))
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>, String> {
        self.get(section, key)
            .map(|v| v.parse().map_err(|e| format!("{section}.{key}: {e}")))
            .transpose()
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Result<Option<u64>, String> {
        self.get(section, key)
            .map(|v| v.parse().map_err(|e| format!("{section}.{key}: {e}")))
            .transpose()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>, String> {
        self.get(section, key)
            .map(|v| match v {
                "true" => Ok(true),
                "false" => Ok(false),
                _ => Err(format!("{section}.{key}: expected true/false, got {v}")),
            })
            .transpose()
    }

    /// Read a key as a list of scalars.  Array values (`[a, "b", c]`)
    /// split on top-level commas with each element unquoted; a scalar
    /// value promotes to a one-element list (so grid axes accept both
    /// `p = 8` and `p = [4, 8]`).
    pub fn get_list(&self, section: &str, key: &str) -> Result<Option<Vec<String>>, String> {
        let Some(raw) = self.get(section, key) else {
            return Ok(None);
        };
        let Some(inner) = raw.strip_prefix('[') else {
            return Ok(Some(vec![raw.to_string()]));
        };
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("{section}.{key}: unterminated array"))?;
        let mut out = Vec::new();
        for item in split_top_level_commas(inner) {
            let item = item.trim();
            if item.is_empty() {
                // tolerate a trailing comma: [4, 64,]
                continue;
            }
            if item.starts_with('[') {
                return Err(format!("{section}.{key}: nested arrays not supported"));
            }
            out.push(unquote(item).map_err(|e| format!("{section}.{key}: {e}"))?);
        }
        Ok(Some(out))
    }
}

/// Split on commas that sit outside quoted strings.
fn split_top_level_commas(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Remove a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Strip surrounding quotes from a string value; reject unsupported TOML.
/// Arrays are stored raw (brackets kept) and split lazily by `get_list`.
fn unquote(v: &str) -> Result<String, String> {
    if v.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = v.strip_prefix('"') {
        return inner
            .strip_suffix('"')
            .map(|s| s.to_string())
            .ok_or_else(|| "unterminated string".into());
    }
    if v.starts_with('[') {
        if !v.ends_with(']') {
            return Err("unterminated array (arrays must be single-line)".into());
        }
        return Ok(v.to_string());
    }
    if v.starts_with('{') {
        return Err("inline tables not supported by the mini parser".into());
    }
    Ok(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_values() {
        let doc = TomlDoc::parse(
            r#"
            # experiment
            top = 1
            [run]
            p = 8
            algo = "recursive_doubling"  # inline comment
            offloaded = true
            [cost]
            sw_copy_ns_per_byte = 2.5
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some("1"));
        assert_eq!(doc.get_usize("run", "p").unwrap(), Some(8));
        assert_eq!(doc.get("run", "algo"), Some("recursive_doubling"));
        assert_eq!(doc.get_bool("run", "offloaded").unwrap(), Some(true));
        assert_eq!(doc.get("cost", "sw_copy_ns_per_byte"), Some("2.5"));
        assert_eq!(doc.get("run", "missing"), None);
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse("name = \"a#b\"").unwrap();
        assert_eq!(doc.get("", "name"), Some("a#b"));
    }

    #[test]
    fn errors_are_loud() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = {a = 1}").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err(), "multi-line arrays rejected");
    }

    #[test]
    fn arrays_parse_and_split() {
        let doc = TomlDoc::parse(
            r#"
            [grid]
            sizes = [4, 64, 1024]
            series = ["sw_seq", "NF_rd"]
            trailing = [1, 2,]
            empty = []
            scalar = 8
            tricky = ["a,b", "c"]
            "#,
        )
        .unwrap();
        assert_eq!(
            doc.get_list("grid", "sizes").unwrap().unwrap(),
            vec!["4", "64", "1024"]
        );
        assert_eq!(
            doc.get_list("grid", "series").unwrap().unwrap(),
            vec!["sw_seq", "NF_rd"]
        );
        assert_eq!(doc.get_list("grid", "trailing").unwrap().unwrap(), vec!["1", "2"]);
        assert!(doc.get_list("grid", "empty").unwrap().unwrap().is_empty());
        assert_eq!(doc.get_list("grid", "scalar").unwrap().unwrap(), vec!["8"]);
        assert_eq!(doc.get_list("grid", "tricky").unwrap().unwrap(), vec!["a,b", "c"]);
        assert_eq!(doc.get_list("grid", "missing").unwrap(), None);
    }

    #[test]
    fn nested_arrays_rejected() {
        let doc = TomlDoc::parse("k = [[1], [2]]").unwrap();
        assert!(doc.get_list("", "k").is_err());
    }

    #[test]
    fn section_iteration_sorted() {
        let doc = TomlDoc::parse("[s]\nb = 2\na = 1").unwrap();
        let kv: Vec<_> = doc.section("s").collect();
        assert_eq!(kv, vec![("a", "1"), ("b", "2")]);
    }
}
