//! Experiment configuration: the cost model plus everything a single
//! simulated run needs (cluster size, algorithm, path, workload).
//!
//! Two views of the same knobs exist: the flat [`ExpConfig`] every
//! existing entry point consumes, and the split
//! [`FabricConfig`]/[`WorkloadSpec`] pair (`workload` module) the
//! multi-tenant [`crate::cluster::Session`] builder composes per tenant.

pub mod cost;
pub mod toml;
pub mod workload;

pub use cost::CostModel;
pub use toml::TomlDoc;
pub use workload::{FabricConfig, WorkloadSpec};

use crate::data::{Dtype, Op};
use crate::packet::{AlgoType, CollType};

/// Which compute engine executes payload reductions.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum EngineKind {
    /// Pure-Rust reference path (always available; used by unit tests and
    /// as the ablation baseline).
    Native,
    /// Compiled HLO artifacts via PJRT (the production hot path); falls
    /// back per-op to native when an artifact is missing.
    Xla,
}

impl EngineKind {
    pub fn from_name(s: &str) -> Option<EngineKind> {
        match s {
            "native" => Some(EngineKind::Native),
            "xla" => Some(EngineKind::Xla),
            _ => None,
        }
    }
}

/// Which execution path runs the collective.  Replaces the old
/// `offloaded: bool` + `handler: bool` pair (whose "handler implies
/// offloaded" coupling was a recurring footgun) with one field that
/// mirrors the `Series` path naming.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ExecPath {
    /// Software MPI baseline: the host stack runs the algorithm.
    Sw,
    /// Fixed-function NetFPGA offload (the paper's NF_ path).
    Fpga,
    /// Offload via the programmable handler VM (`nic::vm`) — sPIN-style
    /// packet programs instead of fixed-function state machines.
    Handler,
}

impl ExecPath {
    pub fn from_name(s: &str) -> Option<ExecPath> {
        match s {
            "sw" => Some(ExecPath::Sw),
            "fpga" => Some(ExecPath::Fpga),
            "handler" => Some(ExecPath::Handler),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ExecPath::Sw => "sw",
            ExecPath::Fpga => "fpga",
            ExecPath::Handler => "handler",
        }
    }

    /// Does this path cross into the NIC?  (Both offload flavors do.)
    pub fn offloaded(&self) -> bool {
        !matches!(self, ExecPath::Sw)
    }

    /// Does this path run handler-VM programs on the NIC?
    pub fn handler(&self) -> bool {
        matches!(self, ExecPath::Handler)
    }
}

/// Full description of one simulated experiment.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Number of ranks (the paper's cluster: 8).
    pub p: usize,
    /// Scan algorithm under test.
    pub algo: AlgoType,
    /// Execution path: software baseline, fixed-function NetFPGA offload,
    /// or the programmable handler VM.
    pub path: ExecPath,
    /// Topology spec: `chain`/`ring`/`hypercube` (direct NetFPGA-to-
    /// NetFPGA wirings), `star[:group]`/`fattree[:k]` (hierarchical
    /// multi-switch fabrics that scale past one 4-port card per host),
    /// or `"auto"` to pick the direct wiring the algorithm wants (the
    /// paper's manually-configured testbed).
    pub topology: String,
    /// Message size in bytes per rank.
    pub msg_bytes: usize,
    /// Measured back-to-back iterations (the paper runs 10M; simulated
    /// runs converge far earlier).
    pub iters: usize,
    /// Unmeasured warmup iterations (fills the sequential pipeline).
    pub warmup: usize,
    pub coll: CollType,
    pub op: Op,
    pub dtype: Dtype,
    pub seed: u64,
    pub engine: EngineKind,
    /// Verify every rank's result against the oracle (tests; off in
    /// perf benches).
    pub verify: bool,
    /// Recursive-doubling multicast + inverse-subtract optimization
    /// (SSIII-C); ablation benches switch it off.
    pub multicast_opt: bool,
    /// Sequential ACK flow control (SSIII-B); the ablation that shows why
    /// the paper needs it (disabling overflows the single NIC buffer).
    pub ack_enabled: bool,
    /// Delay one rank's first call (Fig. 3 late-rank scenarios; the
    /// fault model's straggler knob — a fabric-level setting and sweep
    /// axis, not just the `late_rank` example's private flag).
    pub late_rank: Option<usize>,
    pub late_delay_ns: u64,
    /// Per-hop packet loss probability in [0, 1) for the hostile-network
    /// fault model (`net::fault`).  Any nonzero value arms the NIC
    /// timeout/retransmit protocol; 0 keeps the event schedule and wire
    /// format byte-identical to a fault-free run.
    pub loss: f64,
    /// Deterministic drop schedule, `"src->dst:nth"` rules (see
    /// `net::fault::parse_drop_spec`); empty = none.  A nonempty
    /// schedule arms the retransmit protocol like `loss > 0`.
    pub drop_spec: String,
    /// Trunk (switch-node) bandwidth degradation multiplier: >= 1.0
    /// scales switch transmission time.  1.0 = full rate, never applied.
    pub trunk_degrade: f64,
    /// Fail-stop crash schedule, `"rank:R@epoch:E, switch:S@ns:T"` rules
    /// (see `net::fault::parse_crash_spec`); empty = none.  A nonempty
    /// schedule arms the retransmit protocol, heartbeat probes and the
    /// degrade-don't-hang recovery machinery.
    pub crash_spec: String,
    /// Deterministic frame-corruption schedule, same `"src->dst:nth"`
    /// rule syntax as `drop`; corrupted frames are delivered, fail the
    /// receiver's CRC check, and are recovered by retransmission.
    pub corrupt_spec: String,
    /// Deterministic frame-reordering schedule, same `"src->dst:nth"`
    /// rule syntax as `drop`; reordered frames are delivered late,
    /// behind frames transmitted after them.
    pub reorder_spec: String,
    /// Number of tenants — disjoint communicators running concurrent
    /// collective streams on the shared network (the paper's SSVI comm_id
    /// future work).  Ranks split into `tenants` contiguous groups of
    /// p/tenants.  Heterogeneous tenants go through
    /// [`crate::cluster::Session`] instead.
    pub tenants: usize,
    /// Background point-to-point flows sharing the fabric (0 = off).
    /// Each flow picks a seeded (src, dst) pair and injects
    /// `bg_msgs` frames of `bg_bytes` spaced `bg_gap_ns` apart.
    pub bg_flows: usize,
    /// Frames per background flow.
    pub bg_msgs: u64,
    /// Payload bytes per background frame.
    pub bg_bytes: usize,
    /// Inter-frame gap per background flow (ns).
    pub bg_gap_ns: u64,
    /// Latency attribution: account every measured nanosecond to one of
    /// the breakdown components (wire / switch-queue / hpu-queue /
    /// handler-exec / compute / recovery / host) and emit them in run
    /// metrics and artifacts.  Off by default: disabled attribution is
    /// zero-cost and leaves artifact bytes identical to pre-attribution
    /// builds.
    pub attribution: bool,
    pub cost: CostModel,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            p: 8,
            algo: AlgoType::RecursiveDoubling,
            path: ExecPath::Fpga,
            topology: "auto".into(),
            msg_bytes: 4,
            iters: 1000,
            warmup: 32,
            coll: CollType::Scan,
            op: Op::Sum,
            dtype: Dtype::I32,
            seed: 0x4E46_5343414E, // "NFSCAN"
            engine: EngineKind::Native,
            verify: false,
            multicast_opt: true,
            ack_enabled: true,
            late_rank: None,
            late_delay_ns: 0,
            loss: 0.0,
            drop_spec: String::new(),
            trunk_degrade: 1.0,
            crash_spec: String::new(),
            corrupt_spec: String::new(),
            reorder_spec: String::new(),
            tenants: 1,
            bg_flows: 0,
            bg_msgs: 200,
            bg_bytes: 1024,
            bg_gap_ns: 20_000,
            attribution: false,
            cost: CostModel::default(),
        }
    }
}

impl ExpConfig {
    /// Does this experiment cross into the NIC?
    pub fn offloaded(&self) -> bool {
        self.path.offloaded()
    }

    /// Does this experiment run handler-VM programs?
    pub fn handler(&self) -> bool {
        self.path.handler()
    }

    /// Elements per rank for the configured message size.
    pub fn msg_elems(&self) -> usize {
        (self.msg_bytes / self.dtype.size()).max(1)
    }

    /// Ranks per tenant communicator.
    pub fn group_size(&self) -> usize {
        self.p / self.tenants
    }

    /// (communicator id, base global rank, group size) of a global rank
    /// under the homogeneous contiguous split.
    pub fn comm_of(&self, rank: usize) -> (u16, usize, usize) {
        let g = self.group_size();
        ((rank / g) as u16, rank / g * g, g)
    }

    /// The spec [`ExpConfig::resolve_topology`] will build: "auto"
    /// resolves to each algorithm's natural direct wiring (the paper
    /// pre-wires the testbed per algorithm — §VI "manual configuration").
    pub fn topology_spec(&self) -> &str {
        if self.topology == "auto" {
            match self.algo {
                AlgoType::Sequential => "chain",
                AlgoType::RecursiveDoubling | AlgoType::BinomialTree => "hypercube",
            }
        } else {
            &self.topology
        }
    }

    /// The topology this experiment actually runs on.
    pub fn resolve_topology(&self) -> crate::net::Topology {
        let name = self.topology_spec();
        crate::net::Topology::build(name, self.p)
            .unwrap_or_else(|e| panic!("topology {name} for p={}: {e}", self.p))
    }

    /// Parse an experiment TOML ([run] + [cost] sections).
    pub fn from_toml(text: &str) -> Result<ExpConfig, String> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ExpConfig::default();
        for (k, v) in doc.section("run") {
            cfg.set_run(k, v)?;
        }
        for (k, v) in doc.section("cost") {
            cfg.cost.set(k, v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply one `[run]` key.  `offloaded`/`handler`/`comms` remain as
    /// aliases for configs and flags written before the `path`/`tenants`
    /// redesign.
    pub fn set_run(&mut self, key: &str, v: &str) -> Result<(), String> {
        match key {
            "p" => self.p = v.parse().map_err(|e| format!("run.p: {e}"))?,
            "algo" => {
                self.algo =
                    AlgoType::from_name(v).ok_or_else(|| format!("run.algo: unknown {v}"))?
            }
            "path" => {
                self.path =
                    ExecPath::from_name(v).ok_or_else(|| format!("run.path: unknown {v}"))?
            }
            "offloaded" => {
                // legacy alias: true selects an offload path without
                // downgrading an already-selected Handler
                let b: bool = v.parse().map_err(|e| format!("run.offloaded: {e}"))?;
                self.path = match (b, self.path) {
                    (true, ExecPath::Sw) => ExecPath::Fpga,
                    (true, other) => other,
                    (false, _) => ExecPath::Sw,
                };
            }
            "handler" => {
                // legacy alias: true selects the handler VM (which is an
                // offload path by construction — the old footgun is gone)
                let b: bool = v.parse().map_err(|e| format!("run.handler: {e}"))?;
                self.path = match (b, self.path) {
                    (true, _) => ExecPath::Handler,
                    (false, ExecPath::Handler) => ExecPath::Fpga,
                    (false, other) => other,
                };
            }
            "topology" => self.topology = v.to_string(),
            "msg_bytes" => {
                self.msg_bytes = v.parse().map_err(|e| format!("run.msg_bytes: {e}"))?
            }
            "iters" => self.iters = v.parse().map_err(|e| format!("run.iters: {e}"))?,
            "warmup" => self.warmup = v.parse().map_err(|e| format!("run.warmup: {e}"))?,
            "coll" => {
                self.coll =
                    CollType::from_name(v).ok_or_else(|| format!("run.coll: unknown {v}"))?
            }
            "op" => self.op = Op::from_name(v).ok_or_else(|| format!("run.op: unknown {v}"))?,
            "dtype" => {
                self.dtype =
                    Dtype::from_name(v).ok_or_else(|| format!("run.dtype: unknown {v}"))?
            }
            "seed" => self.seed = v.parse().map_err(|e| format!("run.seed: {e}"))?,
            "engine" => {
                self.engine =
                    EngineKind::from_name(v).ok_or_else(|| format!("run.engine: unknown {v}"))?
            }
            "verify" => self.verify = v.parse().map_err(|e| format!("run.verify: {e}"))?,
            "multicast_opt" => {
                self.multicast_opt = v.parse().map_err(|e| format!("run.multicast_opt: {e}"))?
            }
            "ack_enabled" => {
                self.ack_enabled = v.parse().map_err(|e| format!("run.ack_enabled: {e}"))?
            }
            "late_rank" => {
                // "none" clears the straggler (the late_rank sweep axis
                // uses it for its baseline cells)
                self.late_rank = match v {
                    "none" => None,
                    _ => Some(v.parse().map_err(|e| format!("run.late_rank: {e}"))?),
                }
            }
            "late_delay_ns" => {
                self.late_delay_ns = v.parse().map_err(|e| format!("run.late_delay_ns: {e}"))?
            }
            "loss" => self.loss = v.parse().map_err(|e| format!("run.loss: {e}"))?,
            "drop" => self.drop_spec = v.to_string(),
            "crash" => self.crash_spec = v.to_string(),
            "corrupt" => self.corrupt_spec = v.to_string(),
            "reorder" => self.reorder_spec = v.to_string(),
            "trunk_degrade" => {
                self.trunk_degrade =
                    v.parse().map_err(|e| format!("run.trunk_degrade: {e}"))?
            }
            "tenants" => self.tenants = v.parse().map_err(|e| format!("run.tenants: {e}"))?,
            "comms" => self.tenants = v.parse().map_err(|e| format!("run.comms: {e}"))?,
            "bg_flows" => self.bg_flows = v.parse().map_err(|e| format!("run.bg_flows: {e}"))?,
            "bg_msgs" => self.bg_msgs = v.parse().map_err(|e| format!("run.bg_msgs: {e}"))?,
            "bg_bytes" => self.bg_bytes = v.parse().map_err(|e| format!("run.bg_bytes: {e}"))?,
            "bg_gap_ns" => {
                self.bg_gap_ns = v.parse().map_err(|e| format!("run.bg_gap_ns: {e}"))?
            }
            "attribution" => {
                self.attribution = v.parse().map_err(|e| format!("run.attribution: {e}"))?
            }
            _ => {
                // every [cost] knob doubles as a run key, so flags like
                // --hpus or --timeout_ns work without a [cost] section
                self.cost.set(key, v).map_err(|e| match e.starts_with("unknown cost key") {
                    true => format!("unknown run key: {key}"),
                    false => e,
                })?
            }
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.p < 2 {
            return Err("p must be >= 2".into());
        }
        if self.tenants == 0 || self.p % self.tenants != 0 {
            return Err(format!("tenants {} must divide p {}", self.tenants, self.p));
        }
        let group = self.p / self.tenants;
        if group < 2 {
            return Err("each tenant needs >= 2 ranks".into());
        }
        if !crate::util::is_pow2(group)
            && matches!(self.algo, AlgoType::RecursiveDoubling | AlgoType::BinomialTree)
        {
            return Err(format!(
                "{} requires power-of-two ranks per tenant (paper section II-B), got {group}",
                self.algo.name()
            ));
        }
        if !self.op.valid_for(self.dtype) {
            return Err(format!("{} invalid for {}", self.op.name(), self.dtype.name()));
        }
        if self.msg_bytes % self.dtype.size() != 0 {
            return Err(format!(
                "msg_bytes {} not a multiple of element size {}",
                self.msg_bytes,
                self.dtype.size()
            ));
        }
        // fragment budget: the streaming reassembler's seen-bitmap caps
        // fragments per message; reject here, at config time, instead of
        // panicking mid-run at the card
        let chunk_elems = crate::net::frame::CHUNK_BYTES / self.dtype.size();
        let frags = self.msg_elems().div_ceil(chunk_elems);
        if frags > crate::fpga::reassembly::MAX_FRAGS_PER_MSG {
            return Err(format!(
                "msg_bytes {} needs {frags} MTU fragments, over the {}-fragment reassembly \
                 budget (max ~{} bytes)",
                self.msg_bytes,
                crate::fpga::reassembly::MAX_FRAGS_PER_MSG,
                crate::fpga::reassembly::MAX_FRAGS_PER_MSG * crate::net::frame::CHUNK_BYTES
            ));
        }
        if self.iters == 0 {
            return Err("iters must be > 0".into());
        }
        if self.bg_flows > 0 && self.bg_gap_ns == 0 {
            return Err("bg_gap_ns must be > 0 when background flows are on".into());
        }
        // fault knobs: build (and discard) the plan so bad loss rates and
        // malformed drop/crash/corrupt/reorder schedules fail at config
        // time, with the rule text
        let plan = crate::net::FaultPlan::new(
            self.loss,
            &self.drop_spec,
            self.trunk_degrade,
            self.seed,
        )
        .and_then(|p| p.with_failures(&self.crash_spec, &self.corrupt_spec, &self.reorder_spec))
        .map_err(|e| format!("fault: {e}"))?;
        if plan.lossy() {
            if self.cost.timeout_ns == 0 {
                return Err("cost.timeout_ns must be > 0 on lossy runs".into());
            }
            if self.cost.timeout_backoff < 1.0 {
                return Err(format!(
                    "cost.timeout_backoff {} must be >= 1.0",
                    self.cost.timeout_backoff
                ));
            }
        }
        if plan.has_crashes() && self.cost.probe_interval_ns == 0 {
            return Err("cost.probe_interval_ns must be > 0 when crashes are scheduled".into());
        }
        if let Some(r) = plan.max_crash_rank() {
            if r >= self.p {
                return Err(format!("crash: rank {r} out of range (p = {})", self.p));
            }
        }
        // build (and discard) the resolved wiring so bad specs fail at
        // config time with the cell that owns them, not mid-sweep —
        // "auto" included: it resolves to a hypercube whose p constraint
        // (power of two over the WHOLE cluster, not per tenant)
        // is stricter than the group check above
        let topo = crate::net::Topology::build(self.topology_spec(), self.p)
            .map_err(|e| format!("topology: {e}"))?;
        if let Some(s) = plan.max_crash_switch() {
            if s >= topo.switches() {
                return Err(format!(
                    "crash: switch {s} out of range ({} has {} switches)",
                    topo.name(),
                    topo.switches()
                ));
            }
        }
        if self.handler() && !crate::util::is_pow2(group) {
            return Err(format!(
                "handler programs need power-of-two ranks per tenant, got {group}"
            ));
        }
        match self.coll {
            CollType::Allreduce | CollType::Barrier => {
                if self.algo == AlgoType::Sequential && !self.handler() {
                    return Err(format!(
                        "{:?} has no sequential machine; use rd or binomial",
                        self.coll
                    ));
                }
                if !crate::util::is_pow2(group) {
                    return Err(format!("{:?} requires power-of-two ranks", self.coll));
                }
            }
            CollType::Bcast => {
                if self.path == ExecPath::Fpga {
                    return Err(
                        "MPI_Bcast has no fixed-function machine; offload it via the \
                         handler VM (series handler:bcast / --path handler) or run the \
                         software path"
                            .into(),
                    );
                }
                if !crate::util::is_pow2(group) {
                    return Err("bcast requires power-of-two ranks".into());
                }
            }
            CollType::Reduce => return Err("MPI_Reduce not implemented".into()),
            _ => {}
        }
        Ok(())
    }

    /// Build this run's fault plan (panics on knobs `validate` rejects).
    pub fn fault_plan(&self) -> crate::net::FaultPlan {
        crate::net::FaultPlan::new(self.loss, &self.drop_spec, self.trunk_degrade, self.seed)
            .and_then(|p| {
                p.with_failures(&self.crash_spec, &self.corrupt_spec, &self.reorder_spec)
            })
            .expect("fault knobs were validated")
    }

    /// Short tag for tables: "NF_rd" / "sw_seq" style (paper's naming);
    /// the handler VM path is named by its collective ("handler:exscan").
    pub fn series_name(&self) -> String {
        if self.handler() {
            return format!("handler:{}", self.coll.name());
        }
        let prefix = if self.offloaded() { "NF" } else { "sw" };
        let algo = match self.algo {
            AlgoType::Sequential => "seq",
            AlgoType::RecursiveDoubling => "rd",
            AlgoType::BinomialTree => "binomial",
        };
        format!("{prefix}_{algo}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExpConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = ExpConfig::from_toml(
            r#"
            [run]
            p = 16
            algo = "binomial"
            offloaded = false
            msg_bytes = 64
            dtype = "f64"
            op = "max"
            iters = 10
            [cost]
            link_prop_ns = 700
            "#,
        )
        .unwrap();
        assert_eq!(cfg.p, 16);
        assert_eq!(cfg.algo, AlgoType::BinomialTree);
        assert_eq!(cfg.path, ExecPath::Sw);
        assert!(!cfg.offloaded());
        assert_eq!(cfg.msg_elems(), 8);
        assert_eq!(cfg.cost.link_prop_ns, 700);
        assert_eq!(cfg.series_name(), "sw_binomial");
    }

    #[test]
    fn path_key_and_legacy_aliases_agree() {
        let mut cfg = ExpConfig::default();
        cfg.set_run("path", "handler").unwrap();
        assert_eq!(cfg.path, ExecPath::Handler);
        assert!(cfg.offloaded() && cfg.handler());
        // legacy "offloaded = true" must not downgrade Handler to Fpga
        cfg.set_run("offloaded", "true").unwrap();
        assert_eq!(cfg.path, ExecPath::Handler);
        cfg.set_run("handler", "false").unwrap();
        assert_eq!(cfg.path, ExecPath::Fpga);
        cfg.set_run("offloaded", "false").unwrap();
        assert_eq!(cfg.path, ExecPath::Sw);
        cfg.set_run("handler", "true").unwrap();
        assert_eq!(cfg.path, ExecPath::Handler, "handler alias implies offload");
        assert!(cfg.set_run("path", "warp").is_err());
    }

    #[test]
    fn validation_rejects_bad_combinations() {
        let mut cfg = ExpConfig::default();
        cfg.p = 6;
        assert!(cfg.validate().is_err(), "rd needs power of two");
        cfg.algo = AlgoType::Sequential;
        assert!(cfg.validate().is_ok(), "sequential handles any p");
        cfg.op = Op::Band;
        cfg.dtype = Dtype::F32;
        assert!(cfg.validate().is_err());
        cfg = ExpConfig::default();
        cfg.msg_bytes = 7;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_rejects_over_budget_fragmentation() {
        let mut cfg = ExpConfig::default();
        cfg.msg_bytes = 1 << 20; // ~733 fragments: over the 128-frag budget
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("fragment"), "{err}");
        cfg.msg_bytes = 16384; // 12 fragments: fine
        cfg.validate().unwrap();
    }

    #[test]
    fn auto_topology_matches_algorithm() {
        let mut cfg = ExpConfig::default();
        cfg.algo = AlgoType::Sequential;
        assert_eq!(cfg.resolve_topology().name(), "chain");
        cfg.algo = AlgoType::RecursiveDoubling;
        assert_eq!(cfg.resolve_topology().name(), "hypercube");
        cfg.topology = "ring".into();
        assert_eq!(cfg.resolve_topology().name(), "ring");
    }

    #[test]
    fn hierarchical_topologies_validate() {
        let mut cfg = ExpConfig::default();
        cfg.topology = "fattree".into();
        cfg.validate().unwrap();
        assert_eq!(cfg.resolve_topology().name(), "fattree:4");
        cfg.topology = "star:2".into();
        cfg.validate().unwrap();
        cfg.topology = "fattree:3".into();
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("even"), "{err}");
        cfg.topology = "warp".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn handler_validation() {
        let mut cfg = ExpConfig::default();
        cfg.path = ExecPath::Handler;
        cfg.validate().unwrap();
        assert_eq!(cfg.series_name(), "handler:scan");
        cfg.coll = CollType::Bcast;
        cfg.validate().unwrap();
        assert_eq!(cfg.series_name(), "handler:bcast");
        cfg.path = ExecPath::Fpga;
        assert!(cfg.validate().is_err(), "bcast offload needs the handler VM");
        cfg.path = ExecPath::Sw;
        cfg.validate().unwrap();

        let mut cfg = ExpConfig::default();
        cfg.path = ExecPath::Handler;
        cfg.algo = AlgoType::Sequential;
        cfg.p = 6;
        assert!(cfg.validate().is_err(), "handler programs need power-of-two groups");
    }

    #[test]
    fn tenant_validation() {
        let mut cfg = ExpConfig::default();
        cfg.tenants = 3;
        assert!(cfg.validate().is_err(), "3 does not divide 8");
        cfg.tenants = 8;
        assert!(cfg.validate().is_err(), "groups of 1 are not a collective");
        cfg.tenants = 2;
        cfg.validate().unwrap();
        cfg.set_run("comms", "4").unwrap();
        assert_eq!(cfg.tenants, 4, "legacy comms key still lands on tenants");
        cfg.bg_flows = 2;
        cfg.bg_gap_ns = 0;
        assert!(cfg.validate().is_err(), "flows need a positive gap");
    }

    #[test]
    fn fault_knobs_parse_and_validate() {
        let cfg = ExpConfig::from_toml(
            r#"
            [run]
            loss = 0.05
            drop = ["0->1:1", "2->*:3"]
            trunk_degrade = 2.0
            late_rank = 3
            late_delay_ns = 100000
            [cost]
            timeout_ns = 50000
            max_retries = 5
            timeout_backoff = 1.5
            "#,
        )
        .unwrap();
        assert_eq!(cfg.loss, 0.05);
        assert_eq!(cfg.trunk_degrade, 2.0);
        assert_eq!(cfg.cost.max_retries, 5);
        let plan = cfg.fault_plan();
        assert!(plan.lossy() && plan.degrades());

        let mut bad = ExpConfig::default();
        bad.loss = 1.5;
        assert!(bad.validate().is_err(), "loss over 1 rejected");
        let mut bad = ExpConfig::default();
        bad.drop_spec = "nonsense".into();
        let err = bad.validate().unwrap_err();
        assert!(err.contains("drop rule"), "{err}");
        let mut bad = ExpConfig::default();
        bad.loss = 0.1;
        bad.cost.timeout_ns = 0;
        assert!(bad.validate().is_err(), "lossy runs need a timeout");
    }

    #[test]
    fn crash_corrupt_reorder_knobs_parse_and_validate() {
        let cfg = ExpConfig::from_toml(
            r#"
            [run]
            topology = "fattree"
            crash = ["rank:3@epoch:2", "switch:1@ns:5000"]
            corrupt = "0->1:2"
            reorder = ["2->*:1"]
            [cost]
            max_retries = 6
            "#,
        )
        .unwrap();
        let plan = cfg.fault_plan();
        assert!(plan.lossy() && plan.has_crashes());
        assert_eq!(plan.rank_crash_epoch(3), Some(2));
        assert_eq!(plan.switch_crashes(), vec![(1, 5000)]);

        let mut bad = ExpConfig::default();
        bad.crash_spec = "rank:9@epoch:1".into();
        let err = bad.validate().unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        let mut bad = ExpConfig::default();
        bad.crash_spec = "switch:0@ns:100".into();
        let err = bad.validate().unwrap_err();
        assert!(err.contains("switch"), "hypercube has no switches: {err}");
        let mut bad = ExpConfig::default();
        bad.corrupt_spec = "nonsense".into();
        let err = bad.validate().unwrap_err();
        assert!(err.contains("corrupt rule"), "{err}");
        let mut bad = ExpConfig::default();
        bad.reorder_spec = "0->1:0".into();
        let err = bad.validate().unwrap_err();
        assert!(err.contains("reorder rule"), "{err}");
        let mut bad = ExpConfig::default();
        bad.crash_spec = "rank:3@epoch:2".into();
        bad.cost.probe_interval_ns = 0;
        assert!(bad.validate().is_err(), "crash runs need a probe interval");
    }

    #[test]
    fn late_rank_none_and_attribution_keys() {
        let mut cfg = ExpConfig::default();
        cfg.set_run("late_rank", "3").unwrap();
        assert_eq!(cfg.late_rank, Some(3));
        cfg.set_run("late_rank", "none").unwrap();
        assert_eq!(cfg.late_rank, None, "\"none\" clears the straggler");
        assert!(cfg.set_run("late_rank", "soon").is_err());

        assert!(!cfg.attribution, "attribution defaults off");
        cfg.set_run("attribution", "true").unwrap();
        assert!(cfg.attribution);
        assert!(cfg.set_run("attribution", "yes").is_err());
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(ExpConfig::from_toml("[run]\nbogus = 1").is_err());
        assert!(ExpConfig::from_toml("[cost]\nbogus = 1").is_err());
    }
}
