//! The split view of [`ExpConfig`]: what belongs to the shared fabric
//! versus what each tenant brings.
//!
//! [`FabricConfig`] is everything the simulated network owns — cluster
//! size, wiring, cost model, seed, background traffic.  [`WorkloadSpec`]
//! is one tenant's collective stream — which collective, which algorithm,
//! which path, how many iterations.  `compose` glues one of each back
//! into the flat [`ExpConfig`] the cluster machinery consumes; the
//! [`crate::cluster::Session`] builder does exactly that once per tenant.

use crate::config::cost::CostModel;
use crate::config::{EngineKind, ExecPath, ExpConfig};
use crate::data::{Dtype, Op};
use crate::packet::{AlgoType, CollType};

/// Everything shared by all tenants of one simulated run.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    pub p: usize,
    pub topology: String,
    pub seed: u64,
    pub engine: EngineKind,
    pub verify: bool,
    pub late_rank: Option<usize>,
    pub late_delay_ns: u64,
    /// Hostile-network fault model (shared: faults live on the wires,
    /// not in any tenant's workload).
    pub loss: f64,
    pub drop_spec: String,
    pub trunk_degrade: f64,
    pub bg_flows: usize,
    pub bg_msgs: u64,
    pub bg_bytes: usize,
    pub bg_gap_ns: u64,
    /// Latency attribution (fabric-wide: the accumulators live on the
    /// shared cluster, charged per measuring rank).
    pub attribution: bool,
    pub cost: CostModel,
}

impl Default for FabricConfig {
    fn default() -> Self {
        ExpConfig::default().fabric()
    }
}

/// One tenant's collective stream.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub coll: CollType,
    pub algo: AlgoType,
    pub path: ExecPath,
    pub op: Op,
    pub dtype: Dtype,
    pub msg_bytes: usize,
    pub iters: usize,
    pub warmup: usize,
    pub multicast_opt: bool,
    pub ack_enabled: bool,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        ExpConfig::default().workload()
    }
}

impl ExpConfig {
    /// The fabric half of this flat config.
    pub fn fabric(&self) -> FabricConfig {
        FabricConfig {
            p: self.p,
            topology: self.topology.clone(),
            seed: self.seed,
            engine: self.engine,
            verify: self.verify,
            late_rank: self.late_rank,
            late_delay_ns: self.late_delay_ns,
            loss: self.loss,
            drop_spec: self.drop_spec.clone(),
            trunk_degrade: self.trunk_degrade,
            bg_flows: self.bg_flows,
            bg_msgs: self.bg_msgs,
            bg_bytes: self.bg_bytes,
            bg_gap_ns: self.bg_gap_ns,
            attribution: self.attribution,
            cost: self.cost.clone(),
        }
    }

    /// The per-tenant half of this flat config.
    pub fn workload(&self) -> WorkloadSpec {
        WorkloadSpec {
            coll: self.coll,
            algo: self.algo,
            path: self.path,
            op: self.op,
            dtype: self.dtype,
            msg_bytes: self.msg_bytes,
            iters: self.iters,
            warmup: self.warmup,
            multicast_opt: self.multicast_opt,
            ack_enabled: self.ack_enabled,
        }
    }

    /// Recombine a fabric and one workload into the flat config the
    /// cluster internals consume (single tenant spanning the fabric).
    pub fn compose(fabric: &FabricConfig, w: &WorkloadSpec) -> ExpConfig {
        ExpConfig {
            p: fabric.p,
            algo: w.algo,
            path: w.path,
            topology: fabric.topology.clone(),
            msg_bytes: w.msg_bytes,
            iters: w.iters,
            warmup: w.warmup,
            coll: w.coll,
            op: w.op,
            dtype: w.dtype,
            seed: fabric.seed,
            engine: fabric.engine,
            verify: fabric.verify,
            multicast_opt: w.multicast_opt,
            ack_enabled: w.ack_enabled,
            late_rank: fabric.late_rank,
            late_delay_ns: fabric.late_delay_ns,
            loss: fabric.loss,
            drop_spec: fabric.drop_spec.clone(),
            trunk_degrade: fabric.trunk_degrade,
            tenants: 1,
            bg_flows: fabric.bg_flows,
            bg_msgs: fabric.bg_msgs,
            bg_bytes: fabric.bg_bytes,
            bg_gap_ns: fabric.bg_gap_ns,
            attribution: fabric.attribution,
            cost: fabric.cost.clone(),
        }
    }
}

impl WorkloadSpec {
    /// Apply one `key = value` pair (the tenant-spec syntax used by
    /// `Session` callers and docs: `coll`, `algo`, `path`, `op`, `dtype`,
    /// `msg_bytes`, `iters`, `warmup`, `multicast_opt`, `ack_enabled`).
    pub fn set(&mut self, key: &str, v: &str) -> Result<(), String> {
        match key {
            "coll" => {
                self.coll =
                    CollType::from_name(v).ok_or_else(|| format!("workload.coll: unknown {v}"))?
            }
            "algo" => {
                self.algo =
                    AlgoType::from_name(v).ok_or_else(|| format!("workload.algo: unknown {v}"))?
            }
            "path" => {
                self.path = ExecPath::from_name(v)
                    .ok_or_else(|| format!("workload.path: unknown {v}"))?
            }
            "op" => {
                self.op = Op::from_name(v).ok_or_else(|| format!("workload.op: unknown {v}"))?
            }
            "dtype" => {
                self.dtype =
                    Dtype::from_name(v).ok_or_else(|| format!("workload.dtype: unknown {v}"))?
            }
            "msg_bytes" => {
                self.msg_bytes = v.parse().map_err(|e| format!("workload.msg_bytes: {e}"))?
            }
            "iters" => self.iters = v.parse().map_err(|e| format!("workload.iters: {e}"))?,
            "warmup" => self.warmup = v.parse().map_err(|e| format!("workload.warmup: {e}"))?,
            "multicast_opt" => {
                self.multicast_opt =
                    v.parse().map_err(|e| format!("workload.multicast_opt: {e}"))?
            }
            "ack_enabled" => {
                self.ack_enabled = v.parse().map_err(|e| format!("workload.ack_enabled: {e}"))?
            }
            _ => return Err(format!("unknown workload key: {key}")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_and_compose_roundtrip() {
        let mut cfg = ExpConfig::default();
        cfg.p = 16;
        cfg.path = ExecPath::Handler;
        cfg.coll = CollType::Exscan;
        cfg.msg_bytes = 256;
        cfg.topology = "fattree".into();
        cfg.bg_flows = 3;
        cfg.loss = 0.1;
        cfg.drop_spec = "0->1:1".into();
        cfg.trunk_degrade = 3.0;
        let back = ExpConfig::compose(&cfg.fabric(), &cfg.workload());
        assert_eq!(back.p, 16);
        assert_eq!(back.path, ExecPath::Handler);
        assert_eq!(back.coll, CollType::Exscan);
        assert_eq!(back.msg_bytes, 256);
        assert_eq!(back.topology, "fattree");
        assert_eq!(back.bg_flows, 3);
        assert_eq!(back.tenants, 1, "compose yields a single-tenant view");
        back.validate().unwrap();
    }

    #[test]
    fn workload_set_parses_keys() {
        let mut w = WorkloadSpec::default();
        w.set("coll", "allreduce").unwrap();
        w.set("path", "handler").unwrap();
        w.set("msg_bytes", "128").unwrap();
        assert_eq!(w.coll, CollType::Allreduce);
        assert_eq!(w.path, ExecPath::Handler);
        assert_eq!(w.msg_bytes, 128);
        assert!(w.set("bogus", "1").is_err());
        assert!(w.set("path", "warp").is_err());
    }
}
