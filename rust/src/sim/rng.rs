//! SplitMix64: tiny, seedable, deterministic PRNG.
//!
//! The simulator must be bit-reproducible from its seed (the determinism
//! property tests diff whole runs), so we use our own generator instead of
//! OS entropy.  SplitMix64 passes BigCrush and needs eight lines.

#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n) — unbiased enough for jitter purposes.
    pub fn next_below(&mut self, n: u64) -> u64 {
        if n == 0 {
            0
        } else {
            self.next_u64() % n
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform i64 in [lo, hi] inclusive (for signed payload values).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.next_below((hi - lo) as u64 + 1) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Derive an independent stream (for per-rank RNGs from a master seed).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let v = r.range(10, 20);
            assert!((10..=20).contains(&v));
        }
        assert_eq!(r.next_below(0), 0);
        assert_eq!(r.range(5, 5), 5);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut m = SplitMix64::new(3);
        let mut f1 = m.fork();
        let mut f2 = m.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
