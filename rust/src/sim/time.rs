//! Virtual time: u64 nanoseconds since simulation start.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time (ns).  The NetFPGA's 125 MHz clock is exactly
/// 8 ns per cycle, so cycle counts convert losslessly.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn ns(v: u64) -> Self {
        SimTime(v)
    }

    pub fn us(v: u64) -> Self {
        SimTime(v * 1_000)
    }

    pub fn ms(v: u64) -> Self {
        SimTime(v * 1_000_000)
    }

    /// NetFPGA cycles (125 MHz -> 8 ns/cycle).
    pub fn cycles(c: u64) -> Self {
        SimTime(c * 8)
    }

    pub fn as_ns(self) -> u64 {
        self.0
    }

    pub fn as_us(self) -> f64 {
        self.0 as f64 / 1_000.0
    }

    /// Saturating difference — elapsed time between two stamps.
    pub fn since(self, earlier: SimTime) -> u64 {
        self.0.saturating_sub(earlier.0)
    }
}

impl Add<u64> for SimTime {
    type Output = SimTime;
    fn add(self, rhs: u64) -> SimTime {
        SimTime(self.0 + rhs)
    }
}

impl Add<SimTime> for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign<u64> for SimTime {
    fn add_assign(&mut self, rhs: u64) {
        self.0 += rhs;
    }
}

impl Sub<SimTime> for SimTime {
    type Output = u64;
    fn sub(self, rhs: SimTime) -> u64 {
        self.0.saturating_sub(rhs.0)
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}us", self.as_us())
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}us", self.as_us())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(SimTime::us(3).as_ns(), 3_000);
        assert_eq!(SimTime::ms(1).as_ns(), 1_000_000);
        assert_eq!(SimTime::cycles(125_000_000).as_ns(), 1_000_000_000);
    }

    #[test]
    fn arithmetic_and_since() {
        let t = SimTime::ns(100) + 50;
        assert_eq!(t.as_ns(), 150);
        assert_eq!(t.since(SimTime::ns(100)), 50);
        assert_eq!(SimTime::ns(10).since(SimTime::ns(20)), 0, "saturates");
    }

    #[test]
    fn ordering() {
        assert!(SimTime::ns(1) < SimTime::ns(2));
        assert_eq!(SimTime::ZERO, SimTime::ns(0));
    }
}
