//! The event queue: (time, insertion sequence)-ordered, with two
//! interchangeable cores.
//!
//! The sequence number makes simultaneous events pop in insertion order,
//! which makes whole runs bit-reproducible — the determinism property
//! test (`rust/tests/prop_invariants.rs`) diffs two full simulations.
//!
//! Event volume grows ~p·log p·iters once the testbed scales past the
//! paper's 4 nodes, and the binary heap's O(log n) per operation (plus
//! its cache-hostile sift) starts to show.  The dense core is a
//! **calendar queue** (Brown 1988): a ring of fixed-width time buckets
//! holding the near future, with a min-heap overflow for events beyond
//! the horizon.  Push is O(1); pop scans one small bucket.  Sparse
//! schedules (long idle gaps, few events) stay on the plain heap — the
//! adaptive default starts there and migrates once the queue is dense
//! enough for buckets to pay off.  Both cores produce the *exact* same
//! pop order (the property tests compare them pop-for-pop against a
//! sorted-Vec reference model).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::event::EventKind;
use super::time::SimTime;

struct Entry {
    time: SimTime,
    seq: u64,
    kind: EventKind,
}

impl Entry {
    fn key(&self) -> (SimTime, u64) {
        (self.time, self.seq)
    }
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

/// Bucket width: 2^10 ns.  The simulation's dense event clusters (wire
/// serializations, NIC pipeline exits, stack crossings) land within a few
/// microseconds of each other, so ~1 us buckets keep scans short.
const WIDTH_SHIFT: u32 = 10;
const BUCKET_WIDTH_NS: u64 = 1 << WIDTH_SHIFT;
/// Ring size: 4096 buckets = a ~4.2 ms horizon before events overflow to
/// the heap.  Power of two so the index is a mask, and small enough that
/// one queue costs ~100 KB.
const NUM_BUCKETS: usize = 4096;
/// Adaptive migration point: at or below this many pending events the
/// heap's simplicity wins; the 65th concurrent event triggers migration.
const DENSE_THRESHOLD: usize = 64;

/// The dense core: near-future ring + far-future overflow heap.
///
/// Invariants:
/// - every bucketed entry's time lies in `[base, horizon)` where
///   `horizon = base + NUM_BUCKETS * width`, so bucket index
///   `(t >> WIDTH_SHIFT) % NUM_BUCKETS` is collision-free per lap;
/// - every overflow entry's time is `>= horizon`;
/// - `base` never exceeds the earliest pending entry's time.
struct Calendar {
    buckets: Vec<Vec<Entry>>,
    /// Start time (ns) of the bucket under the cursor; multiple of width.
    base: u64,
    cursor: usize,
    in_buckets: usize,
    overflow: BinaryHeap<Reverse<Entry>>,
}

impl Calendar {
    fn new(start_ns: u64) -> Calendar {
        Calendar {
            buckets: (0..NUM_BUCKETS).map(|_| Vec::new()).collect(),
            base: (start_ns >> WIDTH_SHIFT) << WIDTH_SHIFT,
            cursor: Self::idx_of(start_ns),
            in_buckets: 0,
            overflow: BinaryHeap::new(),
        }
    }

    fn idx_of(t_ns: u64) -> usize {
        ((t_ns >> WIDTH_SHIFT) as usize) % NUM_BUCKETS
    }

    fn horizon(&self) -> u64 {
        self.base + (NUM_BUCKETS as u64) * BUCKET_WIDTH_NS
    }

    fn insert(&mut self, e: Entry) {
        let t = e.time.as_ns();
        debug_assert!(t >= self.base, "insert below the calendar base");
        if t < self.horizon() {
            self.buckets[Self::idx_of(t)].push(e);
            self.in_buckets += 1;
        } else {
            self.overflow.push(Reverse(e));
        }
    }

    /// Pull overflow entries that the (grown) horizon now covers.
    fn drain_overflow(&mut self) {
        let horizon = self.horizon();
        while self.overflow.peek().is_some_and(|r| r.0.time.as_ns() < horizon) {
            let e = self.overflow.pop().expect("peeked").0;
            self.buckets[Self::idx_of(e.time.as_ns())].push(e);
            self.in_buckets += 1;
        }
    }

    fn pop(&mut self) -> Option<Entry> {
        if self.in_buckets == 0 {
            // nothing inside the horizon: jump the calendar to the
            // overflow minimum instead of crawling empty buckets
            let t = self.overflow.peek().map(|r| r.0.time.as_ns())?;
            self.base = (t >> WIDTH_SHIFT) << WIDTH_SHIFT;
            self.cursor = Self::idx_of(t);
            self.drain_overflow();
        }
        loop {
            if !self.buckets[self.cursor].is_empty() {
                let bucket = &mut self.buckets[self.cursor];
                let mut best = 0;
                for i in 1..bucket.len() {
                    if bucket[i].key() < bucket[best].key() {
                        best = i;
                    }
                }
                self.in_buckets -= 1;
                return Some(bucket.swap_remove(best));
            }
            // advance one bucket; the horizon slides one width forward
            self.base += BUCKET_WIDTH_NS;
            self.cursor = (self.cursor + 1) % NUM_BUCKETS;
            self.drain_overflow();
        }
    }
}

enum Core {
    Heap(BinaryHeap<Reverse<Entry>>),
    Calendar(Box<Calendar>),
}

pub struct EventQueue {
    core: Core,
    /// Migrate heap -> calendar when the queue gets dense (new()); forced
    /// cores (with_heap/with_calendar) never migrate.
    adaptive: bool,
    seq: u64,
    len: usize,
    now: SimTime,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue::new()
    }
}

impl EventQueue {
    /// Adaptive queue: heap while sparse, calendar once dense.
    pub fn new() -> Self {
        EventQueue {
            core: Core::Heap(BinaryHeap::new()),
            adaptive: true,
            seq: 0,
            len: 0,
            now: SimTime::ZERO,
        }
    }

    /// Plain binary heap, never migrates (reference core for the
    /// equivalence property tests and the bench baseline).
    pub fn with_heap() -> Self {
        EventQueue { adaptive: false, ..EventQueue::new() }
    }

    /// Calendar from the start, never falls back (bench + property
    /// tests).
    pub fn with_calendar() -> Self {
        EventQueue {
            core: Core::Calendar(Box::new(Calendar::new(0))),
            adaptive: false,
            seq: 0,
            len: 0,
            now: SimTime::ZERO,
        }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Schedule `kind` at absolute time `at`.  Panics if `at` is in the
    /// past — an event scheduled before `now` is always a model bug.
    pub fn push(&mut self, at: SimTime, kind: EventKind) {
        assert!(at >= self.now, "event scheduled in the past: {at} < {}", self.now);
        let entry = Entry { time: at, seq: self.seq, kind };
        self.seq += 1;
        self.len += 1;
        let migrate = match &mut self.core {
            Core::Heap(h) => {
                h.push(Reverse(entry));
                self.adaptive && self.len > DENSE_THRESHOLD
            }
            Core::Calendar(c) => {
                c.insert(entry);
                false
            }
        };
        if migrate {
            self.migrate_to_calendar();
        }
    }

    /// One-time O(n) hand-over of every pending entry into a calendar
    /// anchored at the current virtual time.
    fn migrate_to_calendar(&mut self) {
        let mut cal = Box::new(Calendar::new(self.now.as_ns()));
        let old = std::mem::replace(&mut self.core, Core::Heap(BinaryHeap::new()));
        if let Core::Heap(h) = old {
            for r in h.into_vec() {
                cal.insert(r.0);
            }
        }
        self.core = Core::Calendar(cal);
    }

    /// Pop the earliest event, advancing virtual time to it.
    pub fn pop(&mut self) -> Option<(SimTime, EventKind)> {
        let e = match &mut self.core {
            Core::Heap(h) => h.pop().map(|r| r.0),
            Core::Calendar(c) => {
                if self.len == 0 {
                    None
                } else {
                    c.pop()
                }
            }
        }?;
        self.len -= 1;
        self.now = e.time;
        Some((e.time, e.kind))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{choose, for_each_case};
    use crate::sim::event::EventKind;

    fn marker(rank: usize) -> EventKind {
        EventKind::HostStart { rank }
    }

    fn marker_id(kind: &EventKind) -> usize {
        match kind {
            EventKind::HostStart { rank } => *rank,
            _ => unreachable!(),
        }
    }

    fn all_queues() -> Vec<(&'static str, EventQueue)> {
        vec![
            ("adaptive", EventQueue::new()),
            ("heap", EventQueue::with_heap()),
            ("calendar", EventQueue::with_calendar()),
        ]
    }

    #[test]
    fn pops_in_time_order() {
        for (name, mut q) in all_queues() {
            q.push(SimTime::ns(30), marker(3));
            q.push(SimTime::ns(10), marker(1));
            q.push(SimTime::ns(20), marker(2));
            let order: Vec<u64> =
                std::iter::from_fn(|| q.pop()).map(|(t, _)| t.as_ns()).collect();
            assert_eq!(order, vec![10, 20, 30], "{name}");
        }
    }

    #[test]
    fn ties_break_by_insertion() {
        for (name, mut q) in all_queues() {
            q.push(SimTime::ns(5), marker(0));
            q.push(SimTime::ns(5), marker(1));
            q.push(SimTime::ns(5), marker(2));
            let ranks: Vec<usize> =
                std::iter::from_fn(|| q.pop()).map(|(_, k)| marker_id(&k)).collect();
            assert_eq!(ranks, vec![0, 1, 2], "{name}");
        }
    }

    #[test]
    fn now_advances() {
        for (name, mut q) in all_queues() {
            q.push(SimTime::ns(7), marker(0));
            assert_eq!(q.now(), SimTime::ZERO, "{name}");
            q.pop();
            assert_eq!(q.now(), SimTime::ns(7), "{name}");
        }
    }

    #[test]
    #[should_panic]
    fn past_event_panics() {
        let mut q = EventQueue::new();
        q.push(SimTime::ns(10), marker(0));
        q.pop();
        q.push(SimTime::ns(5), marker(1));
    }

    #[test]
    fn calendar_handles_horizon_overflow_and_jumps() {
        let mut q = EventQueue::with_calendar();
        // far beyond the ring horizon (4096 buckets x 1024 ns ~ 4.2 ms)
        q.push(SimTime::ms(100), marker(9));
        q.push(SimTime::ns(50), marker(1));
        q.push(SimTime::ms(50), marker(5));
        assert_eq!(q.pop().map(|(_, k)| marker_id(&k)), Some(1));
        assert_eq!(q.pop().map(|(_, k)| marker_id(&k)), Some(5));
        // push between far-apart pops (the jump realigned the calendar)
        q.push(SimTime::ms(50) + 10, marker(6));
        assert_eq!(q.pop().map(|(_, k)| marker_id(&k)), Some(6));
        assert_eq!(q.pop().map(|(_, k)| marker_id(&k)), Some(9));
        assert!(q.pop().is_none());
    }

    #[test]
    fn adaptive_migrates_and_stays_correct() {
        let mut q = EventQueue::new();
        let n = DENSE_THRESHOLD * 3;
        for i in 0..n {
            // descending times: worst case for a naive ring
            q.push(SimTime::ns(((n - i) * 137) as u64), marker(i));
        }
        let times: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|(t, _)| t.as_ns()).collect();
        assert_eq!(times.len(), n);
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "sorted output");
    }

    /// Satellite: random schedule/pop interleavings (equal timestamps
    /// included) must match a sorted-Vec reference model on all three
    /// queue flavors — so the calendar matches the old heap pop-for-pop.
    #[test]
    fn random_interleavings_match_reference_model() {
        for_each_case(150, 0xCA1E_17DA, |rng| {
            let mut queues = all_queues();
            // reference model: (time, id); ids are insertion-ordered, so
            // stable min by (time, id) is exactly the queue contract
            let mut model: Vec<(u64, usize)> = Vec::new();
            let mut next_id = 0usize;
            let mut now = 0u64;
            for _ in 0..400 {
                let push = model.is_empty() || rng.next_below(5) < 3;
                if push {
                    // offsets span ties, same-bucket, cross-bucket and
                    // beyond-horizon schedules
                    let offset =
                        *choose(rng, &[0u64, 1, 600, 1024, 40_000, 2_000_000, 30_000_000]);
                    let at = now + offset;
                    for (_, q) in queues.iter_mut() {
                        q.push(SimTime::ns(at), marker(next_id));
                    }
                    model.push((at, next_id));
                    next_id += 1;
                } else {
                    let best = model
                        .iter()
                        .enumerate()
                        .min_by_key(|&(_, &(t, id))| (t, id))
                        .map(|(i, _)| i)
                        .unwrap();
                    let (t, id) = model.remove(best);
                    for (name, q) in queues.iter_mut() {
                        let (qt, kind) = q.pop().expect("model says nonempty");
                        assert_eq!(qt.as_ns(), t, "{name} time");
                        assert_eq!(marker_id(&kind), id, "{name} order");
                    }
                    now = t;
                }
            }
            // drain the rest in lockstep
            model.sort_unstable();
            for (t, id) in model {
                for (name, q) in queues.iter_mut() {
                    let (qt, kind) = q.pop().expect("drain");
                    assert_eq!((qt.as_ns(), marker_id(&kind)), (t, id), "{name} drain");
                }
            }
            for (name, q) in queues.iter_mut() {
                assert!(q.pop().is_none(), "{name} empty at end");
                assert!(q.is_empty(), "{name} len");
            }
        });
    }
}
