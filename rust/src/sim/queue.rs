//! The event queue: a binary heap ordered by (time, insertion sequence).
//!
//! The sequence number makes simultaneous events pop in insertion order,
//! which makes whole runs bit-reproducible — the determinism property test
//! (`rust/tests/prop_invariants.rs`) diffs two full simulations.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::event::EventKind;
use super::time::SimTime;

struct Entry {
    time: SimTime,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
    now: SimTime,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: SimTime::ZERO }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `kind` at absolute time `at`.  Panics if `at` is in the
    /// past — an event scheduled before `now` is always a model bug.
    pub fn push(&mut self, at: SimTime, kind: EventKind) {
        assert!(at >= self.now, "event scheduled in the past: {at} < {}", self.now);
        let entry = Entry { time: at, seq: self.seq, kind };
        self.seq += 1;
        self.heap.push(Reverse(entry));
    }

    /// Pop the earliest event, advancing virtual time to it.
    pub fn pop(&mut self) -> Option<(SimTime, EventKind)> {
        let Reverse(e) = self.heap.pop()?;
        self.now = e.time;
        Some((e.time, e.kind))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::event::EventKind;

    fn marker(rank: usize) -> EventKind {
        EventKind::HostStart { rank }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime::ns(30), marker(3));
        q.push(SimTime::ns(10), marker(1));
        q.push(SimTime::ns(20), marker(2));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|(t, _)| t.as_ns()).collect();
        assert_eq!(order, vec![10, 20, 30]);
    }

    #[test]
    fn ties_break_by_insertion() {
        let mut q = EventQueue::new();
        q.push(SimTime::ns(5), marker(0));
        q.push(SimTime::ns(5), marker(1));
        q.push(SimTime::ns(5), marker(2));
        let ranks: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, k)| match k {
                EventKind::HostStart { rank } => rank,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ranks, vec![0, 1, 2]);
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.push(SimTime::ns(7), marker(0));
        assert_eq!(q.now(), SimTime::ZERO);
        q.pop();
        assert_eq!(q.now(), SimTime::ns(7));
    }

    #[test]
    #[should_panic]
    fn past_event_panics() {
        let mut q = EventQueue::new();
        q.push(SimTime::ns(10), marker(0));
        q.pop();
        q.push(SimTime::ns(5), marker(1));
    }
}
