//! Deterministic discrete-event simulation core.
//!
//! The paper's testbed is eight hosts with NetFPGA NICs wired together; this
//! module is the clock and event loop that everything (hosts, NICs, wires)
//! is scheduled on.  Design points:
//!
//! - virtual time is `u64` nanoseconds ([`SimTime`]) — the NetFPGA's 8 ns
//!   clock tick divides it exactly;
//! - the queue breaks time ties by insertion sequence number, so identical
//!   runs replay identically (the property tests rely on this);
//! - randomness (arrival jitter, compute noise) comes only from the seeded
//!   [`rng::SplitMix64`], never from the OS.

pub mod event;
pub mod queue;
pub mod rng;
pub mod time;

pub use event::{EventKind, HostMsg, OffloadRequest, EVENT_KINDS, EVENT_KIND_NAMES};
pub use queue::EventQueue;
pub use rng::SplitMix64;
pub use time::SimTime;
