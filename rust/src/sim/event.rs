//! Event vocabulary of the simulated cluster.
//!
//! Nine event kinds cover the whole system: host processes acting, data
//! crossing the host/NIC boundary (in both directions), frames arriving
//! at NIC ports, NIC handler units retiring, background-traffic
//! injections, retransmit-timer expiry for the reliability layer, the
//! liveness probe timer, and scheduled switch deaths (the last two only
//! on crash-scheduled runs).  Costs (host stack, DMA crossing, wire time) are charged
//! when the event is *scheduled*; the event fires when the thing has
//! fully happened.

use crate::data::{Dtype, Op, Payload};
use crate::net::{Frame, PortNo, Rank, SwMsg};
use crate::packet::{AlgoType, CollType};

/// A host's request to its own NetFPGA: "run this collective for me".
/// This is the decoded form of the specially-crafted UDP HostRequest
/// packet (the crossing cost has already been charged).
#[derive(Clone, Debug)]
pub struct OffloadRequest {
    pub rank: Rank,
    pub comm: u16,
    pub epoch: u16,
    pub comm_size: u16,
    pub coll: CollType,
    pub algo: AlgoType,
    pub op: Op,
    pub dtype: Dtype,
    pub payload: Payload,
}

/// Something delivered up a host's protocol stack to the application.
#[derive(Clone, Debug)]
pub enum HostMsg {
    /// A (reassembled) software-MPI message from a peer rank.
    Sw(SwMsg),
    /// The NetFPGA's Result packet: final scan outcome for this rank plus
    /// the elapsed on-NIC time the hardware timestamping measured
    /// (offload->release, Figs. 6/7).
    NfResult { epoch: u16, payload: Payload, nic_elapsed_ns: u64 },
}

/// One scheduled occurrence in the simulation.
#[derive(Debug)]
pub enum EventKind {
    /// The host process at `rank` takes its next driver action (issue the
    /// next MPI_Scan of the benchmark loop, typically).
    HostStart { rank: Rank },
    /// A message/result finished climbing `rank`'s protocol stack.
    HostRecv { rank: Rank, msg: HostMsg },
    /// A frame finished arriving at `rank`'s NIC on `port`.
    NicRecv { rank: Rank, port: PortNo, frame: Frame },
    /// An offload request finished crossing from host to NIC.
    NicHostReq { rank: Rank, req: OffloadRequest },
    /// A handler processing unit on `rank`'s NIC finished its activation
    /// (only scheduled when `cost.hpus > 0` constrains the pool).
    HpuDone { rank: Rank },
    /// The background traffic generator injects flow `flow`'s next frame.
    BgTick { flow: u16 },
    /// The retransmit timer for reliable transaction `txn`, armed on
    /// `rank`'s NIC when the frame was sent, expires.  A no-op if the
    /// ack already came back (the pending entry is gone); otherwise the
    /// NIC retransmits or gives up.
    RetxTimer { rank: Rank, txn: u64 },
    /// `rank`'s NIC low-rate liveness probe timer fires: if its monitored
    /// peer has been silent for a probe interval, send a reliable Probe
    /// frame (whose retransmit give-up is the suspicion signal).  Only
    /// armed on crash-scheduled runs.
    ProbeTimer { rank: Rank },
    /// Scheduled fail-stop death of switch `node` (node id, i.e. `p + s`
    /// for switch index `s`): the switch stops forwarding, routes are
    /// rebuilt around it, and unreachable survivor pairs become a named
    /// partition error.  Only scheduled on crash-scheduled runs.
    CrashSwitch { node: usize },
}

/// Number of [`EventKind`] variants ([`EventKind::index`] stays below
/// this) — sizes the event-loop self-profile's fixed tables.
pub const EVENT_KINDS: usize = 9;

/// Display names by [`EventKind::index`] slot (profile table rows).
pub const EVENT_KIND_NAMES: [&str; EVENT_KINDS] = [
    "host_start",
    "host_recv",
    "nic_recv",
    "nic_host_req",
    "hpu_done",
    "bg_tick",
    "retx_timer",
    "probe_timer",
    "crash_switch",
];

impl EventKind {
    /// Stable display name, in [`EventKind::index`] order.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::HostStart { .. } => "host_start",
            EventKind::HostRecv { .. } => "host_recv",
            EventKind::NicRecv { .. } => "nic_recv",
            EventKind::NicHostReq { .. } => "nic_host_req",
            EventKind::HpuDone { .. } => "hpu_done",
            EventKind::BgTick { .. } => "bg_tick",
            EventKind::RetxTimer { .. } => "retx_timer",
            EventKind::ProbeTimer { .. } => "probe_timer",
            EventKind::CrashSwitch { .. } => "crash_switch",
        }
    }

    /// Dense variant index in `0..EVENT_KINDS` (profile table slot).
    pub fn index(&self) -> usize {
        match self {
            EventKind::HostStart { .. } => 0,
            EventKind::HostRecv { .. } => 1,
            EventKind::NicRecv { .. } => 2,
            EventKind::NicHostReq { .. } => 3,
            EventKind::HpuDone { .. } => 4,
            EventKind::BgTick { .. } => 5,
            EventKind::RetxTimer { .. } => 6,
            EventKind::ProbeTimer { .. } => 7,
            EventKind::CrashSwitch { .. } => 8,
        }
    }
}
