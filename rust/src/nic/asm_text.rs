//! Line-oriented text form of the handler ISA, so `nfscan lint` can
//! verify programs that were never compiled into the binary — the
//! workflow the verifier exists for: write a handler, lint it, only
//! then let it near a flow table.
//!
//! Grammar (one item per line, `;` or `#` starts a comment):
//!
//! ```text
//! .request start          ; entry label for the host-request activation
//! .packet  on_pkt         ; entry label for the packet activation
//! .timer   on_tmr         ; entry label for the retransmit-timer
//!                         ; activation; when absent, the standard
//!                         ; policy (retx while retries < max_retries)
//!                         ; is appended, exactly as `Asm::finish` does
//! start:                  ; a label binds the next instruction
//!   imm   r0, 42
//!   env   r1, rank        ; rank | p | inclusive | pkt.step | pkt.src
//!                         ; | pkt.kind | retries | max_retries
//!   alu   add r2, r0, r1  ; add sub xor and shl shr lt eq
//!   ldpkt r3
//!   empty_like r4, r3
//!   ident_like r4, r3
//!   ld    r5, r0          ; dst, slot-index register
//!   st    r0, r5          ; slot-index register, src
//!   clr   r0
//!   combine r3, r3, r4
//!   is_set  r6, r3
//!   jmp   start
//!   jz    r6, start
//!   jnz   r6, start
//!   emit  r1, data, r0, r3   ; dst-rank, msg type, step, payload
//!   deliver r3
//!   retx                  ; replay the timed-out frame (timer entry only)
//!   drop
//!   halt
//! ```
//!
//! Registers beyond `r15` and labels that never bind parse fine — they
//! are the *verifier's* findings (`bad-register`, `bad-target`), and
//! lint exists to show them; only genuinely unreadable syntax errors
//! here.

use std::collections::HashMap;

use super::vm::{AluOp, EnvVal, Instr, Program, Reg};
use crate::packet::MsgType;

/// A parse failure, carrying the 1-based source line.
#[derive(Debug)]
pub struct AsmError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.msg)
    }
}

fn err(line: usize, msg: impl Into<String>) -> AsmError {
    AsmError { line, msg: msg.into() }
}

fn parse_reg(line: usize, tok: &str) -> Result<Reg, AsmError> {
    let digits = tok
        .strip_prefix('r')
        .ok_or_else(|| err(line, format!("expected a register (rN), got `{tok}`")))?;
    digits.parse::<Reg>().map_err(|_| err(line, format!("bad register `{tok}`")))
}

fn parse_int(line: usize, tok: &str) -> Result<i64, AsmError> {
    let (neg, body) = match tok.strip_prefix('-') {
        Some(rest) => (true, rest),
        None => (false, tok),
    };
    let v = if let Some(hex) = body.strip_prefix("0x") {
        i64::from_str_radix(hex, 16)
    } else {
        body.parse::<i64>()
    }
    .map_err(|_| err(line, format!("bad integer `{tok}`")))?;
    Ok(if neg { -v } else { v })
}

fn parse_env(line: usize, tok: &str) -> Result<EnvVal, AsmError> {
    Ok(match tok {
        "rank" => EnvVal::Rank,
        "p" => EnvVal::P,
        "inclusive" => EnvVal::Inclusive,
        "pkt.step" => EnvVal::PktStep,
        "pkt.src" => EnvVal::PktSrc,
        "pkt.kind" => EnvVal::PktKind,
        "retries" => EnvVal::Retries,
        "max_retries" => EnvVal::MaxRetries,
        _ => return Err(err(line, format!("unknown env value `{tok}`"))),
    })
}

fn parse_alu(line: usize, tok: &str) -> Result<AluOp, AsmError> {
    Ok(match tok {
        "add" => AluOp::Add,
        "sub" => AluOp::Sub,
        "xor" => AluOp::Xor,
        "and" => AluOp::And,
        "shl" => AluOp::Shl,
        "shr" => AluOp::Shr,
        "lt" => AluOp::Lt,
        "eq" => AluOp::Eq,
        _ => return Err(err(line, format!("unknown alu op `{tok}`"))),
    })
}

fn parse_msg_type(line: usize, tok: &str) -> Result<MsgType, AsmError> {
    Ok(match tok {
        "hostrequest" => MsgType::HostRequest,
        "data" => MsgType::Data,
        "ack" => MsgType::Ack,
        "result" => MsgType::Result,
        "cumtagged" => MsgType::CumTagged,
        "down" => MsgType::Down,
        _ => return Err(err(line, format!("unknown msg type `{tok}`"))),
    })
}

/// A jump operand: resolved after all labels are seen.
struct Fixup {
    line: usize,
    pc: usize,
    label: String,
}

/// Assemble handler-ISA text into a [`Program`].  `name` is the image
/// name used in diagnostics (typically the file stem).
pub fn assemble(name: &str, src: &str) -> Result<Program, AsmError> {
    let mut code: Vec<Instr> = Vec::new();
    let mut labels: HashMap<String, usize> = HashMap::new();
    let mut fixups: Vec<Fixup> = Vec::new();
    let mut entry_request: Option<(usize, String)> = None;
    let mut entry_packet: Option<(usize, String)> = None;
    let mut entry_timer: Option<(usize, String)> = None;

    for (i, raw) in src.lines().enumerate() {
        let line = i + 1;
        let text = raw.split([';', '#']).next().unwrap_or("").trim();
        if text.is_empty() {
            continue;
        }
        if let Some(rest) = text.strip_prefix(".request") {
            entry_request = Some((line, rest.trim().to_string()));
            continue;
        }
        if let Some(rest) = text.strip_prefix(".packet") {
            entry_packet = Some((line, rest.trim().to_string()));
            continue;
        }
        if let Some(rest) = text.strip_prefix(".timer") {
            entry_timer = Some((line, rest.trim().to_string()));
            continue;
        }
        if let Some(label) = text.strip_suffix(':') {
            let label = label.trim();
            if label.is_empty() || label.contains(char::is_whitespace) {
                return Err(err(line, format!("bad label `{text}`")));
            }
            if labels.insert(label.to_string(), code.len()).is_some() {
                return Err(err(line, format!("label `{label}` bound twice")));
            }
            continue;
        }

        // `op tok, tok, ...` — commas and whitespace both separate
        let toks: Vec<&str> =
            text.split([',', ' ', '\t']).filter(|t| !t.is_empty()).collect();
        let op = toks[0];
        let want = |n: usize| -> Result<(), AsmError> {
            if toks.len() == n + 1 {
                Ok(())
            } else {
                Err(err(line, format!("`{op}` takes {n} operand(s), got {}", toks.len() - 1)))
            }
        };
        let mut jump = |label: &str| {
            fixups.push(Fixup { line, pc: code.len(), label: label.to_string() });
            0usize // patched later
        };
        let instr = match op {
            "imm" => {
                want(2)?;
                Instr::Imm { dst: parse_reg(line, toks[1])?, val: parse_int(line, toks[2])? }
            }
            "mov" => {
                want(2)?;
                Instr::Mov { dst: parse_reg(line, toks[1])?, src: parse_reg(line, toks[2])? }
            }
            "env" => {
                want(2)?;
                Instr::Env { dst: parse_reg(line, toks[1])?, what: parse_env(line, toks[2])? }
            }
            "ldpkt" => {
                want(1)?;
                Instr::LdPkt { dst: parse_reg(line, toks[1])? }
            }
            "empty_like" => {
                want(2)?;
                Instr::EmptyLike { dst: parse_reg(line, toks[1])?, src: parse_reg(line, toks[2])? }
            }
            "ident_like" => {
                want(2)?;
                Instr::IdentLike { dst: parse_reg(line, toks[1])?, src: parse_reg(line, toks[2])? }
            }
            "ld" => {
                want(2)?;
                Instr::Ld { dst: parse_reg(line, toks[1])?, slot: parse_reg(line, toks[2])? }
            }
            "st" => {
                want(2)?;
                Instr::St { slot: parse_reg(line, toks[1])?, src: parse_reg(line, toks[2])? }
            }
            "clr" => {
                want(1)?;
                Instr::Clr { slot: parse_reg(line, toks[1])? }
            }
            "alu" => {
                want(4)?;
                Instr::Alu {
                    op: parse_alu(line, toks[1])?,
                    dst: parse_reg(line, toks[2])?,
                    a: parse_reg(line, toks[3])?,
                    b: parse_reg(line, toks[4])?,
                }
            }
            "combine" => {
                want(3)?;
                Instr::Combine {
                    dst: parse_reg(line, toks[1])?,
                    a: parse_reg(line, toks[2])?,
                    b: parse_reg(line, toks[3])?,
                }
            }
            "is_set" => {
                want(2)?;
                Instr::IsSet { dst: parse_reg(line, toks[1])?, src: parse_reg(line, toks[2])? }
            }
            "jmp" => {
                want(1)?;
                Instr::Jmp { to: jump(toks[1]) }
            }
            "jz" => {
                want(2)?;
                Instr::Jz { cond: parse_reg(line, toks[1])?, to: jump(toks[2]) }
            }
            "jnz" => {
                want(2)?;
                Instr::Jnz { cond: parse_reg(line, toks[1])?, to: jump(toks[2]) }
            }
            "emit" => {
                want(4)?;
                Instr::Emit {
                    dst: parse_reg(line, toks[1])?,
                    mt: parse_msg_type(line, toks[2])?,
                    step: parse_reg(line, toks[3])?,
                    payload: parse_reg(line, toks[4])?,
                }
            }
            "deliver" => {
                want(1)?;
                Instr::Deliver { payload: parse_reg(line, toks[1])? }
            }
            "retx" => {
                want(0)?;
                Instr::Retx
            }
            "drop" | "park" => {
                want(0)?;
                Instr::Drop
            }
            "halt" => {
                want(0)?;
                Instr::Halt
            }
            _ => return Err(err(line, format!("unknown instruction `{op}`"))),
        };
        code.push(instr);
    }

    // timer entry: explicit label, or the standard policy block appended
    // at the end — exactly what `Asm::finish` emits, so text-form and
    // compiled-in images get identical default retransmit behavior.
    // Appended BEFORE the unbound-label sentinel is computed: the block
    // grows the code, and the sentinel must stay out of range.
    let on_timer = match &entry_timer {
        Some((line, label)) => labels.get(label).copied().ok_or_else(|| {
            err(*line, format!(".timer entry label `{label}` never bound"))
        })?,
        None => {
            let t = code.len();
            code.extend([
                Instr::Env { dst: 0, what: EnvVal::Retries },
                Instr::Env { dst: 1, what: EnvVal::MaxRetries },
                Instr::Alu { op: AluOp::Lt, dst: 2, a: 0, b: 1 },
                Instr::Jz { cond: 2, to: t + 5 },
                Instr::Retx,
                Instr::Halt,
            ]);
            t
        }
    };

    // resolve: an unbound jump label becomes a deliberately out-of-range
    // target so the verifier reports it as `bad-target` with the pc
    let out_of_range = code.len().max(1);
    for fx in fixups {
        let target = labels.get(&fx.label).copied().unwrap_or(out_of_range);
        match &mut code[fx.pc] {
            Instr::Jmp { to } | Instr::Jz { to, .. } | Instr::Jnz { to, .. } => *to = target,
            _ => unreachable!("fixup on non-jump at pc {}", fx.pc),
        }
    }
    let resolve_entry = |e: &Option<(usize, String)>, which: &str| -> Result<usize, AsmError> {
        match e {
            Some((line, label)) => labels
                .get(label)
                .copied()
                .ok_or_else(|| err(*line, format!("{which} entry label `{label}` never bound"))),
            // default: first instruction, so tiny test programs need no
            // directives at all
            None => Ok(0),
        }
    };
    let on_request = resolve_entry(&entry_request, ".request")?;
    let on_packet = resolve_entry(&entry_packet, ".packet")?;

    // Program.name is &'static str (images are compiled in); a linted
    // file's name lives as long as the process anyway
    let name: &'static str = Box::leak(name.to_string().into_boxed_str());
    Ok(Program { name, code, on_request, on_packet, on_timer })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nic::verify;

    #[test]
    fn round_trips_a_well_formed_program() {
        let src = r"
            ; k = 0; while (1 << k) < p { k += 1 }
            .request start
            .packet  start
            start:
              imm r0, 0
              imm r1, 1
            head:
              alu shl r2, r1, r0
              env r3, p
              alu lt r4, r2, r3
              jz  r4, done
              alu add r0, r0, r1
              jmp head
            done:
              halt
        ";
        let prog = assemble("rdloop", src).expect("assembles");
        assert_eq!(prog.name, "rdloop");
        assert_eq!(prog.on_request, 0);
        let report = verify::verify(&prog).expect("verifies");
        assert!(report.on_request_bound > 0);
    }

    #[test]
    fn unbound_jump_label_becomes_bad_target() {
        let src = "start:\n  jmp nowhere\n  halt\n";
        let prog = assemble("t", src).expect("assembles");
        let rejects = verify::verify(&prog).expect_err("rejected");
        assert!(rejects.iter().any(|r| r.class() == "bad-target"));
    }

    #[test]
    fn timer_directive_and_retx_parse() {
        let src = r"
            .request start
            .packet  start
            .timer   tmr
            start:
              halt
            tmr:                    ; double the budget before giving up
              env r0, retries
              env r1, max_retries
              alu add r1, r1, r1
              alu lt r2, r0, r1
              jz  r2, give_up
              retx
            give_up:
              halt
        ";
        let prog = assemble("t-timer", src).expect("assembles");
        assert_eq!(prog.on_timer, 1);
        assert!(prog.code.iter().any(|i| matches!(i, Instr::Retx)));
        let report = verify::verify(&prog).expect("custom timer policy verifies");
        assert!(report.on_timer_bound > 0);
    }

    #[test]
    fn missing_timer_directive_appends_standard_policy() {
        let src = ".request s\n.packet s\ns:\n  halt\n";
        let prog = assemble("t-default", src).expect("assembles");
        assert_eq!(prog.on_timer, 1, "standard block appended after user code");
        assert!(matches!(prog.code[prog.on_timer], Instr::Env { what: EnvVal::Retries, .. }));
        assert!(prog.code.iter().any(|i| matches!(i, Instr::Retx)));
        verify::verify(&prog).expect("default retransmit policy verifies");
    }

    #[test]
    fn unbound_timer_label_is_a_parse_error() {
        let e = assemble("t", ".timer nowhere\nhalt\n").expect_err("unbound");
        assert!(e.msg.contains("nowhere"), "{}", e.msg);
    }

    #[test]
    fn syntax_errors_carry_the_line() {
        let e = assemble("t", "halt\nbogus r1\n").expect_err("syntax error");
        assert_eq!(e.line, 2);
        assert!(e.msg.contains("bogus"));
    }

    #[test]
    fn comments_commas_and_hex_parse() {
        let src = "imm r0, 0x10 ; sixteen\nimm r1 -3 # negative\nhalt\n";
        let prog = assemble("t", src).expect("assembles");
        assert!(matches!(prog.code[0], Instr::Imm { dst: 0, val: 16 }));
        assert!(matches!(prog.code[1], Instr::Imm { dst: 1, val: -3 }));
    }
}
