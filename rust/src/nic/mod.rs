//! The programmable NIC: collectives as per-packet handler programs.
//!
//! The paper builds ONE collective (MPI_Scan) as a fixed-function
//! NetFPGA datapath; the durable version of the idea — sPIN (Hoefler et
//! al. 2017) and its open-hardware descendant FPsPIN (Schneider et al.
//! 2024) — makes the NIC *programmable*: every collective is a small
//! handler program run against each arriving message, with bounded
//! per-flow state and run-to-completion semantics.
//!
//! - [`vm`] — the deterministic 16-register handler VM: scratchpad
//!   load/store, scalar ALU, the shared dtype x op combine datapath,
//!   `emit`/`deliver`/`drop` intrinsics, per-instruction + per-byte
//!   costs charged through `config::cost`;
//! - [`programs`] — the handler programs (scan, exscan, allreduce,
//!   barrier, bcast) and the [`programs::HandlerEngine`] adapter that
//!   slots a flow into the NIC's existing engine table;
//! - [`verify`] — the static verifier: abstract interpretation over the
//!   ISA proving initialization, scratch bounds, termination and the
//!   per-activation instruction budget before an image is ever
//!   installed (`nfscan lint`, and every [`programs`] image at
//!   construction);
//! - [`asm_text`] — the text form of the ISA, so `nfscan lint --file`
//!   can verify programs that were never compiled in.
//!
//! The cluster dispatches to this subsystem instead of the `fpga::`
//! state machines when `ExpConfig::handler` is set (the `handler[:coll]`
//! series axis).  Results are bit-identical to the fixed-function path —
//! the VM's vector ALU *is* `EngineCtx::combine` — only latencies (and
//! the new `handler_instrs` / `handler_stalls` counters) differ.

pub mod asm_text;
pub mod programs;
pub mod verify;
pub mod vm;

pub use programs::{handler_engine, program_for, HandlerEngine};
pub use verify::{verify as verify_program, CostReport, RejectReason};
pub use vm::{Activation, Asm, Flow, Instr, Program};
