//! Static verifier for handler programs: every invariant the VM enforces
//! with a runtime `assert!` is proven (or rejected) here, **before any
//! packet flies** — sPIN's run-to-completion contract as a load-time
//! check instead of a mid-simulation panic.
//!
//! The verifier abstractly interprets a [`Program`] over an interval +
//! type domain:
//!
//! - **register initialization** — no register is read before it is
//!   written on any path (`UninitRead`);
//! - **scratch-slot bounds** — every `Ld`/`St`/`Clr` slot index is
//!   proven within `[0, SCRATCH_SLOTS)`, including computed indices like
//!   the packet inbox `step + INBOX` (`ScratchOob`);
//! - **shift ranges** — every `Shl`/`Shr` amount is proven within
//!   `[0, 63]` (`ShiftRange`);
//! - **type consistency** — an operand that can *never* have the type an
//!   instruction requires (e.g. `Combine` over an integer register: the
//!   shared dtype x op datapath needs payloads on both sides) is
//!   rejected (`DtypeMismatch`).  Values loaded from scratch may be
//!   `Empty` at runtime; those reads stay legal and the VM's (now
//!   flow-attributed) asserts remain the dynamic backstop;
//! - **termination** — every path ends in `Deliver`+`Halt`, `Drop` or
//!   `Halt`: no fall-through off the end of the code (`MissingHalt`), no
//!   unresolved jump target (`BadTarget`), and no reachable cycle that
//!   cannot exit (`NoTermination`);
//! - **instruction budget** — a worst-case instruction bound per
//!   activation (request, packet *and* retransmit-timer), valid for
//!   every p <= 2^16, is computed and checked against [`MAX_STEPS`]
//!   (`BudgetExceeded`).  Bounding `on_timer` matters doubly: the
//!   timer entry runs while the card is already in recovery, so an
//!   unbounded retransmit handler would wedge exactly the flow it is
//!   supposed to rescue.
//!
//! Loop bounds come from the recursive-doubling round structure: a
//! handler loop advances at least one RD round per iteration and a
//! round counter k satisfies `1 << k < p <= 2^16`, so any back-edge is
//! taken at most [`LOOP_BOUND`] times.  The interval domain *proves*
//! that counters stay inside `[0, 16]` by refining branch conditions:
//! the analyzer tracks `dst = (a < b)` and `dst = (1 << k)` facts, so
//! falling through `jz` on `(1 << k) < p` tightens `k <= 15` exactly
//! the way the programs' guards intend.
//!
//! Environment assumptions (documented contract, enforced upstream):
//! `p <= 2^16` ([`MAX_P`]), `rank < p`, and an inbound packet's `step`
//! field respects the RD round structure (`step <= 16`).  A hostile
//! step field is still caught by the VM's slot-bound assert — the
//! verifier guarantees the *program* cannot misbehave, the runtime
//! asserts guard the *inputs*.

use std::collections::VecDeque;
use std::fmt;

use super::vm::{AluOp, EnvVal, Instr, Program, Reg, MAX_STEPS, NREGS, SCRATCH_SLOTS};

/// Largest communicator size the cost bound is proven for.
pub const MAX_P: i64 = 1 << 16;

/// Max recursive-doubling rounds for p <= [`MAX_P`]: ceil(log2(p)) <= 16.
pub const MAX_ROUNDS: i64 = 16;

/// Per-back-edge iteration bound: one trip per RD round plus the final
/// bound-check trip.
pub const LOOP_BOUND: usize = MAX_ROUNDS as usize + 1;

// ------------------------------------------------------------ verdicts

/// Why a program image was rejected.  Each variant is one invariant
/// class; `class()` gives the stable short name the negative-corpus
/// tests and `nfscan lint` match on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// A register operand is >= [`NREGS`].
    BadRegister { pc: usize, reg: Reg },
    /// A jump targets an instruction index outside the code.
    BadTarget { pc: usize, target: usize },
    /// An entry point is outside the code (or the code is empty).
    BadEntry { which: &'static str, target: usize },
    /// The last instruction can fall through off the end of the code.
    MissingHalt { pc: usize },
    /// A reachable cycle from which no `Halt`/`Drop` is reachable.
    NoTermination { pc: usize },
    /// A register is read before any write on some path.
    UninitRead { pc: usize, reg: Reg },
    /// A scratch-slot index not provably within `[0, SCRATCH_SLOTS)`.
    ScratchOob { pc: usize, lo: i64, hi: i64 },
    /// A shift amount not provably within `[0, 63]`.
    ShiftRange { pc: usize, lo: i64, hi: i64 },
    /// An operand that can never have the required type (`Combine` /
    /// `Emit` / `Deliver` need payloads; ALU / slot / branch operands
    /// need integers).
    DtypeMismatch { pc: usize, reg: Reg, expected: &'static str },
    /// An `Emit` destination or step field provably always outside its
    /// wire range.
    WireRange { pc: usize, lo: i64, hi: i64 },
    /// The worst-case instruction bound for an entry exceeds
    /// [`MAX_STEPS`].
    BudgetExceeded { entry: &'static str, bound: usize },
}

impl RejectReason {
    /// Stable short class name (what the negative corpus asserts on).
    pub fn class(&self) -> &'static str {
        match self {
            RejectReason::BadRegister { .. } => "bad-register",
            RejectReason::BadTarget { .. } => "bad-target",
            RejectReason::BadEntry { .. } => "bad-entry",
            RejectReason::MissingHalt { .. } => "missing-halt",
            RejectReason::NoTermination { .. } => "no-termination",
            RejectReason::UninitRead { .. } => "uninit-read",
            RejectReason::ScratchOob { .. } => "scratch-oob",
            RejectReason::ShiftRange { .. } => "shift-range",
            RejectReason::DtypeMismatch { .. } => "dtype-mismatch",
            RejectReason::WireRange { .. } => "wire-range",
            RejectReason::BudgetExceeded { .. } => "budget",
        }
    }
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::BadRegister { pc, reg } => {
                write!(f, "@{pc}: register r{reg} out of range")
            }
            RejectReason::BadTarget { pc, target } => {
                write!(f, "@{pc}: jump target {target} out of range")
            }
            RejectReason::BadEntry { which, target } => {
                write!(f, "entry {which} = {target} out of range")
            }
            RejectReason::MissingHalt { pc } => {
                write!(f, "@{pc}: control can fall off the end of the code")
            }
            RejectReason::NoTermination { pc } => {
                write!(f, "@{pc}: in a cycle that can never reach halt/drop")
            }
            RejectReason::UninitRead { pc, reg } => {
                write!(f, "@{pc}: r{reg} read before any write on some path")
            }
            RejectReason::ScratchOob { pc, lo, hi } => {
                write!(f, "@{pc}: scratch slot in [{lo}, {hi}] not provably within 0..{SCRATCH_SLOTS}")
            }
            RejectReason::ShiftRange { pc, lo, hi } => {
                write!(f, "@{pc}: shift amount in [{lo}, {hi}] not provably within 0..64")
            }
            RejectReason::DtypeMismatch { pc, reg, expected } => {
                write!(f, "@{pc}: r{reg} can never hold the required {expected}")
            }
            RejectReason::WireRange { pc, lo, hi } => {
                write!(f, "@{pc}: emit field in [{lo}, {hi}] always outside its wire range")
            }
            RejectReason::BudgetExceeded { entry, bound } => {
                write!(f, "{entry}: worst-case bound {bound} instrs exceeds budget {MAX_STEPS}")
            }
        }
    }
}

/// One loop (nontrivial strongly-connected component) in the program.
#[derive(Clone, Debug)]
pub struct LoopReport {
    /// Smallest pc in the loop.
    pub head: usize,
    /// Number of instructions in the loop body.
    pub body: usize,
    /// Backwards (program-order) edges inside the loop.
    pub back_edges: usize,
    /// Worst-case instructions retired inside the loop per activation.
    pub bound: usize,
}

/// Proof artifacts of a successful verification: the per-activation
/// worst-case instruction bounds `nfscan lint` reports.
#[derive(Clone, Debug)]
pub struct CostReport {
    /// Worst-case instructions for one `on_request` activation.
    pub on_request_bound: usize,
    /// Worst-case instructions for one `on_packet` activation.
    pub on_packet_bound: usize,
    /// Worst-case instructions for one `on_timer` (retransmit-timer)
    /// activation.
    pub on_timer_bound: usize,
    /// Every loop found, with its contribution to the bound.
    pub loops: Vec<LoopReport>,
}

// ------------------------------------------------------------- domain

/// Inclusive integer interval.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Iv {
    lo: i64,
    hi: i64,
}

impl Iv {
    const TOP: Iv = Iv { lo: i64::MIN, hi: i64::MAX };

    fn exact(v: i64) -> Iv {
        Iv { lo: v, hi: v }
    }

    fn new(lo: i64, hi: i64) -> Iv {
        Iv { lo, hi }
    }

    fn hull(a: Iv, b: Iv) -> Iv {
        Iv { lo: a.lo.min(b.lo), hi: a.hi.max(b.hi) }
    }

    fn within(&self, lo: i64, hi: i64) -> bool {
        self.lo >= lo && self.hi <= hi
    }

    fn disjoint(&self, lo: i64, hi: i64) -> bool {
        self.hi < lo || self.lo > hi
    }

    fn is_exact(&self) -> Option<i64> {
        (self.lo == self.hi).then_some(self.lo)
    }
}

fn clamp128(v: i128) -> i64 {
    v.clamp(i64::MIN as i128, i64::MAX as i128) as i64
}

/// Smallest `2^k - 1 >= v` (v >= 0) — the tightest mask bound for
/// xor/and of non-negative ranges.
fn bits_mask(v: i64) -> i64 {
    if v <= 0 {
        return 0;
    }
    let b = 64 - v.leading_zeros();
    if b >= 63 {
        i64::MAX
    } else {
        (1i64 << b) - 1
    }
}

fn ilog2_floor(v: i64) -> i64 {
    debug_assert!(v >= 1);
    63 - (v as u64).leading_zeros() as i64
}

fn alu_iv(op: AluOp, a: Iv, b: Iv) -> Iv {
    match op {
        AluOp::Add => Iv::new(a.lo.saturating_add(b.lo), a.hi.saturating_add(b.hi)),
        AluOp::Sub => Iv::new(a.lo.saturating_sub(b.hi), a.hi.saturating_sub(b.lo)),
        AluOp::Xor => {
            if a.lo >= 0 && b.lo >= 0 {
                Iv::new(0, bits_mask(a.hi | b.hi))
            } else {
                Iv::TOP
            }
        }
        AluOp::And => {
            if a.lo >= 0 && b.lo >= 0 {
                Iv::new(0, a.hi.min(b.hi))
            } else {
                Iv::TOP
            }
        }
        AluOp::Shl => {
            if a.lo >= 0 && b.within(0, 62) {
                Iv::new(clamp128((a.lo as i128) << b.lo), clamp128((a.hi as i128) << b.hi))
            } else {
                Iv::TOP
            }
        }
        AluOp::Shr => {
            if b.within(0, 63) {
                let c = [a.lo >> b.lo, a.lo >> b.hi, a.hi >> b.lo, a.hi >> b.hi];
                Iv::new(*c.iter().min().unwrap(), *c.iter().max().unwrap())
            } else {
                Iv::TOP
            }
        }
        AluOp::Lt => match (a.is_exact(), b.is_exact()) {
            (Some(x), Some(y)) => Iv::exact((x < y) as i64),
            _ if a.hi < b.lo => Iv::exact(1),
            _ if a.lo >= b.hi => Iv::exact(0),
            _ => Iv::new(0, 1),
        },
        AluOp::Eq => match (a.is_exact(), b.is_exact()) {
            (Some(x), Some(y)) => Iv::exact((x == y) as i64),
            _ if a.disjoint(b.lo, b.hi) => Iv::exact(0),
            _ => Iv::new(0, 1),
        },
    }
}

/// Abstract value: which runtime shapes a register/slot can take, with
/// an interval on the integer shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct AbsVal {
    /// The register was never written on some path to here.
    uninit: bool,
    /// Can be `Val::Empty`.
    empty: bool,
    /// Can be `Val::Vec`.
    vec: bool,
    /// If it can be `Val::Int`, the interval it lies in.
    int: Option<Iv>,
}

impl AbsVal {
    const UNINIT: AbsVal = AbsVal { uninit: true, empty: true, vec: false, int: None };
    const EMPTY: AbsVal = AbsVal { uninit: false, empty: true, vec: false, int: None };
    const VEC: AbsVal = AbsVal { uninit: false, empty: false, vec: true, int: None };

    fn int(iv: Iv) -> AbsVal {
        AbsVal { uninit: false, empty: false, vec: false, int: Some(iv) }
    }

    fn join(a: AbsVal, b: AbsVal) -> AbsVal {
        AbsVal {
            uninit: a.uninit || b.uninit,
            empty: a.empty || b.empty,
            vec: a.vec || b.vec,
            int: match (a.int, b.int) {
                (Some(x), Some(y)) => Some(Iv::hull(x, y)),
                (x, None) => x,
                (None, y) => y,
            },
        }
    }

    /// Interval to use when the VM will read this as an integer.
    fn iv(&self) -> Iv {
        self.int.unwrap_or(Iv::TOP)
    }

    /// Definitely `Val::Empty` (an `IsSet` of this is exactly 0).
    fn pure_empty(&self) -> bool {
        !self.vec && self.int.is_none()
    }
}

/// Relational fact about what a register currently holds; used to refine
/// intervals at conditional branches.  Invalidated when any mentioned
/// register (or the holder) is rewritten.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Fact {
    None,
    /// Holder = `(a < b)` over the current values of `a` and `b`.
    Lt(Reg, Reg),
    /// Holder = `(reg != Empty)` for the current value of `reg`.
    SetOf(Reg),
    /// Holder = `1 << k` over the current value of `k`.
    Shl1(Reg),
}

impl Fact {
    fn mentions(&self, r: usize) -> bool {
        match *self {
            Fact::None => false,
            Fact::Lt(a, b) => a as usize == r || b as usize == r,
            Fact::SetOf(s) => s as usize == r,
            Fact::Shl1(k) => k as usize == r,
        }
    }
}

/// The dataflow state at one program point.
#[derive(Clone, PartialEq, Eq)]
struct State {
    regs: [AbsVal; NREGS],
    scratch: [AbsVal; SCRATCH_SLOTS],
    facts: [Fact; NREGS],
}

impl State {
    fn entry(scratch: &[AbsVal; SCRATCH_SLOTS]) -> State {
        State {
            regs: [AbsVal::UNINIT; NREGS],
            scratch: *scratch,
            facts: [Fact::None; NREGS],
        }
    }

    fn write(&mut self, r: Reg, v: AbsVal) {
        let ri = r as usize;
        for f in self.facts.iter_mut() {
            if f.mentions(ri) {
                *f = Fact::None;
            }
        }
        self.facts[ri] = Fact::None;
        self.regs[ri] = v;
    }

    fn join(a: &State, b: &State) -> State {
        State {
            regs: std::array::from_fn(|i| AbsVal::join(a.regs[i], b.regs[i])),
            scratch: std::array::from_fn(|i| AbsVal::join(a.scratch[i], b.scratch[i])),
            facts: std::array::from_fn(|i| if a.facts[i] == b.facts[i] {
                a.facts[i]
            } else {
                Fact::None
            }),
        }
    }
}

/// Widening thresholds: the constants the handler ISA's invariants live
/// at (round counts, slot bounds, wire ranges).  Climbing intervals jump
/// to the next threshold so the fixpoint converges without losing the
/// bounds the checks need.
const THRESHOLDS: [i64; 17] = [
    i64::MIN,
    -2,
    -1,
    0,
    1,
    2,
    15,
    16,
    17,
    31,
    32,
    47,
    48,
    63,
    64,
    MAX_P - 1,
    i64::MAX,
];

fn widen_iv(old: Iv, new: Iv) -> Iv {
    let lo = if new.lo < old.lo {
        *THRESHOLDS.iter().rev().find(|&&t| t <= new.lo).unwrap()
    } else {
        old.lo.min(new.lo)
    };
    let hi = if new.hi > old.hi {
        *THRESHOLDS.iter().find(|&&t| t >= new.hi).unwrap()
    } else {
        old.hi.max(new.hi)
    };
    Iv::new(lo, hi)
}

fn widen_val(old: AbsVal, new: AbsVal) -> AbsVal {
    AbsVal {
        int: match (old.int, new.int) {
            (Some(o), Some(n)) => Some(widen_iv(o, n)),
            (o, n) => o.or(n),
        },
        ..new
    }
}

fn env_iv(what: EnvVal) -> Iv {
    match what {
        EnvVal::Rank => Iv::new(0, MAX_P - 1),
        EnvVal::P => Iv::new(1, MAX_P),
        EnvVal::Inclusive => Iv::new(0, 1),
        // RD round structure: an in-protocol step field is a round index.
        EnvVal::PktStep => Iv::new(0, MAX_ROUNDS),
        EnvVal::PktSrc => Iv::new(0, MAX_P - 1),
        // MsgType wire codes are 1..=6; 0 inside a timer activation.
        EnvVal::PktKind => Iv::new(0, 6),
        // Retry counters are u32s maintained by the NIC; the program
        // only ever compares them, so the full unsigned range is fine.
        EnvVal::Retries | EnvVal::MaxRetries => Iv::new(0, u32::MAX as i64),
    }
}

// --------------------------------------------------------- refinement

/// Apply `fact` (known true when `positive`) to the state; `None` means
/// the branch is infeasible.
fn refine(mut st: State, fact: Fact, positive: bool) -> Option<State> {
    match (fact, positive) {
        (Fact::Lt(a, b), true) => {
            // a < b
            let (ia, ib) = (st.regs[a as usize].iv(), st.regs[b as usize].iv());
            let a_hi = ia.hi.min(ib.hi.saturating_sub(1));
            let b_lo = ib.lo.max(ia.lo.saturating_add(1));
            if let Some(iv) = st.regs[a as usize].int.as_mut() {
                iv.hi = iv.hi.min(a_hi);
                if iv.lo > iv.hi {
                    return None;
                }
            }
            if let Some(iv) = st.regs[b as usize].int.as_mut() {
                iv.lo = iv.lo.max(b_lo);
                if iv.lo > iv.hi {
                    return None;
                }
            }
            // chain through 1<<k facts: (1 << k) <= a_hi  =>  k <= log2
            if let Fact::Shl1(k) = st.facts[a as usize] {
                if a_hi >= 1 {
                    if let Some(iv) = st.regs[k as usize].int.as_mut() {
                        iv.hi = iv.hi.min(ilog2_floor(a_hi));
                        if iv.lo > iv.hi {
                            return None;
                        }
                    }
                }
            }
        }
        (Fact::Lt(a, b), false) => {
            // a >= b
            let (ia, ib) = (st.regs[a as usize].iv(), st.regs[b as usize].iv());
            let a_lo = ia.lo.max(ib.lo);
            let b_hi = ib.hi.min(ia.hi);
            if let Some(iv) = st.regs[a as usize].int.as_mut() {
                iv.lo = iv.lo.max(a_lo);
                if iv.lo > iv.hi {
                    return None;
                }
            }
            if let Some(iv) = st.regs[b as usize].int.as_mut() {
                iv.hi = iv.hi.min(b_hi);
                if iv.lo > iv.hi {
                    return None;
                }
            }
        }
        (Fact::SetOf(s), true) => {
            let v = &mut st.regs[s as usize];
            if v.pure_empty() && !v.uninit {
                return None; // definitely Empty: "set" branch infeasible
            }
            v.empty = false;
            v.uninit = false;
        }
        (Fact::SetOf(s), false) => {
            let v = &mut st.regs[s as usize];
            if !v.empty && !v.uninit {
                return None; // definitely set: "empty" branch infeasible
            }
            v.vec = false;
            v.int = None;
            v.empty = true;
        }
        _ => {}
    }
    Some(st)
}

/// Refine a branch condition register itself around zero.
fn refine_cond(mut st: State, cond: Reg, taken_zero: bool) -> Option<State> {
    if let Some(iv) = st.regs[cond as usize].int.as_mut() {
        if taken_zero {
            if iv.disjoint(0, 0) {
                return None;
            }
            *iv = Iv::exact(0);
        } else {
            if iv.is_exact() == Some(0) {
                return None;
            }
            if iv.lo == 0 {
                iv.lo = 1;
            } else if iv.hi == 0 {
                iv.hi = -1;
            }
        }
    }
    Some(st)
}

// ----------------------------------------------------------- analyzer

struct Analysis {
    /// Converged in-state per pc (None = unreachable).
    in_states: Vec<Option<State>>,
}

/// Scratch slot range a slot register can address (clamped); None if it
/// can never be a valid slot.
fn slot_range(v: AbsVal) -> Option<(usize, usize)> {
    let iv = v.iv();
    if iv.disjoint(0, SCRATCH_SLOTS as i64 - 1) {
        return None;
    }
    let lo = iv.lo.clamp(0, SCRATCH_SLOTS as i64 - 1) as usize;
    let hi = iv.hi.clamp(0, SCRATCH_SLOTS as i64 - 1) as usize;
    Some((lo, hi))
}

/// Abstract transfer of one instruction: successor (pc, state) pairs.
/// Terminators push their scratch into `exit_scratch` instead.
fn transfer(
    prog: &Program,
    pc: usize,
    st: &State,
    exit_scratch: &mut [AbsVal; SCRATCH_SLOTS],
) -> Vec<(usize, State)> {
    let mut out = Vec::with_capacity(2);
    let mut s = st.clone();
    match prog.code[pc] {
        Instr::Imm { dst, val } => {
            s.write(dst, AbsVal::int(Iv::exact(val)));
            out.push((pc + 1, s));
        }
        Instr::Mov { dst, src } => {
            let v = s.regs[src as usize];
            s.write(dst, v);
            out.push((pc + 1, s));
        }
        Instr::Env { dst, what } => {
            s.write(dst, AbsVal::int(env_iv(what)));
            out.push((pc + 1, s));
        }
        Instr::LdPkt { dst } | Instr::EmptyLike { dst, .. } | Instr::IdentLike { dst, .. } => {
            s.write(dst, AbsVal::VEC);
            out.push((pc + 1, s));
        }
        Instr::Ld { dst, slot } => {
            if let Some((lo, hi)) = slot_range(s.regs[slot as usize]) {
                let mut v = s.scratch[lo];
                for sl in lo + 1..=hi {
                    v = AbsVal::join(v, s.scratch[sl]);
                }
                s.write(dst, v);
                out.push((pc + 1, s));
            }
            // certainly-OOB slot: the path dies on the VM assert
        }
        Instr::St { slot, src } => {
            let v = s.regs[src as usize];
            let stored = AbsVal { uninit: false, ..v };
            if let Some((lo, hi)) = slot_range(s.regs[slot as usize]) {
                if lo == hi && s.regs[slot as usize].iv().is_exact().is_some() {
                    s.scratch[lo] = stored; // strong update
                } else {
                    for sl in lo..=hi {
                        s.scratch[sl] = AbsVal::join(s.scratch[sl], stored);
                    }
                }
                out.push((pc + 1, s));
            }
        }
        Instr::Clr { slot } => {
            if let Some((lo, hi)) = slot_range(s.regs[slot as usize]) {
                if lo == hi && s.regs[slot as usize].iv().is_exact().is_some() {
                    s.scratch[lo] = AbsVal::EMPTY;
                } else {
                    for sl in lo..=hi {
                        s.scratch[sl] = AbsVal::join(s.scratch[sl], AbsVal::EMPTY);
                    }
                }
                out.push((pc + 1, s));
            }
        }
        Instr::Alu { op, dst, a, b } => {
            let (ia, ib) = (s.regs[a as usize].iv(), s.regs[b as usize].iv());
            s.write(dst, AbsVal::int(alu_iv(op, ia, ib)));
            // record relational facts for later branch refinement
            let fact = match op {
                AluOp::Lt if dst != a && dst != b => Fact::Lt(a, b),
                AluOp::Shl if dst != b && ia.is_exact() == Some(1) => Fact::Shl1(b),
                _ => Fact::None,
            };
            s.facts[dst as usize] = fact;
            out.push((pc + 1, s));
        }
        Instr::Combine { dst, .. } => {
            s.write(dst, AbsVal::VEC);
            out.push((pc + 1, s));
        }
        Instr::IsSet { dst, src } => {
            let v = s.regs[src as usize];
            let res = if v.pure_empty() {
                Iv::exact(0) // uninit or Empty both read as Empty
            } else if !v.empty && !v.uninit {
                Iv::exact(1)
            } else {
                Iv::new(0, 1)
            };
            let fact = if dst != src { Fact::SetOf(src) } else { Fact::None };
            s.write(dst, AbsVal::int(res));
            s.facts[dst as usize] = fact;
            out.push((pc + 1, s));
        }
        Instr::Jmp { to } => out.push((to, s)),
        Instr::Jz { cond, to } | Instr::Jnz { cond, to } => {
            let jz = matches!(prog.code[pc], Instr::Jz { .. });
            let fact = s.facts[cond as usize];
            // taken edge
            let taken_zero = jz; // Jz takes on zero, Jnz on non-zero
            if let Some(t) = refine_cond(s.clone(), cond, taken_zero)
                .and_then(|t| refine(t, fact, !jz))
            {
                out.push((to, t));
            }
            // fall-through edge
            if let Some(ft) =
                refine_cond(s, cond, !taken_zero).and_then(|t| refine(t, fact, jz))
            {
                out.push((pc + 1, ft));
            }
        }
        Instr::Emit { .. } | Instr::Deliver { .. } | Instr::Retx => out.push((pc + 1, s)),
        Instr::Drop | Instr::Halt => {
            for (e, v) in exit_scratch.iter_mut().zip(s.scratch.iter()) {
                *e = AbsVal::join(*e, *v);
            }
        }
    }
    out
}

/// Visits to a pc before interval widening kicks in.
const WIDEN_AT: u32 = 6;
/// Visits to a pc before a full blow-to-top (termination backstop).
const TOP_AT: u32 = 60;

fn analyze_entry(
    prog: &Program,
    entry: usize,
    entry_scratch: &[AbsVal; SCRATCH_SLOTS],
    exit_scratch: &mut [AbsVal; SCRATCH_SLOTS],
) -> Analysis {
    let n = prog.code.len();
    let mut in_states: Vec<Option<State>> = vec![None; n];
    let mut visits = vec![0u32; n];
    let mut work: VecDeque<usize> = VecDeque::new();
    in_states[entry] = Some(State::entry(entry_scratch));
    work.push_back(entry);

    while let Some(pc) = work.pop_front() {
        let st = in_states[pc].clone().expect("queued pc has a state");
        for (succ, new_st) in transfer(prog, pc, &st, exit_scratch) {
            visits[succ] += 1;
            let joined = match &in_states[succ] {
                None => new_st,
                Some(old) => {
                    let mut j = State::join(old, &new_st);
                    if visits[succ] >= TOP_AT {
                        for v in j.regs.iter_mut().chain(j.scratch.iter_mut()) {
                            if let Some(iv) = v.int.as_mut() {
                                *iv = Iv::TOP;
                            }
                        }
                    } else if visits[succ] >= WIDEN_AT {
                        for (jv, ov) in j.regs.iter_mut().zip(old.regs.iter()) {
                            *jv = widen_val(*ov, *jv);
                        }
                        for (jv, ov) in j.scratch.iter_mut().zip(old.scratch.iter()) {
                            *jv = widen_val(*ov, *jv);
                        }
                    }
                    j
                }
            };
            if in_states[succ].as_ref() != Some(&joined) {
                in_states[succ] = Some(joined);
                work.push_back(succ);
            }
        }
    }
    Analysis { in_states }
}

// ------------------------------------------------------- structural

/// Structural CFG successors (taken + fall-through).
fn successors(instr: Instr, pc: usize) -> Vec<usize> {
    match instr {
        Instr::Jmp { to } => vec![to],
        Instr::Jz { to, .. } | Instr::Jnz { to, .. } => vec![to, pc + 1],
        Instr::Drop | Instr::Halt => vec![],
        _ => vec![pc + 1],
    }
}

/// Every register operand an instruction names.
fn regs_of(instr: Instr) -> Vec<Reg> {
    match instr {
        Instr::Imm { dst, .. } | Instr::Env { dst, .. } | Instr::LdPkt { dst } => vec![dst],
        Instr::Mov { dst, src }
        | Instr::EmptyLike { dst, src }
        | Instr::IdentLike { dst, src }
        | Instr::IsSet { dst, src } => vec![dst, src],
        Instr::Ld { dst, slot } => vec![dst, slot],
        Instr::St { slot, src } => vec![slot, src],
        Instr::Clr { slot } => vec![slot],
        Instr::Alu { dst, a, b, .. } | Instr::Combine { dst, a, b } => vec![dst, a, b],
        Instr::Jz { cond, .. } | Instr::Jnz { cond, .. } => vec![cond],
        Instr::Emit { dst, step, payload, .. } => vec![dst, step, payload],
        Instr::Deliver { payload } => vec![payload],
        Instr::Jmp { .. } | Instr::Drop | Instr::Halt | Instr::Retx => vec![],
    }
}

/// Checks that need no dataflow: entries and jump targets in range,
/// register indices valid, no fall-through off the end.  Dataflow
/// assumes these hold, so any hit here returns before it runs.
fn structural_rejects(prog: &Program) -> Vec<RejectReason> {
    let mut out = Vec::new();
    let n = prog.code.len();
    if prog.on_request >= n {
        out.push(RejectReason::BadEntry { which: "on_request", target: prog.on_request });
    }
    if prog.on_packet >= n {
        out.push(RejectReason::BadEntry { which: "on_packet", target: prog.on_packet });
    }
    if prog.on_timer >= n {
        out.push(RejectReason::BadEntry { which: "on_timer", target: prog.on_timer });
    }
    for (pc, instr) in prog.code.iter().enumerate() {
        for reg in regs_of(*instr) {
            if reg as usize >= NREGS {
                out.push(RejectReason::BadRegister { pc, reg });
            }
        }
        if let Instr::Jmp { to } | Instr::Jz { to, .. } | Instr::Jnz { to, .. } = *instr {
            if to >= n {
                out.push(RejectReason::BadTarget { pc, target: to });
            }
        }
    }
    if n > 0 && !matches!(prog.code[n - 1], Instr::Halt | Instr::Drop | Instr::Jmp { .. }) {
        out.push(RejectReason::MissingHalt { pc: n - 1 });
    }
    out
}

// -------------------------------------------------- termination + cost

/// Strongly connected components of the reachable CFG, iterative Tarjan.
/// Emitted sinks-first (reverse topological order of the condensation).
fn sccs(n: usize, succs: &[Vec<usize>], reach: &[bool]) -> Vec<Vec<usize>> {
    let mut index = vec![usize::MAX; n];
    let mut low = vec![0usize; n];
    let mut on_stack = vec![false; n];
    let mut stack: Vec<usize> = Vec::new();
    let mut next = 0usize;
    let mut comps: Vec<Vec<usize>> = Vec::new();
    for start in 0..n {
        if !reach[start] || index[start] != usize::MAX {
            continue;
        }
        let mut frames: Vec<(usize, usize)> = vec![(start, 0)];
        while let Some(frame) = frames.last_mut() {
            let v = frame.0;
            if frame.1 == 0 && index[v] == usize::MAX {
                index[v] = next;
                low[v] = next;
                next += 1;
                stack.push(v);
                on_stack[v] = true;
            }
            if frame.1 < succs[v].len() {
                let w = succs[v][frame.1];
                frame.1 += 1;
                if index[w] == usize::MAX {
                    frames.push((w, 0));
                } else if on_stack[w] {
                    low[v] = low[v].min(index[w]);
                }
            } else {
                frames.pop();
                if let Some(parent) = frames.last() {
                    let u = parent.0;
                    low[u] = low[u].min(low[v]);
                }
                if low[v] == index[v] {
                    let mut comp = Vec::new();
                    loop {
                        let w = stack.pop().expect("tarjan stack");
                        on_stack[w] = false;
                        comp.push(w);
                        if w == v {
                            break;
                        }
                    }
                    comps.push(comp);
                }
            }
        }
    }
    comps
}

/// Worst-case instruction bound per activation.  Every nontrivial SCC
/// costs `|SCC| * LOOP_BOUND^B` instructions (B = backwards program-order
/// edges inside it: each is an RD-round back-edge taken at most
/// [`LOOP_BOUND`] times, and any cycle must contain one); trivial nodes
/// cost 1.  The entry bound is the longest path through the SCC
/// condensation, which the sinks-first emission order makes a single
/// backwards sweep.
fn cost_bound(
    prog: &Program,
    succs: &[Vec<usize>],
    reach: &[bool],
) -> (CostReport, Vec<RejectReason>) {
    let n = prog.code.len();
    let comps = sccs(n, succs, reach);
    let mut comp_of = vec![usize::MAX; n];
    for (ci, comp) in comps.iter().enumerate() {
        for &v in comp {
            comp_of[v] = ci;
        }
    }
    let mut cost: Vec<u128> = Vec::with_capacity(comps.len());
    let mut loops: Vec<LoopReport> = Vec::new();
    for comp in &comps {
        let me = comp_of[comp[0]];
        let nontrivial = comp.len() > 1 || succs[comp[0]].contains(&comp[0]);
        if !nontrivial {
            cost.push(1);
            continue;
        }
        let mut back = 0usize;
        for &u in comp {
            for &w in &succs[u] {
                if comp_of[w] == me && w <= u {
                    back += 1;
                }
            }
        }
        let back = back.max(1);
        let iters = (LOOP_BOUND as u128).checked_pow(back.min(8) as u32).unwrap_or(u128::MAX);
        let bound = (comp.len() as u128).saturating_mul(iters);
        loops.push(LoopReport {
            head: *comp.iter().min().expect("nonempty scc"),
            body: comp.len(),
            back_edges: back,
            bound: bound.min(usize::MAX as u128) as usize,
        });
        cost.push(bound);
    }
    let mut best = vec![0u128; comps.len()];
    for (ci, comp) in comps.iter().enumerate() {
        let mut downstream = 0u128;
        for &v in comp {
            for &w in &succs[v] {
                let cw = comp_of[w];
                if cw != ci {
                    downstream = downstream.max(best[cw]);
                }
            }
        }
        best[ci] = cost[ci].saturating_add(downstream);
    }
    let bound_of =
        |entry: usize| -> usize { best[comp_of[entry]].min(usize::MAX as u128) as usize };
    let on_request_bound = bound_of(prog.on_request);
    let on_packet_bound = bound_of(prog.on_packet);
    let on_timer_bound = bound_of(prog.on_timer);
    let mut rejects = Vec::new();
    if on_request_bound > MAX_STEPS {
        rejects
            .push(RejectReason::BudgetExceeded { entry: "on_request", bound: on_request_bound });
    }
    if on_packet_bound > MAX_STEPS {
        rejects.push(RejectReason::BudgetExceeded { entry: "on_packet", bound: on_packet_bound });
    }
    if on_timer_bound > MAX_STEPS {
        rejects.push(RejectReason::BudgetExceeded { entry: "on_timer", bound: on_timer_bound });
    }
    loops.sort_by_key(|l| l.head);
    (CostReport { on_request_bound, on_packet_bound, on_timer_bound, loops }, rejects)
}

// ---------------------------------------------------------- check pass

/// Rejection checks for one instruction against its converged in-state.
/// Run only after the fixpoint: transient states mid-analysis would
/// produce spurious findings.
fn check_instr(pc: usize, instr: Instr, st: &State) -> Vec<RejectReason> {
    let mut out = Vec::new();
    let int_read = |r: Reg, out: &mut Vec<RejectReason>| {
        let v = st.regs[r as usize];
        if v.uninit {
            out.push(RejectReason::UninitRead { pc, reg: r });
        } else if v.int.is_none() {
            out.push(RejectReason::DtypeMismatch { pc, reg: r, expected: "integer" });
        }
    };
    let vec_read = |r: Reg, out: &mut Vec<RejectReason>| {
        let v = st.regs[r as usize];
        if v.uninit {
            out.push(RejectReason::UninitRead { pc, reg: r });
        } else if !v.vec {
            out.push(RejectReason::DtypeMismatch { pc, reg: r, expected: "payload" });
        }
    };
    let any_read = |r: Reg, out: &mut Vec<RejectReason>| {
        if st.regs[r as usize].uninit {
            out.push(RejectReason::UninitRead { pc, reg: r });
        }
    };
    let slot_bounds = |r: Reg, out: &mut Vec<RejectReason>| {
        let v = st.regs[r as usize];
        if !v.uninit {
            if let Some(iv) = v.int {
                if !iv.within(0, SCRATCH_SLOTS as i64 - 1) {
                    out.push(RejectReason::ScratchOob { pc, lo: iv.lo, hi: iv.hi });
                }
            }
        }
    };
    match instr {
        Instr::Imm { .. } | Instr::Env { .. } | Instr::LdPkt { .. } => {}
        Instr::Mov { src, .. } | Instr::IsSet { src, .. } => any_read(src, &mut out),
        Instr::EmptyLike { src, .. } | Instr::IdentLike { src, .. } => vec_read(src, &mut out),
        Instr::Ld { slot, .. } | Instr::Clr { slot } => {
            int_read(slot, &mut out);
            slot_bounds(slot, &mut out);
        }
        Instr::St { slot, src } => {
            int_read(slot, &mut out);
            slot_bounds(slot, &mut out);
            any_read(src, &mut out);
        }
        Instr::Alu { op, a, b, .. } => {
            int_read(a, &mut out);
            int_read(b, &mut out);
            if matches!(op, AluOp::Shl | AluOp::Shr) {
                let v = st.regs[b as usize];
                if !v.uninit {
                    if let Some(iv) = v.int {
                        if !iv.within(0, 63) {
                            out.push(RejectReason::ShiftRange { pc, lo: iv.lo, hi: iv.hi });
                        }
                    }
                }
            }
        }
        Instr::Combine { a, b, .. } => {
            vec_read(a, &mut out);
            vec_read(b, &mut out);
        }
        Instr::Jz { cond, .. } | Instr::Jnz { cond, .. } => int_read(cond, &mut out),
        Instr::Emit { dst, step, payload, .. } => {
            int_read(dst, &mut out);
            int_read(step, &mut out);
            vec_read(payload, &mut out);
            // only *certain* wire violations are static facts; "maybe
            // out of [0, p)" is the runtime assert's job
            let d = st.regs[dst as usize];
            if !d.uninit {
                if let Some(iv) = d.int {
                    if iv.disjoint(0, MAX_P - 1) {
                        out.push(RejectReason::WireRange { pc, lo: iv.lo, hi: iv.hi });
                    }
                }
            }
            let sv = st.regs[step as usize];
            if !sv.uninit {
                if let Some(iv) = sv.int {
                    if iv.disjoint(0, u16::MAX as i64) {
                        out.push(RejectReason::WireRange { pc, lo: iv.lo, hi: iv.hi });
                    }
                }
            }
        }
        Instr::Deliver { payload } => vec_read(payload, &mut out),
        // Retx replays a frame the NIC already holds: it names no
        // registers and writes nothing, so there is nothing to check.
        Instr::Jmp { .. } | Instr::Drop | Instr::Halt | Instr::Retx => {}
    }
    out
}

// ------------------------------------------------------------- verify

/// Statically verify a handler program.  `Ok` carries the proof
/// artifacts (worst-case activation bounds); `Err` carries every
/// finding, most fundamental first.
pub fn verify(prog: &Program) -> Result<CostReport, Vec<RejectReason>> {
    let mut rejects = structural_rejects(prog);
    if !rejects.is_empty() {
        return Err(rejects);
    }
    let n = prog.code.len();
    let succs: Vec<Vec<usize>> = (0..n).map(|pc| successors(prog.code[pc], pc)).collect();

    // reachability from all three entries
    let mut reach = vec![false; n];
    let mut stack = vec![prog.on_request, prog.on_packet, prog.on_timer];
    while let Some(v) = stack.pop() {
        if !reach[v] {
            reach[v] = true;
            for &w in &succs[v] {
                stack.push(w);
            }
        }
    }

    // termination: every reachable pc must reach a Halt/Drop
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
    for v in 0..n {
        if reach[v] {
            for &w in &succs[v] {
                preds[w].push(v);
            }
        }
    }
    let mut can_exit = vec![false; n];
    let mut stack: Vec<usize> = (0..n)
        .filter(|&v| reach[v] && matches!(prog.code[v], Instr::Halt | Instr::Drop))
        .collect();
    for &v in &stack {
        can_exit[v] = true;
    }
    while let Some(v) = stack.pop() {
        for &u in &preds[v] {
            if !can_exit[u] {
                can_exit[u] = true;
                stack.push(u);
            }
        }
    }
    if let Some(pc) = (0..n).find(|&v| reach[v] && !can_exit[v]) {
        rejects.push(RejectReason::NoTermination { pc });
    }

    // worst-case instruction budget
    let (report, budget_rejects) = cost_bound(prog, &succs, &reach);
    rejects.extend(budget_rejects);

    // dataflow, with the inter-activation scratch fixpoint: the
    // scratchpad persists across activations, so each entry is analyzed
    // against the join of every exit's scratch state until stable
    let mut entry_scratch = [AbsVal::EMPTY; SCRATCH_SLOTS];
    let mut rounds = 0usize;
    let (req_an, pkt_an, tmr_an) = loop {
        rounds += 1;
        let mut out_scratch = entry_scratch;
        let a = analyze_entry(prog, prog.on_request, &entry_scratch, &mut out_scratch);
        let b = analyze_entry(prog, prog.on_packet, &entry_scratch, &mut out_scratch);
        let c = analyze_entry(prog, prog.on_timer, &entry_scratch, &mut out_scratch);
        let mut next = entry_scratch;
        let mut changed = false;
        for i in 0..SCRATCH_SLOTS {
            let mut j = AbsVal::join(entry_scratch[i], out_scratch[i]);
            if rounds > 4 {
                j = widen_val(entry_scratch[i], j);
            }
            if rounds > 32 {
                if let Some(iv) = j.int.as_mut() {
                    *iv = Iv::TOP;
                }
            }
            if j != next[i] {
                next[i] = j;
                changed = true;
            }
        }
        if !changed {
            break (a, b, c);
        }
        entry_scratch = next;
    };

    for an in [&req_an, &pkt_an, &tmr_an] {
        for (pc, st) in an.in_states.iter().enumerate() {
            if let Some(st) = st {
                for r in check_instr(pc, prog.code[pc], st) {
                    if !rejects.contains(&r) {
                        rejects.push(r);
                    }
                }
            }
        }
    }
    if rejects.is_empty() {
        Ok(report)
    } else {
        Err(rejects)
    }
}

/// Verify at image-build time.  A rejected program never reaches the
/// cluster: this panics with the full finding list, naming the image.
pub fn verify_or_panic(prog: &Program) -> CostReport {
    match verify(prog) {
        Ok(report) => report,
        Err(reasons) => {
            let lines: Vec<String> =
                reasons.iter().map(|r| format!("  {r} [{}]", r.class())).collect();
            panic!(
                "handler program {} rejected by the static verifier:\n{}",
                prog.name,
                lines.join("\n")
            );
        }
    }
}

// --------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nic::programs::program_for;
    use crate::nic::vm::Asm;
    use crate::packet::CollType;

    /// Reject classes for a program the verifier must refuse.
    fn classes(prog: &Program) -> Vec<&'static str> {
        verify(prog).expect_err("must reject").iter().map(|r| r.class()).collect()
    }

    #[test]
    fn shipped_images_verify_within_budget() {
        for coll in CollType::HANDLER_SET {
            let prog = program_for(coll);
            let report = verify(prog).unwrap_or_else(|rs| {
                let lines: Vec<String> = rs.iter().map(|r| r.to_string()).collect();
                panic!("{coll:?} rejected:\n{}", lines.join("\n"))
            });
            assert!(
                report.on_request_bound <= MAX_STEPS
                    && report.on_packet_bound <= MAX_STEPS
                    && report.on_timer_bound <= MAX_STEPS,
                "{coll:?}: bounds {}/{}/{} exceed {MAX_STEPS}",
                report.on_request_bound,
                report.on_packet_bound,
                report.on_timer_bound
            );
            assert!(report.on_request_bound > 0 && report.on_packet_bound > 0);
            assert!(report.on_timer_bound > 0, "{coll:?}: timer entry must be reachable");
        }
    }

    #[test]
    fn scan_image_reports_bounded_loops() {
        let report = verify(program_for(CollType::Scan)).expect("scan verifies");
        assert!(!report.loops.is_empty(), "scan's advance loop must be reported");
        for l in &report.loops {
            assert!(l.back_edges >= 1);
            assert!(l.bound <= MAX_STEPS, "loop @{} bound {} too large", l.head, l.bound);
        }
    }

    #[test]
    fn accepts_rd_style_counting_loop() {
        // k = 0; while (1 << k) < p { k += 1 } — the idiom every shipped
        // program uses.  Acceptance hinges on the Shl1 fact: falling
        // through the guard proves (1 << k) < p <= 2^16, hence k <= 15,
        // so the shift amount stays provably in range.
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.imm(0, 0); // k
        a.imm(1, 1);
        let head = a.label();
        let done = a.label();
        a.bind(head);
        a.alu(AluOp::Shl, 2, 1, 0); // 1 << k
        a.env(3, EnvVal::P);
        a.alu(AluOp::Lt, 4, 2, 3);
        a.jz(4, done);
        a.alu(AluOp::Add, 0, 0, 1);
        a.jmp(head);
        a.bind(done);
        a.halt();
        let prog = a.finish("t-rdloop", entry, entry);
        let report = verify(&prog).expect("rd counting loop verifies");
        assert!(report.on_request_bound <= MAX_STEPS);
        assert_eq!(report.loops.len(), 1);
    }

    #[test]
    fn rejects_uninit_read() {
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.alu(AluOp::Add, 0, 1, 2); // r1, r2 never written
        a.halt();
        let prog = a.finish("t-uninit", entry, entry);
        assert!(classes(&prog).contains(&"uninit-read"));
    }

    #[test]
    fn rejects_fall_through_off_the_end() {
        // Hand-built image: `Asm::finish` would append the (Halt-
        // terminated) standard timer block and mask the fall-through.
        let prog = Program {
            name: "t-fallthrough",
            code: vec![Instr::Imm { dst: 0, val: 1 }],
            on_request: 0,
            on_packet: 0,
            on_timer: 0,
        };
        assert!(classes(&prog).contains(&"missing-halt"));
    }

    #[test]
    fn rejects_inescapable_loop() {
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.jmp(entry);
        let prog = a.finish("t-spin", entry, entry);
        assert!(classes(&prog).contains(&"no-termination"));
    }

    #[test]
    fn rejects_scratch_oob() {
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.imm(0, SCRATCH_SLOTS as i64); // one past the end
        a.imm(1, 7);
        a.st(0, 1);
        a.halt();
        let prog = a.finish("t-oob", entry, entry);
        assert!(classes(&prog).contains(&"scratch-oob"));
    }

    #[test]
    fn rejects_combine_on_integers() {
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.imm(0, 1);
        a.imm(1, 2);
        a.combine(2, 0, 1);
        a.halt();
        let prog = a.finish("t-dtype", entry, entry);
        assert!(classes(&prog).contains(&"dtype-mismatch"));
    }

    #[test]
    fn rejects_shift_out_of_range() {
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.imm(0, 1);
        a.imm(1, 64); // amount provably outside 0..64
        a.alu(AluOp::Shl, 2, 0, 1);
        a.halt();
        let prog = a.finish("t-shift", entry, entry);
        assert!(classes(&prog).contains(&"shift-range"));
    }

    #[test]
    fn rejects_budget_blowup() {
        // one structural loop whose body alone pushes body * LOOP_BOUND
        // past the activation budget
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.imm(0, 0);
        a.imm(1, 1);
        let head = a.label();
        a.bind(head);
        for _ in 0..300 {
            a.alu(AluOp::Add, 0, 0, 1);
        }
        a.env(2, EnvVal::P);
        a.alu(AluOp::Lt, 3, 0, 2);
        a.jnz(3, head);
        a.halt();
        let prog = a.finish("t-budget", entry, entry);
        assert!(classes(&prog).contains(&"budget"));
    }

    #[test]
    fn rejects_bad_target_and_bad_entry() {
        let prog = Program {
            name: "t-badjump",
            code: vec![Instr::Jmp { to: 99 }, Instr::Halt],
            on_request: 0,
            on_packet: 0,
            on_timer: 1,
        };
        assert!(classes(&prog).contains(&"bad-target"));
        let prog = Program {
            name: "t-badentry",
            code: vec![Instr::Halt],
            on_request: 5,
            on_packet: 0,
            on_timer: 0,
        };
        assert!(classes(&prog).contains(&"bad-entry"));
        let prog = Program {
            name: "t-badtimer",
            code: vec![Instr::Halt],
            on_request: 0,
            on_packet: 0,
            on_timer: 9,
        };
        let rejects = verify(&prog).expect_err("must reject");
        assert!(
            rejects
                .iter()
                .any(|r| matches!(r, RejectReason::BadEntry { which: "on_timer", .. })),
            "{rejects:?}"
        );
    }

    #[test]
    fn standard_timer_block_verifies_with_small_bound() {
        // The auto-appended retry policy (retries < max_retries -> Retx)
        // must prove out on its own: straight-line, loop-free, tiny.
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.halt();
        let prog = a.finish("t-timer-default", entry, entry);
        let report = verify(&prog).expect("default timer block verifies");
        assert!(
            report.on_timer_bound >= 4 && report.on_timer_bound <= 16,
            "straight-line timer policy, got bound {}",
            report.on_timer_bound
        );
    }

    #[test]
    fn rejects_uninit_read_reachable_only_from_timer_entry() {
        // A defect on the retransmit path alone must still be caught:
        // the timer entry is verified like the other two.
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.halt();
        let timer = a.label();
        a.bind(timer);
        a.alu(AluOp::Add, 0, 1, 2); // r1, r2 never written on this path
        a.halt();
        let prog = a.finish_with_timer("t-timer-uninit", entry, entry, timer);
        assert!(classes(&prog).contains(&"uninit-read"));
    }

    #[test]
    fn rejects_unbounded_timer_loop() {
        // An inescapable spin in the retransmit handler would wedge the
        // very flow recovery is meant to rescue.
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.halt();
        let timer = a.label();
        a.bind(timer);
        a.retx();
        a.jmp(timer);
        let prog = a.finish_with_timer("t-timer-spin", entry, entry, timer);
        assert!(classes(&prog).contains(&"no-termination"));
    }

    #[test]
    fn reject_display_names_the_site() {
        let r = RejectReason::UninitRead { pc: 7, reg: 3 };
        let s = r.to_string();
        assert!(s.contains("@7") && s.contains("r3"), "{s}");
        assert_eq!(r.class(), "uninit-read");
    }
}
