//! Handler programs: the five offloaded collectives, written against the
//! [`vm`](super::vm) instruction set.
//!
//! Three programs cover the five collectives:
//!
//! - **scan** (MPI_Scan + MPI_Exscan, `Env::Inclusive` selects) — the
//!   recursive-doubling exchange of the paper's SSIII-C, minus the
//!   multicast optimization (a handler emits plain unicasts; the
//!   fixed-function path keeps that trick).  Fold order matches
//!   `fpga::rd::RdEngine` exactly, so results are bit-identical.
//! - **allreduce** (MPI_Allreduce + MPI_Barrier — a barrier is an
//!   allreduce with a zero-element payload) — the recursive-doubling
//!   butterfly of `fpga::allreduce::RdAllreduce`, same fold order.
//! - **bcast** (MPI_Bcast, root = local rank 0) — a binomial *gather of
//!   empty ready-tokens* up to the root, then the root's payload
//!   multiplied down the same tree.  The token phase is what bounds
//!   epoch skew: a card delivers only after its whole subtree has
//!   entered the collective, so the NIC's 8-entry engine table can
//!   never be flooded by a fast root racing ahead.
//!
//! Every program runs in communicator-local rank space and reads rank /
//! p / inclusiveness from the VM environment — one program image serves
//! every rank of every communicator (the sPIN model: programs are code,
//! flows are state).
//!
//! Scratchpad layout (by convention; slots 16+ are the packet inbox,
//! indexed by algorithm step):
//!
//! | slot | scan            | allreduce    | bcast        |
//! |------|-----------------|--------------|--------------|
//! | 0    | called          | called       | called       |
//! | 1    | step            | step         | t (children) |
//! | 2    | partial         | value        | up tokens    |
//! | 3    | inclusive acc   | —            | own payload  |
//! | 4    | exclusive acc   | —            | total        |
//! | 5    | sent-through    | sent-through | up sent      |
//! | 6    | delivered       | delivered    | delivered    |

use std::sync::OnceLock;

use crate::fpga::engine::{CollEngine, EngineCtx, NicAction};
use crate::packet::{AlgoType, CollPacket, CollType, MsgType};
use crate::sim::OffloadRequest;

use super::vm::{self, Activation, AluOp, Asm, EnvVal, Flow, Program, Reg};

// Scratchpad slot conventions (see module table).
const S_CALLED: i64 = 0;
const S_STEP: i64 = 1;
const S_PARTIAL: i64 = 2; // scan partial / allreduce value / bcast token count
const S_INC: i64 = 3; // scan inclusive acc / bcast own payload
const S_EXC: i64 = 4; // scan exclusive acc / bcast total
const S_SENT: i64 = 5;
const S_DONE: i64 = 6;
/// Packet inbox base: slot 16 + step.
const INBOX: i64 = 16;

// bcast aliases for readability
const S_T: i64 = S_STEP;
const S_UPSEEN: i64 = S_PARTIAL;
const S_OWN: i64 = S_INC;
const S_TOTAL: i64 = S_EXC;
const S_UPSENT: i64 = S_SENT;

/// Load `scratch[slot]` into `dst` (r15 is the reserved slot-pointer
/// register of these programs).
fn lds(a: &mut Asm, dst: Reg, slot: i64) {
    a.imm(15, slot);
    a.ld(dst, 15);
}

/// Store `src` into `scratch[slot]`.
fn sts(a: &mut Asm, slot: i64, src: Reg) {
    a.imm(15, slot);
    a.st(15, src);
}

/// The recursive-doubling scan/exscan program (see module docs).
fn build_scan() -> Program {
    use AluOp::*;
    use EnvVal::*;
    let mut a = Asm::new();
    let on_request = a.label();
    let on_packet = a.label();
    let advance = a.label();
    let after_send = a.label();
    let fold_low = a.label();
    let exc_has = a.label();
    let exc_done = a.label();
    let fold_done = a.label();
    let finish = a.label();
    let not_incl = a.label();
    let exc_ident = a.label();
    let mark = a.label();
    let already = a.label();
    let park = a.label();

    // -- packet: buffer the partner's step-k block; advance if called.
    a.bind(on_packet);
    a.env(0, PktStep);
    a.imm(1, INBOX);
    a.alu(Add, 13, 0, 1);
    a.ldpkt(8);
    a.st(13, 8);
    lds(&mut a, 4, S_CALLED);
    a.is_set(4, 4);
    a.jz(4, park);
    a.jmp(advance);

    // -- request: partial = inc = own; step = sent = 0.
    a.bind(on_request);
    a.ldpkt(8);
    sts(&mut a, S_PARTIAL, 8);
    sts(&mut a, S_INC, 8);
    a.imm(0, 1);
    sts(&mut a, S_CALLED, 0);
    a.imm(0, 0);
    sts(&mut a, S_STEP, 0);
    sts(&mut a, S_SENT, 0);
    // falls through into the advance loop

    // -- advance: per step k, send our partial once, then fold the
    //    partner's block when it is in; stop at the first missing input.
    a.bind(advance);
    lds(&mut a, 0, S_STEP); // r0 = k
    a.imm(1, 1);
    a.alu(Shl, 2, 1, 0); // r2 = 1 << k
    a.env(3, P);
    a.alu(Lt, 4, 2, 3);
    a.jz(4, finish); // all log2(p) steps folded
    lds(&mut a, 5, S_SENT);
    a.alu(Lt, 4, 0, 5); // k < sent -> already sent this step
    a.jnz(4, after_send);
    a.env(6, Rank);
    a.alu(Xor, 7, 6, 2); // partner = rank ^ 2^k
    lds(&mut a, 8, S_PARTIAL);
    a.emit(7, MsgType::Data, 0, 8);
    a.alu(Add, 10, 0, 1);
    sts(&mut a, S_SENT, 10);
    a.bind(after_send);
    a.imm(10, INBOX);
    a.alu(Add, 13, 0, 10);
    a.ld(9, 13); // r9 = incoming block (maybe Empty)
    a.is_set(4, 9);
    a.jz(4, park); // wait for the partner
    a.clr(13);
    a.env(6, Rank);
    a.alu(Xor, 7, 6, 2);
    a.alu(Lt, 4, 7, 6); // partner below us?
    a.jnz(4, fold_low);
    // higher partner only extends the block partial from the right
    lds(&mut a, 8, S_PARTIAL);
    a.combine(8, 8, 9);
    sts(&mut a, S_PARTIAL, 8);
    a.jmp(fold_done);
    a.bind(fold_low);
    // lower partner extends prefix accumulators + partial from the left
    lds(&mut a, 8, S_INC);
    a.combine(8, 9, 8);
    sts(&mut a, S_INC, 8);
    lds(&mut a, 8, S_EXC);
    a.is_set(4, 8);
    a.jnz(4, exc_has);
    sts(&mut a, S_EXC, 9);
    a.jmp(exc_done);
    a.bind(exc_has);
    a.combine(8, 9, 8);
    sts(&mut a, S_EXC, 8);
    a.bind(exc_done);
    lds(&mut a, 8, S_PARTIAL);
    a.combine(8, 9, 8);
    sts(&mut a, S_PARTIAL, 8);
    a.bind(fold_done);
    a.imm(1, 1);
    a.alu(Add, 10, 0, 1);
    sts(&mut a, S_STEP, 10);
    a.jmp(advance);

    // -- finish: deliver inclusive or exclusive accumulator, once.
    a.bind(finish);
    lds(&mut a, 4, S_DONE);
    a.is_set(4, 4);
    a.jnz(4, already);
    a.env(4, Inclusive);
    a.jz(4, not_incl);
    lds(&mut a, 8, S_INC);
    a.deliver(8);
    a.jmp(mark);
    a.bind(not_incl);
    lds(&mut a, 8, S_EXC);
    a.is_set(4, 8);
    a.jz(4, exc_ident);
    a.deliver(8);
    a.jmp(mark);
    a.bind(exc_ident);
    // rank 0 exclusive: nothing below us, deliver the op identity
    lds(&mut a, 8, S_INC);
    a.ident_like(8, 8);
    a.deliver(8);
    a.bind(mark);
    a.imm(0, 1);
    sts(&mut a, S_DONE, 0);
    a.bind(already);
    a.halt();

    a.bind(park);
    a.park();

    a.finish("handler:scan", on_request, on_packet)
}

/// The recursive-doubling butterfly (allreduce; barrier with empty
/// payloads).
fn build_allreduce() -> Program {
    use AluOp::*;
    use EnvVal::*;
    let mut a = Asm::new();
    let on_request = a.label();
    let on_packet = a.label();
    let advance = a.label();
    let after_send = a.label();
    let fold_low = a.label();
    let fold_done = a.label();
    let finish = a.label();
    let already = a.label();
    let park = a.label();

    a.bind(on_packet);
    a.env(0, PktStep);
    a.imm(1, INBOX);
    a.alu(Add, 13, 0, 1);
    a.ldpkt(8);
    a.st(13, 8);
    lds(&mut a, 4, S_CALLED);
    a.is_set(4, 4);
    a.jz(4, park);
    a.jmp(advance);

    a.bind(on_request);
    a.ldpkt(8);
    sts(&mut a, S_PARTIAL, 8); // running value
    a.imm(0, 1);
    sts(&mut a, S_CALLED, 0);
    a.imm(0, 0);
    sts(&mut a, S_STEP, 0);
    sts(&mut a, S_SENT, 0);
    // falls through

    a.bind(advance);
    lds(&mut a, 0, S_STEP);
    a.imm(1, 1);
    a.alu(Shl, 2, 1, 0);
    a.env(3, P);
    a.alu(Lt, 4, 2, 3);
    a.jz(4, finish);
    lds(&mut a, 5, S_SENT);
    a.alu(Lt, 4, 0, 5);
    a.jnz(4, after_send);
    a.env(6, Rank);
    a.alu(Xor, 7, 6, 2);
    lds(&mut a, 8, S_PARTIAL);
    a.emit(7, MsgType::Data, 0, 8);
    a.alu(Add, 10, 0, 1);
    sts(&mut a, S_SENT, 10);
    a.bind(after_send);
    a.imm(10, INBOX);
    a.alu(Add, 13, 0, 10);
    a.ld(9, 13);
    a.is_set(4, 9);
    a.jz(4, park);
    a.clr(13);
    a.env(6, Rank);
    a.alu(Xor, 7, 6, 2);
    a.alu(Lt, 4, 7, 6);
    lds(&mut a, 8, S_PARTIAL);
    a.jnz(4, fold_low);
    a.combine(8, 8, 9); // rank-ordered fold: we sit below the partner
    a.jmp(fold_done);
    a.bind(fold_low);
    a.combine(8, 9, 8);
    a.bind(fold_done);
    sts(&mut a, S_PARTIAL, 8);
    a.imm(1, 1);
    a.alu(Add, 10, 0, 1);
    sts(&mut a, S_STEP, 10);
    a.jmp(advance);

    a.bind(finish);
    lds(&mut a, 4, S_DONE);
    a.is_set(4, 4);
    a.jnz(4, already);
    lds(&mut a, 8, S_PARTIAL);
    a.deliver(8);
    a.imm(0, 1);
    sts(&mut a, S_DONE, 0);
    a.bind(already);
    a.halt();

    a.bind(park);
    a.park();

    a.finish("handler:allreduce", on_request, on_packet)
}

/// Binomial broadcast rooted at local rank 0: ready-tokens gather up the
/// tree (bounding epoch skew), then the root's payload flows down it.
fn build_bcast() -> Program {
    use AluOp::*;
    use EnvVal::*;
    let mut a = Asm::new();
    let on_request = a.label();
    let on_packet = a.label();
    let try_up = a.label();
    let cnt_ok = a.label();
    let t_ready = a.label();
    let t_loop = a.label();
    let t_store = a.label();
    let seen_ok = a.label();
    let root_down = a.label();
    let handle_down = a.label();
    let down_deliver = a.label();
    let down_loop = a.label();
    let after_down = a.label();
    let nothing = a.label();
    let fin = a.label();
    let park = a.label();

    // -- request: remember own payload (the root's is the broadcast).
    a.bind(on_request);
    a.ldpkt(8);
    sts(&mut a, S_OWN, 8);
    a.imm(0, 1);
    sts(&mut a, S_CALLED, 0);
    a.jmp(try_up);

    // -- packet: an up ready-token (Data) or the root payload (Down).
    a.bind(on_packet);
    a.env(0, PktKind);
    a.imm(1, MsgType::Down.wire_code() as i64);
    a.alu(Eq, 2, 0, 1);
    a.jnz(2, handle_down);
    // up token: count it (tokens may precede the local call)
    lds(&mut a, 4, S_UPSEEN);
    a.is_set(5, 4);
    a.jnz(5, cnt_ok);
    a.imm(4, 0);
    a.bind(cnt_ok);
    a.imm(5, 1);
    a.alu(Add, 4, 4, 5);
    sts(&mut a, S_UPSEEN, 4);
    a.jmp(try_up);

    // -- try_up: once called and all children's tokens are in, send our
    //    token to the parent (root instead turns the tree around).
    a.bind(try_up);
    lds(&mut a, 4, S_CALLED);
    a.is_set(4, 4);
    a.jz(4, park);
    // ensure t = number of children = trailing zeros of rank
    // (log2(p) for the root)
    lds(&mut a, 4, S_T);
    a.is_set(5, 4);
    a.jnz(5, t_ready);
    a.imm(0, 0); // t
    a.env(6, Rank);
    a.env(3, P);
    a.imm(1, 1);
    a.bind(t_loop);
    a.alu(Shl, 2, 1, 0);
    a.alu(Lt, 4, 2, 3);
    a.jz(4, t_store); // 2^t >= p: the root owns the whole tree
    a.alu(Shr, 5, 6, 0);
    a.alu(And, 5, 5, 1);
    a.jnz(5, t_store); // lowest set bit found
    a.alu(Add, 0, 0, 1);
    a.jmp(t_loop);
    a.bind(t_store);
    sts(&mut a, S_T, 0);
    a.bind(t_ready);
    lds(&mut a, 0, S_T); // r0 = t
    lds(&mut a, 4, S_UPSEEN);
    a.is_set(5, 4);
    a.jnz(5, seen_ok);
    a.imm(4, 0);
    a.bind(seen_ok);
    a.alu(Eq, 5, 4, 0); // all children ready?
    a.jz(5, park);
    lds(&mut a, 4, S_UPSENT);
    a.is_set(5, 4);
    a.jnz(5, nothing); // already acted
    a.imm(4, 1);
    sts(&mut a, S_UPSENT, 4);
    a.env(6, Rank);
    a.jz(6, root_down);
    // non-root: empty token to parent = rank - 2^t, tagged step = t
    a.imm(1, 1);
    a.alu(Shl, 2, 1, 0);
    a.alu(Sub, 7, 6, 2);
    lds(&mut a, 8, S_OWN);
    a.empty_like(8, 8);
    a.emit(7, MsgType::Data, 0, 8);
    a.halt();
    a.bind(root_down);
    lds(&mut a, 8, S_OWN);
    sts(&mut a, S_TOTAL, 8);
    a.jmp(down_deliver);

    // -- down: store the root payload, forward it down, deliver.
    a.bind(handle_down);
    a.ldpkt(8);
    sts(&mut a, S_TOTAL, 8);
    // falls through: a down implies we sent our token, so t is set

    a.bind(down_deliver);
    lds(&mut a, 0, S_T);
    lds(&mut a, 9, S_TOTAL);
    a.env(6, Rank);
    a.imm(1, 1);
    a.alu(Sub, 0, 0, 1); // k = t-1 .. 0
    a.bind(down_loop);
    a.imm(2, 0);
    a.alu(Lt, 4, 0, 2);
    a.jnz(4, after_down);
    a.alu(Shl, 3, 1, 0);
    a.alu(Add, 7, 6, 3); // child = rank + 2^k
    a.imm(5, 0);
    a.emit(7, MsgType::Down, 5, 9);
    a.alu(Sub, 0, 0, 1);
    a.jmp(down_loop);
    a.bind(after_down);
    lds(&mut a, 4, S_DONE);
    a.is_set(4, 4);
    a.jnz(4, fin);
    a.deliver(9);
    a.imm(4, 1);
    sts(&mut a, S_DONE, 4);
    a.bind(fin);
    a.halt();

    a.bind(nothing);
    a.halt();

    a.bind(park);
    a.park();

    a.finish("handler:bcast", on_request, on_packet)
}

/// Build + statically verify an image exactly once.  Verification at
/// construction is the load-time gate: a program the verifier rejects
/// panics here, before any flow is created, instead of tripping a VM
/// assert mid-simulation.
fn build_verified(build: fn() -> Program) -> Program {
    let prog = build();
    super::verify::verify_or_panic(&prog);
    prog
}

fn scan_program() -> &'static Program {
    static P: OnceLock<Program> = OnceLock::new();
    P.get_or_init(|| build_verified(build_scan))
}

fn allreduce_program() -> &'static Program {
    static P: OnceLock<Program> = OnceLock::new();
    P.get_or_init(|| build_verified(build_allreduce))
}

fn bcast_program() -> &'static Program {
    static P: OnceLock<Program> = OnceLock::new();
    P.get_or_init(|| build_verified(build_bcast))
}

/// The program image a card loads for `coll` (shared, built once).
pub fn program_for(coll: CollType) -> &'static Program {
    match coll {
        CollType::Scan | CollType::Exscan => scan_program(),
        CollType::Allreduce | CollType::Barrier => allreduce_program(),
        CollType::Bcast => bcast_program(),
        CollType::Reduce => panic!("MPI_Reduce has no handler program"),
    }
}

/// One handler-VM flow wrapped as a [`CollEngine`], so the NIC's engine
/// table (creation on demand, retirement via `done`, the live-engine
/// cap) treats programmable and fixed-function collectives uniformly.
pub struct HandlerEngine {
    prog: &'static Program,
    flow: Flow,
    algo: AlgoType,
}

/// Instantiate the handler engine for one collective invocation.
pub fn handler_engine(coll: CollType) -> Box<dyn CollEngine> {
    let algo = match coll {
        CollType::Bcast => AlgoType::BinomialTree,
        _ => AlgoType::RecursiveDoubling,
    };
    Box::new(HandlerEngine { prog: program_for(coll), flow: Flow::new(), algo })
}

impl CollEngine for HandlerEngine {
    fn on_host_request(&mut self, ctx: &mut EngineCtx, req: &OffloadRequest) -> Vec<NicAction> {
        vm::run(self.prog, &mut self.flow, ctx, Activation::Request(req))
    }

    fn on_packet(&mut self, ctx: &mut EngineCtx, pkt: &CollPacket) -> Vec<NicAction> {
        vm::run(self.prog, &mut self.flow, ctx, Activation::Packet(pkt))
    }

    fn done(&self) -> bool {
        self.flow.delivered
    }

    fn algo(&self) -> AlgoType {
        self.algo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Payload;
    use crate::fpga::engine::testutil::Harness;

    fn handler_harness(p: usize, coll: CollType) -> Harness {
        Harness::with_engines(p, coll, |_| handler_engine(coll))
    }

    fn contributions(p: usize) -> Vec<Vec<i32>> {
        (0..p).map(|r| vec![r as i32 + 1, -(r as i32), 100 + r as i32]).collect()
    }

    fn orders(p: usize) -> Vec<Vec<usize>> {
        vec![
            (0..p).collect(),
            (0..p).rev().collect(),
            (0..p).step_by(2).chain((1..p).step_by(2)).collect(),
        ]
    }

    #[test]
    fn all_five_collectives_all_orders() {
        for coll in CollType::HANDLER_SET {
            for p in [2usize, 4, 8, 16] {
                for order in orders(p) {
                    let mut h = handler_harness(p, coll);
                    let contribs = if coll == CollType::Barrier {
                        vec![vec![]; p]
                    } else {
                        contributions(p)
                    };
                    h.run_and_check(&contribs, &order);
                }
            }
        }
    }

    #[test]
    fn handler_matches_fixed_function_bit_for_bit() {
        // same contributions through the VM and through the fpga state
        // machines: the shared ALU + identical fold order must produce
        // identical bytes, not just tolerably-close values
        use crate::packet::AlgoType;
        for coll in [CollType::Scan, CollType::Exscan, CollType::Allreduce] {
            for order in orders(8) {
                let c = contributions(8);
                let mut vmh = handler_harness(8, coll);
                let mut ffh = Harness::new(AlgoType::RecursiveDoubling, 8, coll, false);
                for &r in &order {
                    vmh.call(r, Payload::from_i32(&c[r]));
                    vmh.drain();
                    ffh.call(r, Payload::from_i32(&c[r]));
                    ffh.drain();
                }
                for r in 0..8 {
                    let a = vmh.results[r].as_ref().unwrap();
                    let b = ffh.results[r].as_ref().unwrap();
                    assert_eq!(a.bytes(), b.bytes(), "{coll:?} rank {r} ({order:?})");
                }
            }
        }
    }

    #[test]
    fn bcast_delivers_only_after_the_subtree_called() {
        // rank 0 calls first: nothing may complete (the ready-token
        // phase gates the root) until every rank has entered
        let mut h = handler_harness(4, CollType::Bcast);
        let c = contributions(4);
        h.call(0, Payload::from_i32(&c[0]));
        h.drain();
        assert!(h.results.iter().all(|r| r.is_none()), "no delivery before the last call");
        for r in [2, 1, 3] {
            h.call(r, Payload::from_i32(&c[r]));
            h.drain();
        }
        for r in 0..4 {
            assert_eq!(h.results[r].as_ref().unwrap().to_i32(), c[0], "rank {r}");
        }
    }

    #[test]
    fn programs_assemble_once_and_are_shared() {
        let a = program_for(CollType::Scan) as *const Program;
        let b = program_for(CollType::Exscan) as *const Program;
        assert_eq!(a, b, "scan and exscan share one image");
        assert!(program_for(CollType::Barrier).code.len() < 100, "programs stay tiny");
    }
}
