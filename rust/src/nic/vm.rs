//! A tiny deterministic register VM for sPIN-style per-packet handler
//! programs (Hoefler et al., "sPIN: High-performance streaming
//! Processing in the Network"; Schneider et al., "FPsPIN").
//!
//! One *program* implements one collective; one *flow* is one collective
//! invocation on one card (the per-epoch scratchpad).  Every inbound
//! event — the host's offload request or a reassembled peer packet —
//! runs the program to completion ([`run`]), sPIN's
//! handler-per-message model.  Handlers are pure state machines over
//! the flow scratchpad: no heap, no host memory, no blocking.
//!
//! Machine model:
//!
//! - 16 general registers (`r0..r15`) holding a tagged [`Val`]: a
//!   64-bit integer, a typed payload vector, or empty;
//! - a per-flow scratchpad of [`SCRATCH_SLOTS`] value slots
//!   (load/store by computed index — the inbox for out-of-order
//!   packets lives here);
//! - scalar ALU ops ([`AluOp`]) for control flow, plus [`Instr::Combine`],
//!   which calls straight into the same [`EngineCtx::combine`] the
//!   fixed-function `fpga::` machines use — the VM's vector ALU *is*
//!   the existing dtype x op datapath, so results are bit-identical
//!   across both offload paths;
//! - intrinsics: `Emit` (frame towards a peer card), `Deliver` (Result
//!   packet up to the host), `Drop` (park this activation waiting for
//!   input — counted as a handler stall), `Halt`.
//!
//! Costing: every retired instruction charges
//! `cost.handler_instr_cycles`; payload movement (scratchpad stores,
//! frame emission, delivery) charges `cost.handler_copy_cycles_per_8b`
//! per 8 bytes; combines charge `cost.nic_combine_cycles` exactly like
//! the fixed-function path.  Cycles accumulate in [`EngineCtx::cycles`]
//! and the NIC converts them to virtual time as usual.

use std::fmt;

use crate::data::Payload;
use crate::fpga::engine::{EngineCtx, NicAction};
use crate::packet::{CollPacket, CollType, MsgType};
use crate::sim::OffloadRequest;

/// General-purpose registers per activation.
pub const NREGS: usize = 16;

/// Per-flow scratchpad slots (the card's per-collective SRAM budget).
pub const SCRATCH_SLOTS: usize = 64;

/// Per-activation instruction budget.  Handlers must run to completion
/// in bounded time (the sPIN contract); exceeding this is a program
/// bug, not a load condition, and fails loudly.
pub const MAX_STEPS: usize = 4096;

/// Register index (must be < [`NREGS`]).
pub type Reg = u8;

/// A register / scratchpad value.
#[derive(Clone, Debug, Default)]
pub enum Val {
    #[default]
    Empty,
    Int(i64),
    Vec(Payload),
}

/// Scalar ALU operations (64-bit signed).
#[derive(Clone, Copy, Debug)]
pub enum AluOp {
    Add,
    Sub,
    Xor,
    And,
    /// `a << b` (b in 0..64).
    Shl,
    /// Arithmetic `a >> b` (b in 0..64).
    Shr,
    /// `(a < b) as i64`, signed.
    Lt,
    /// `(a == b) as i64`.
    Eq,
}

/// Read-only environment values a handler can query.
#[derive(Clone, Copy, Debug)]
pub enum EnvVal {
    /// Communicator-local rank of this card.
    Rank,
    /// Communicator size.
    P,
    /// 1 for inclusive collectives (MPI_Scan), 0 otherwise.
    Inclusive,
    /// Triggering packet's step field (0 for the host request).
    PktStep,
    /// Triggering packet's sender rank (own rank for the host request).
    PktSrc,
    /// Triggering message type, as its wire code (`MsgType::wire_code`;
    /// the host request reads as `HostRequest`, a timer reads as 0).
    PktKind,
    /// Retransmit attempts already made for the timed-out frame
    /// (0 outside a timer activation).
    Retries,
    /// The card's configured retransmit budget (`cost.max_retries`;
    /// 0 outside a timer activation).
    MaxRetries,
}

/// One VM instruction.
#[derive(Clone, Copy, Debug)]
pub enum Instr {
    /// `dst = val`
    Imm { dst: Reg, val: i64 },
    /// `dst = src`
    Mov { dst: Reg, src: Reg },
    /// `dst = env[what]`
    Env { dst: Reg, what: EnvVal },
    /// `dst = ` the triggering event's payload.
    LdPkt { dst: Reg },
    /// `dst = ` zero-element payload with `src`'s dtype.
    EmptyLike { dst: Reg, src: Reg },
    /// `dst = ` op-identity payload shaped like `src`.
    IdentLike { dst: Reg, src: Reg },
    /// `dst = scratch[slot]` (Empty if never stored).
    Ld { dst: Reg, slot: Reg },
    /// `scratch[slot] = src` (charges per-byte for payloads).
    St { slot: Reg, src: Reg },
    /// `scratch[slot] = Empty`
    Clr { slot: Reg },
    /// `dst = a (op) b` over integers.
    Alu { op: AluOp, dst: Reg, a: Reg, b: Reg },
    /// `dst = combine(a, b)` through the shared dtype x op datapath.
    Combine { dst: Reg, a: Reg, b: Reg },
    /// `dst = (src != Empty) as i64`
    IsSet { dst: Reg, src: Reg },
    Jmp { to: usize },
    /// Jump when `cond` is integer zero.
    Jz { cond: Reg, to: usize },
    /// Jump when `cond` is integer non-zero.
    Jnz { cond: Reg, to: usize },
    /// Emit a collective frame towards local rank `dst` (the NIC frames,
    /// fragments and routes it).
    Emit { dst: Reg, mt: MsgType, step: Reg, payload: Reg },
    /// Deliver the final outcome to the local host (Result packet).
    Deliver { payload: Reg },
    /// Park: this event is buffered/absorbed, the flow waits for more
    /// input.  Counted in `handler_stalls`.
    Drop,
    /// Ask the NIC to replay the pending reliable frame this timer
    /// activation fired for (the NIC owns the pending store; the program
    /// only decides the policy).  Meaningless outside `on_timer`.
    Retx,
    /// Normal end of activation.
    Halt,
}

/// An assembled handler program with its three entry points.
#[derive(Debug)]
pub struct Program {
    pub name: &'static str,
    pub code: Vec<Instr>,
    pub on_request: usize,
    pub on_packet: usize,
    /// Entry run when a reliable frame's retransmit timer expires.
    /// [`Asm::finish`] installs the standard policy (retransmit while
    /// under budget) unless the program supplies its own via
    /// [`Asm::finish_with_timer`].
    pub on_timer: usize,
}

/// Per-flow persistent state: the scratchpad plus the delivered flag the
/// NIC's engine table retires on.
#[derive(Debug)]
pub struct Flow {
    scratch: Vec<Val>,
    pub delivered: bool,
}

impl Flow {
    pub fn new() -> Flow {
        Flow { scratch: vec![Val::Empty; SCRATCH_SLOTS], delivered: false }
    }
}

impl Default for Flow {
    fn default() -> Self {
        Flow::new()
    }
}

/// What triggered this activation.
#[derive(Clone, Copy, Debug)]
pub enum Activation<'a> {
    Request(&'a OffloadRequest),
    Packet(&'a CollPacket),
    /// A reliable frame's retransmit timer expired with the ack still
    /// outstanding.  Carries the retry ledger; there is no packet, so
    /// `LdPkt` is illegal and the `Pkt*` env values read as defaults.
    Timer { retries: u32, max_retries: u32 },
}

/// Panic-site context: which image, which flow (collective, rank,
/// epoch), which pc.  A dynamic trip is the verifier's backstop — when
/// one fires mid-simulation the message must identify the exact flow,
/// not just the program.  Formatted only inside a panic, so the hot
/// path never allocates for it.
#[derive(Clone, Copy)]
struct Site<'a> {
    prog: &'a str,
    coll: CollType,
    rank: usize,
    epoch: u16,
    pc: usize,
}

impl fmt::Display for Site<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{:?} rank {} epoch {}]@{}",
            self.prog, self.coll, self.rank, self.epoch, self.pc
        )
    }
}

fn as_int(v: &Val, site: Site<'_>) -> i64 {
    match v {
        Val::Int(i) => *i,
        other => panic!("{site}: expected integer register, got {other:?}"),
    }
}

fn as_vec<'a>(v: &'a Val, site: Site<'_>) -> &'a Payload {
    match v {
        Val::Vec(p) => p,
        other => panic!("{site}: expected payload register, got {other:?}"),
    }
}

fn into_vec(v: Val, site: Site<'_>) -> Payload {
    match v {
        Val::Vec(p) => p,
        other => panic!("{site}: expected payload register, got {other:?}"),
    }
}

/// Run one activation of `prog` over `flow`, returning the NIC actions
/// it produced.  Instruction/stall counts and datapath cycles are
/// charged into `ctx` (the NIC adds pipeline latency and converts to
/// virtual time exactly as for the fixed-function engines).
pub fn run(
    prog: &Program,
    flow: &mut Flow,
    ctx: &mut EngineCtx,
    act: Activation,
) -> Vec<NicAction> {
    // stack register file: activations are the per-packet hot path
    let mut regs: [Val; NREGS] = std::array::from_fn(|_| Val::Empty);
    let mut out = Vec::new();
    let mut pc = match act {
        Activation::Request(_) => prog.on_request,
        Activation::Packet(_) => prog.on_packet,
        Activation::Timer { .. } => prog.on_timer,
    };
    let mut steps = 0usize;
    // flow identity, copied out so `site` doesn't hold a borrow of the
    // ctx the loop mutates
    let (coll, rank, epoch) = (ctx.coll, ctx.rank, ctx.epoch);
    let site = move |pc: usize| Site { prog: prog.name, coll, rank, epoch, pc };
    loop {
        assert!(pc < prog.code.len(), "{}: pc {pc} out of range", site(pc));
        steps += 1;
        assert!(
            steps <= MAX_STEPS,
            "{}: instruction budget exceeded ({MAX_STEPS}) — runaway handler",
            site(pc)
        );
        ctx.instrs += 1;
        ctx.cycles += ctx.cost.handler_instr_cycles;
        let at = pc;
        let instr = prog.code[pc];
        pc += 1;
        let r = |reg: Reg| -> usize {
            let i = reg as usize;
            assert!(i < NREGS, "{}: register r{reg} out of range", site(at));
            i
        };
        match instr {
            Instr::Imm { dst, val } => regs[r(dst)] = Val::Int(val),
            Instr::Mov { dst, src } => regs[r(dst)] = regs[r(src)].clone(),
            Instr::Env { dst, what } => {
                let v = match what {
                    EnvVal::Rank => ctx.rank as i64,
                    EnvVal::P => ctx.p as i64,
                    EnvVal::Inclusive => ctx.inclusive as i64,
                    EnvVal::PktStep => match act {
                        Activation::Request(_) | Activation::Timer { .. } => 0,
                        Activation::Packet(pkt) => pkt.step as i64,
                    },
                    EnvVal::PktSrc => match act {
                        Activation::Request(req) => req.rank as i64,
                        Activation::Packet(pkt) => pkt.rank as i64,
                        Activation::Timer { .. } => ctx.rank as i64,
                    },
                    EnvVal::PktKind => match act {
                        Activation::Request(_) => MsgType::HostRequest.wire_code() as i64,
                        Activation::Packet(pkt) => pkt.msg_type.wire_code() as i64,
                        Activation::Timer { .. } => 0,
                    },
                    EnvVal::Retries => match act {
                        Activation::Timer { retries, .. } => retries as i64,
                        _ => 0,
                    },
                    EnvVal::MaxRetries => match act {
                        Activation::Timer { max_retries, .. } => max_retries as i64,
                        _ => 0,
                    },
                };
                regs[r(dst)] = Val::Int(v);
            }
            Instr::LdPkt { dst } => {
                let p = match act {
                    Activation::Request(req) => req.payload.clone(),
                    Activation::Packet(pkt) => pkt.payload.clone(),
                    Activation::Timer { .. } => {
                        panic!("{}: LdPkt in a timer activation (no packet)", site(at))
                    }
                };
                regs[r(dst)] = Val::Vec(p);
            }
            Instr::EmptyLike { dst, src } => {
                let like = as_vec(&regs[r(src)], site(at));
                regs[r(dst)] = Val::Vec(like.slice(0, 0));
            }
            Instr::IdentLike { dst, src } => {
                let like = as_vec(&regs[r(src)], site(at)).clone();
                regs[r(dst)] = Val::Vec(ctx.identity(&like));
            }
            Instr::Ld { dst, slot } => {
                let s = as_int(&regs[r(slot)], site(at)) as usize;
                assert!(s < SCRATCH_SLOTS, "{}: scratch slot {s} out of range", site(at));
                regs[r(dst)] = flow.scratch[s].clone();
            }
            Instr::St { slot, src } => {
                let s = as_int(&regs[r(slot)], site(at)) as usize;
                assert!(s < SCRATCH_SLOTS, "{}: scratch slot {s} out of range", site(at));
                let v = regs[r(src)].clone();
                if let Val::Vec(p) = &v {
                    ctx.cycles += ctx.cost.handler_copy_cycles(p.byte_len());
                }
                flow.scratch[s] = v;
            }
            Instr::Clr { slot } => {
                let s = as_int(&regs[r(slot)], site(at)) as usize;
                assert!(s < SCRATCH_SLOTS, "{}: scratch slot {s} out of range", site(at));
                flow.scratch[s] = Val::Empty;
            }
            Instr::Alu { op, dst, a, b } => {
                let x = as_int(&regs[r(a)], site(at));
                let y = as_int(&regs[r(b)], site(at));
                let v = match op {
                    AluOp::Add => x.wrapping_add(y),
                    AluOp::Sub => x.wrapping_sub(y),
                    AluOp::Xor => x ^ y,
                    AluOp::And => x & y,
                    AluOp::Shl => {
                        assert!((0..64).contains(&y), "{}: shift {y}", site(at));
                        x << y
                    }
                    AluOp::Shr => {
                        assert!((0..64).contains(&y), "{}: shift {y}", site(at));
                        x >> y
                    }
                    AluOp::Lt => (x < y) as i64,
                    AluOp::Eq => (x == y) as i64,
                };
                regs[r(dst)] = Val::Int(v);
            }
            Instr::Combine { dst, a, b } => {
                // the accumulator forms `dst == a` / `dst == b` (every
                // program fold) take the value OUT of the destination
                // register and fold in place — zero allocations once the
                // register uniquely owns its payload.  Operand order is
                // preserved bit-for-bit in all cases.
                let res = if a == b {
                    let x = as_vec(&regs[r(a)], site(at)).clone();
                    let mut v = x.clone();
                    ctx.combine_into(&mut v, &x);
                    v
                } else if dst == a {
                    let mut v = into_vec(std::mem::take(&mut regs[r(a)]), site(at));
                    let y = as_vec(&regs[r(b)], site(at));
                    ctx.combine_into(&mut v, y); // v = a (op) b
                    v
                } else if dst == b {
                    let mut v = into_vec(std::mem::take(&mut regs[r(b)]), site(at));
                    let x = as_vec(&regs[r(a)], site(at));
                    ctx.combine_into_rev(&mut v, x); // v = a (op) b
                    v
                } else {
                    let mut v = as_vec(&regs[r(a)], site(at)).clone();
                    let y = as_vec(&regs[r(b)], site(at));
                    ctx.combine_into(&mut v, y);
                    v
                };
                regs[r(dst)] = Val::Vec(res);
            }
            Instr::IsSet { dst, src } => {
                let set = !matches!(regs[r(src)], Val::Empty);
                regs[r(dst)] = Val::Int(set as i64);
            }
            Instr::Jmp { to } => pc = to,
            Instr::Jz { cond, to } => {
                if as_int(&regs[r(cond)], site(at)) == 0 {
                    pc = to;
                }
            }
            Instr::Jnz { cond, to } => {
                if as_int(&regs[r(cond)], site(at)) != 0 {
                    pc = to;
                }
            }
            Instr::Emit { dst, mt, step, payload } => {
                let d = as_int(&regs[r(dst)], site(at));
                assert!(d >= 0 && (d as usize) < ctx.p, "{}: emit dst {d}", site(at));
                let s = as_int(&regs[r(step)], site(at));
                assert!(
                    (0..=u16::MAX as i64).contains(&s),
                    "{}: emit step {s} out of wire range",
                    site(at)
                );
                let p = as_vec(&regs[r(payload)], site(at)).clone();
                ctx.cycles += ctx.cost.handler_copy_cycles(p.byte_len());
                out.push(NicAction::Send {
                    dst: d as usize,
                    mt,
                    step: s as u16,
                    tag: 0,
                    payload: p,
                });
            }
            Instr::Deliver { payload } => {
                let p = as_vec(&regs[r(payload)], site(at)).clone();
                ctx.cycles += ctx.cost.handler_copy_cycles(p.byte_len());
                flow.delivered = true;
                out.push(NicAction::Deliver { payload: p });
            }
            Instr::Drop => {
                ctx.stalls += 1;
                break;
            }
            Instr::Retx => out.push(NicAction::Retransmit),
            Instr::Halt => break,
        }
    }
    out
}

// --------------------------------------------------------------- asm

/// A forward-referenceable jump target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Label(usize);

/// Tiny two-pass assembler: emit instructions with symbolic labels,
/// then [`Asm::finish`] resolves every jump to an absolute index.
pub struct Asm {
    code: Vec<Instr>,
    labels: Vec<Option<usize>>,
}

impl Default for Asm {
    fn default() -> Self {
        Asm::new()
    }
}

impl Asm {
    pub fn new() -> Asm {
        Asm { code: Vec::new(), labels: Vec::new() }
    }

    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    pub fn bind(&mut self, l: Label) {
        assert!(self.labels[l.0].is_none(), "label {} bound twice", l.0);
        self.labels[l.0] = Some(self.code.len());
    }

    pub fn imm(&mut self, dst: Reg, val: i64) {
        self.code.push(Instr::Imm { dst, val });
    }

    pub fn mov(&mut self, dst: Reg, src: Reg) {
        self.code.push(Instr::Mov { dst, src });
    }

    pub fn env(&mut self, dst: Reg, what: EnvVal) {
        self.code.push(Instr::Env { dst, what });
    }

    pub fn ldpkt(&mut self, dst: Reg) {
        self.code.push(Instr::LdPkt { dst });
    }

    pub fn empty_like(&mut self, dst: Reg, src: Reg) {
        self.code.push(Instr::EmptyLike { dst, src });
    }

    pub fn ident_like(&mut self, dst: Reg, src: Reg) {
        self.code.push(Instr::IdentLike { dst, src });
    }

    pub fn ld(&mut self, dst: Reg, slot: Reg) {
        self.code.push(Instr::Ld { dst, slot });
    }

    pub fn st(&mut self, slot: Reg, src: Reg) {
        self.code.push(Instr::St { slot, src });
    }

    pub fn clr(&mut self, slot: Reg) {
        self.code.push(Instr::Clr { slot });
    }

    pub fn alu(&mut self, op: AluOp, dst: Reg, a: Reg, b: Reg) {
        self.code.push(Instr::Alu { op, dst, a, b });
    }

    pub fn combine(&mut self, dst: Reg, a: Reg, b: Reg) {
        self.code.push(Instr::Combine { dst, a, b });
    }

    pub fn is_set(&mut self, dst: Reg, src: Reg) {
        self.code.push(Instr::IsSet { dst, src });
    }

    pub fn jmp(&mut self, to: Label) {
        self.code.push(Instr::Jmp { to: to.0 });
    }

    pub fn jz(&mut self, cond: Reg, to: Label) {
        self.code.push(Instr::Jz { cond, to: to.0 });
    }

    pub fn jnz(&mut self, cond: Reg, to: Label) {
        self.code.push(Instr::Jnz { cond, to: to.0 });
    }

    pub fn emit(&mut self, dst: Reg, mt: MsgType, step: Reg, payload: Reg) {
        self.code.push(Instr::Emit { dst, mt, step, payload });
    }

    pub fn deliver(&mut self, payload: Reg) {
        self.code.push(Instr::Deliver { payload });
    }

    pub fn park(&mut self) {
        self.code.push(Instr::Drop);
    }

    pub fn retx(&mut self) {
        self.code.push(Instr::Retx);
    }

    pub fn halt(&mut self) {
        self.code.push(Instr::Halt);
    }

    /// Resolve labels and seal the program, appending the standard
    /// retransmit-timer policy as the `on_timer` entry: replay the
    /// pending frame while `retries < max_retries`, otherwise give up
    /// (halt without `Retx`, surfaced by the NIC as a recovery failure).
    pub fn finish(mut self, name: &'static str, on_request: Label, on_packet: Label) -> Program {
        let on_timer = self.label();
        let give_up = self.label();
        self.bind(on_timer);
        self.env(0, EnvVal::Retries);
        self.env(1, EnvVal::MaxRetries);
        self.alu(AluOp::Lt, 2, 0, 1);
        self.jz(2, give_up);
        self.retx();
        self.bind(give_up);
        self.halt();
        self.finish_with_timer(name, on_request, on_packet, on_timer)
    }

    /// Resolve labels and seal a program that supplies its own
    /// retransmit-timer entry.
    pub fn finish_with_timer(
        self,
        name: &'static str,
        on_request: Label,
        on_packet: Label,
        on_timer: Label,
    ) -> Program {
        let resolve = |id: usize| {
            self.labels[id].unwrap_or_else(|| panic!("{name}: label {id} never bound"))
        };
        let code: Vec<Instr> = self
            .code
            .iter()
            .map(|i| match *i {
                Instr::Jmp { to } => Instr::Jmp { to: resolve(to) },
                Instr::Jz { cond, to } => Instr::Jz { cond, to: resolve(to) },
                Instr::Jnz { cond, to } => Instr::Jnz { cond, to: resolve(to) },
                other => other,
            })
            .collect();
        let prog = Program {
            name,
            code,
            on_request: resolve(on_request.0),
            on_packet: resolve(on_packet.0),
            on_timer: resolve(on_timer.0),
        };
        assert!(prog.on_request < prog.code.len(), "{name}: empty on_request");
        assert!(prog.on_packet < prog.code.len(), "{name}: empty on_packet");
        assert!(prog.on_timer < prog.code.len(), "{name}: empty on_timer");
        prog
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CostModel;
    use crate::data::{Dtype, Op};
    use crate::packet::CollType;
    use crate::runtime::NativeEngine;

    fn req(vals: &[i32]) -> OffloadRequest {
        OffloadRequest {
            rank: 1,
            comm: 0,
            epoch: 0,
            comm_size: 4,
            coll: CollType::Scan,
            algo: crate::packet::AlgoType::RecursiveDoubling,
            op: Op::Sum,
            dtype: Dtype::I32,
            payload: Payload::from_i32(vals),
        }
    }

    fn ctx_parts() -> (NativeEngine, CostModel) {
        (NativeEngine::new(), CostModel::default())
    }

    fn make_ctx<'a>(compute: &'a NativeEngine, cost: &'a CostModel) -> EngineCtx<'a> {
        EngineCtx {
            rank: 1,
            p: 4,
            inclusive: true,
            op: Op::Sum,
            coll: CollType::Scan,
            epoch: 0,
            compute,
            cost,
            cycles: 0,
            combine_cycles: 0,
            instrs: 0,
            stalls: 0,
        }
    }

    #[test]
    #[should_panic(expected = "[Scan rank 1 epoch 0]")]
    fn dynamic_trips_name_the_flow() {
        // reading an integer out of a never-written register must say
        // which flow (collective, rank, epoch) hit it, not just which
        // program — the whole point of the flow-attributed Site
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.alu(AluOp::Add, 0, 1, 2);
        a.halt();
        let prog = a.finish("t-site", entry, entry);
        let (compute, cost) = ctx_parts();
        let mut ctx = make_ctx(&compute, &cost);
        let mut flow = Flow::new();
        let r = req(&[1]);
        run(&prog, &mut flow, &mut ctx, Activation::Request(&r));
    }

    #[test]
    fn alu_scratch_and_emit() {
        // On request: r0 = (rank ^ 2), store payload at slot r0, load it
        // back, combine with itself, emit to partner, halt.
        let mut a = Asm::new();
        let on_request = a.label();
        let on_packet = a.label();
        a.bind(on_request);
        a.env(0, EnvVal::Rank);
        a.imm(1, 2);
        a.alu(AluOp::Xor, 2, 0, 1); // partner = rank ^ 2 = 3
        a.ldpkt(3);
        a.st(1, 3); // scratch[2] = payload
        a.ld(4, 1);
        a.combine(5, 3, 4); // doubled
        a.imm(6, 7); // step
        a.emit(2, MsgType::Data, 6, 5);
        a.halt();
        a.bind(on_packet);
        a.park();
        let prog = a.finish("t", on_request, on_packet);

        let (compute, cost) = ctx_parts();
        let mut ctx = make_ctx(&compute, &cost);
        let mut flow = Flow::new();
        let r = req(&[1, -2, 3]);
        let actions = run(&prog, &mut flow, &mut ctx, Activation::Request(&r));
        assert_eq!(actions.len(), 1);
        match &actions[0] {
            NicAction::Send { dst, mt, step, payload, .. } => {
                assert_eq!(*dst, 3);
                assert_eq!(*mt, MsgType::Data);
                assert_eq!(*step, 7);
                assert_eq!(payload.to_i32(), vec![2, -4, 6]);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(ctx.instrs, 10, "every retired instruction is counted");
        assert!(ctx.cycles >= 10, "per-instruction cycles charged");
        assert_eq!(ctx.stalls, 0);
        assert!(!flow.delivered);
    }

    #[test]
    fn drop_counts_a_stall_and_deliver_marks_the_flow() {
        let mut a = Asm::new();
        let on_request = a.label();
        let on_packet = a.label();
        a.bind(on_request);
        a.ldpkt(0);
        a.deliver(0);
        a.halt();
        a.bind(on_packet);
        a.park();
        let prog = a.finish("t2", on_request, on_packet);

        let (compute, cost) = ctx_parts();
        let mut ctx = make_ctx(&compute, &cost);
        let mut flow = Flow::new();
        let r = req(&[5]);
        let pkt = CollPacket {
            comm_id: 0,
            comm_size: 4,
            coll_type: CollType::Scan,
            algo_type: crate::packet::AlgoType::RecursiveDoubling,
            node_type: crate::packet::NodeType::Generic,
            msg_type: MsgType::Data,
            step: 0,
            rank: 0,
            root: 0,
            operation: Op::Sum,
            data_type: Dtype::I32,
            count: 1,
            frag_idx: 0,
            frag_total: 1,
            tag: 0,
            payload: Payload::from_i32(&[9]),
        };
        let none = run(&prog, &mut flow, &mut ctx, Activation::Packet(&pkt));
        assert!(none.is_empty());
        assert_eq!(ctx.stalls, 1);
        assert!(!flow.delivered);

        let actions = run(&prog, &mut flow, &mut ctx, Activation::Request(&r));
        assert_eq!(actions.len(), 1);
        assert!(matches!(&actions[0], NicAction::Deliver { payload } if payload.to_i32() == vec![5]));
        assert!(flow.delivered);
    }

    #[test]
    fn standard_timer_entry_retransmits_until_budget_exhausted() {
        // any program sealed with `finish` gets the standard policy:
        // Retx while retries < max_retries, bare Halt afterwards
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.halt();
        let prog = a.finish("t-timer", entry, entry);
        let (compute, cost) = ctx_parts();
        let mut ctx = make_ctx(&compute, &cost);
        let mut flow = Flow::new();
        let acts =
            run(&prog, &mut flow, &mut ctx, Activation::Timer { retries: 1, max_retries: 3 });
        assert!(matches!(acts[..], [NicAction::Retransmit]), "{acts:?}");
        let acts =
            run(&prog, &mut flow, &mut ctx, Activation::Timer { retries: 3, max_retries: 3 });
        assert!(acts.is_empty(), "exhausted budget gives up: {acts:?}");
    }

    #[test]
    #[should_panic(expected = "LdPkt in a timer activation")]
    fn ldpkt_is_illegal_in_timer_activations() {
        let mut a = Asm::new();
        let entry = a.label();
        a.bind(entry);
        a.halt();
        let timer = a.label();
        a.bind(timer);
        a.ldpkt(0);
        a.halt();
        let prog = a.finish_with_timer("t-nopkt", entry, entry, timer);
        let (compute, cost) = ctx_parts();
        let mut ctx = make_ctx(&compute, &cost);
        let mut flow = Flow::new();
        run(&prog, &mut flow, &mut ctx, Activation::Timer { retries: 0, max_retries: 3 });
    }

    #[test]
    #[should_panic(expected = "instruction budget")]
    fn runaway_program_trips_the_budget() {
        let mut a = Asm::new();
        let on_request = a.label();
        a.bind(on_request);
        let spin = a.label();
        a.bind(spin);
        a.jmp(spin);
        let prog = a.finish("spin", on_request, on_request);
        let (compute, cost) = ctx_parts();
        let mut ctx = make_ctx(&compute, &cost);
        let mut flow = Flow::new();
        let r = req(&[1]);
        run(&prog, &mut flow, &mut ctx, Activation::Request(&r));
    }

    #[test]
    #[should_panic(expected = "never bound")]
    fn unbound_label_fails_at_assembly() {
        let mut a = Asm::new();
        let on_request = a.label();
        a.bind(on_request);
        let nowhere = a.label();
        a.jmp(nowhere);
        a.finish("bad", on_request, on_request);
    }
}
