//! nfscan CLI — the leader entrypoint.
//!
//! See `nfscan help` (or `cli::print_help`) for commands.  All the logic
//! lives in the library; this binary only parses argv and reports errors.

// Counting allocator: lets `nfscan bench` report allocs/op for the hot
// datapath (two relaxed atomic increments per malloc — noise elsewhere).
#[global_allocator]
static ALLOC: nfscan::util::alloc::CountingAllocator = nfscan::util::alloc::CountingAllocator;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = nfscan::cli::main_with_args(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
