//! Parse `artifacts/manifest.txt` — the key=value index `aot.py` writes.
//!
//! One line per artifact:
//! `name=combine_sum_i32 kind=combine op=sum dtype=i32 block=2048 args=2
//!  file=combine_sum_i32.hlo.txt`

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::{Dtype, Op};

/// What graph an artifact implements.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ArtifactKind {
    Combine,
    ScanInc,
    ScanExc,
    Derive,
}

impl ArtifactKind {
    fn from_name(s: &str) -> Option<Self> {
        match s {
            "combine" => Some(ArtifactKind::Combine),
            "scan_inc" => Some(ArtifactKind::ScanInc),
            "scan_exc" => Some(ArtifactKind::ScanExc),
            "derive" => Some(ArtifactKind::Derive),
            _ => None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub kind: ArtifactKind,
    pub op: Op,
    pub dtype: Dtype,
    pub block: usize,
    pub args: usize,
    pub path: PathBuf,
}

#[derive(Debug, Default)]
pub struct Manifest {
    entries: HashMap<(ArtifactKind, Op, Dtype), ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut entries = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut fields: HashMap<&str, &str> = HashMap::new();
            for kv in line.split_whitespace() {
                let (k, v) = kv
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: bad field {kv}", lineno + 1))?;
                fields.insert(k, v);
            }
            let get = |k: &str| -> Result<&str> {
                fields.get(k).copied().with_context(|| {
                    format!("manifest line {}: missing field {k}", lineno + 1)
                })
            };
            let kind = ArtifactKind::from_name(get("kind")?)
                .with_context(|| format!("line {}: bad kind", lineno + 1))?;
            let op = Op::from_name(get("op")?)
                .with_context(|| format!("line {}: bad op", lineno + 1))?;
            let dtype = Dtype::from_name(get("dtype")?)
                .with_context(|| format!("line {}: bad dtype", lineno + 1))?;
            let entry = ManifestEntry {
                name: get("name")?.to_string(),
                kind,
                op,
                dtype,
                block: get("block")?.parse().context("block")?,
                args: get("args")?.parse().context("args")?,
                path: dir.join(get("file")?),
            };
            if entry.block != super::AOT_BLOCK {
                bail!(
                    "artifact {} compiled for block {} but runtime expects {}",
                    entry.name,
                    entry.block,
                    super::AOT_BLOCK
                );
            }
            if entries.insert((kind, op, dtype), entry).is_some() {
                bail!("duplicate artifact for {kind:?}/{}/{}", op.name(), dtype.name());
            }
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(Manifest { entries })
    }

    pub fn get(&self, kind: ArtifactKind, op: Op, dtype: Dtype) -> Option<&ManifestEntry> {
        self.entries.get(&(kind, op, dtype))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ManifestEntry> {
        self.entries.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# nf-scan AOT manifest: block=2048
name=combine_sum_i32 kind=combine op=sum dtype=i32 block=2048 args=2 file=combine_sum_i32.hlo.txt
name=scan_inc_sum_f32 kind=scan_inc op=sum dtype=f32 block=2048 args=1 file=scan_inc_sum_f32.hlo.txt
";

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.len(), 2);
        let e = m.get(ArtifactKind::Combine, Op::Sum, Dtype::I32).unwrap();
        assert_eq!(e.args, 2);
        assert_eq!(e.path, Path::new("/a/combine_sum_i32.hlo.txt"));
        assert!(m.get(ArtifactKind::Derive, Op::Sum, Dtype::I32).is_none());
    }

    #[test]
    fn wrong_block_rejected() {
        let bad = SAMPLE.replace("block=2048", "block=1024");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }

    #[test]
    fn duplicate_rejected() {
        let dup = format!("{SAMPLE}{}", SAMPLE.lines().nth(1).unwrap());
        assert!(Manifest::parse(&dup, Path::new("/a")).is_err());
    }

    #[test]
    fn empty_rejected() {
        assert!(Manifest::parse("# nothing\n", Path::new("/a")).is_err());
    }

    #[test]
    fn missing_field_rejected() {
        assert!(Manifest::parse("name=x kind=combine op=sum dtype=i32 block=2048", Path::new("/a"))
            .is_err());
    }
}
