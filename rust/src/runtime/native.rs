//! Pure-Rust compute engine: the reference datapath.
//!
//! Semantics must match `python/compile/kernels/ref.py` exactly — integer
//! ops wrap (two's complement, like jnp.int32), float ops follow IEEE.
//! This engine is the correctness oracle for the XLA engine and the
//! baseline for the `runtime_combine` ablation bench.

use anyhow::{bail, Result};

use crate::data::{payload, Dtype, Op, Payload};

use super::engine::Compute;

#[derive(Default)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        NativeEngine
    }
}

macro_rules! zip_op {
    ($a:expr, $b:expr, $f:expr) => {
        $a.iter().zip($b.iter()).map(|(&x, &y)| $f(x, y)).collect::<Vec<_>>()
    };
}

// SSPerf iteration 4 (REVERTED): a byte-level combine loop (one output
// allocation, no typed intermediates) measured 66% SLOWER than this
// typed-vector path — per-element [u8;N] encode/decode defeats the
// autovectorizer, while to_i32/apply/from_i32 compiles to clean SIMD.
// Kept as a negative result in EXPERIMENTS.md SSPerf.

// NOTE (SSPerf): the per-op match must stay INSIDE each apply fn with
// inline closures — hoisting it into a fn-pointer lookup blocked inlining
// and with it autovectorization (measured regression, see EXPERIMENTS.md).
fn apply_i32(op: Op, a: &[i32], b: &[i32]) -> Vec<i32> {
    match op {
        Op::Sum => zip_op!(a, b, |x: i32, y: i32| x.wrapping_add(y)),
        Op::Prod => zip_op!(a, b, |x: i32, y: i32| x.wrapping_mul(y)),
        Op::Max => zip_op!(a, b, |x: i32, y: i32| x.max(y)),
        Op::Min => zip_op!(a, b, |x: i32, y: i32| x.min(y)),
        Op::Band => zip_op!(a, b, |x: i32, y: i32| x & y),
        Op::Bor => zip_op!(a, b, |x: i32, y: i32| x | y),
        Op::Bxor => zip_op!(a, b, |x: i32, y: i32| x ^ y),
    }
}

fn apply_f32(op: Op, a: &[f32], b: &[f32]) -> Vec<f32> {
    match op {
        Op::Sum => zip_op!(a, b, |x: f32, y: f32| x + y),
        Op::Prod => zip_op!(a, b, |x: f32, y: f32| x * y),
        Op::Max => zip_op!(a, b, |x: f32, y: f32| x.max(y)),
        Op::Min => zip_op!(a, b, |x: f32, y: f32| x.min(y)),
        _ => unreachable!("bitwise on float rejected earlier"),
    }
}

fn apply_f64(op: Op, a: &[f64], b: &[f64]) -> Vec<f64> {
    match op {
        Op::Sum => zip_op!(a, b, |x: f64, y: f64| x + y),
        Op::Prod => zip_op!(a, b, |x: f64, y: f64| x * y),
        Op::Max => zip_op!(a, b, |x: f64, y: f64| x.max(y)),
        Op::Min => zip_op!(a, b, |x: f64, y: f64| x.min(y)),
        _ => unreachable!("bitwise on float rejected earlier"),
    }
}

impl Compute for NativeEngine {
    fn combine(&self, a: &Payload, b: &Payload, op: Op) -> Result<Payload> {
        if a.dtype() != b.dtype() || a.len() != b.len() {
            bail!(
                "combine shape/dtype mismatch: {:?}x{} vs {:?}x{}",
                a.dtype(),
                a.len(),
                b.dtype(),
                b.len()
            );
        }
        if !op.valid_for(a.dtype()) {
            bail!("{} invalid for {}", op.name(), a.dtype().name());
        }
        Ok(match a.dtype() {
            Dtype::I32 => Payload::from_i32(&apply_i32(op, &a.to_i32(), &b.to_i32())),
            Dtype::F32 => Payload::from_f32(&apply_f32(op, &a.to_f32(), &b.to_f32())),
            Dtype::F64 => Payload::from_f64(&apply_f64(op, &a.to_f64(), &b.to_f64())),
        })
    }

    fn scan(&self, x: &Payload, op: Op, inclusive: bool) -> Result<Payload> {
        if !op.valid_for(x.dtype()) {
            bail!("{} invalid for {}", op.name(), x.dtype().name());
        }
        fn scan_vec<T: Copy>(xs: &[T], f: impl Fn(T, T) -> T, ident: T, inclusive: bool) -> Vec<T> {
            let mut acc = ident;
            xs.iter()
                .map(|&v| {
                    if inclusive {
                        acc = f(acc, v);
                        acc
                    } else {
                        let out = acc;
                        acc = f(acc, v);
                        out
                    }
                })
                .collect()
        }
        Ok(match x.dtype() {
            Dtype::I32 => Payload::from_i32(&scan_vec(
                &x.to_i32(),
                |a, b| apply_i32(op, &[a], &[b])[0],
                payload::identity_i32(op),
                inclusive,
            )),
            Dtype::F32 => Payload::from_f32(&scan_vec(
                &x.to_f32(),
                |a, b| apply_f32(op, &[a], &[b])[0],
                payload::identity_f32(op),
                inclusive,
            )),
            Dtype::F64 => Payload::from_f64(&scan_vec(
                &x.to_f64(),
                |a, b| apply_f64(op, &[a], &[b])[0],
                payload::identity_f64(op),
                inclusive,
            )),
        })
    }

    fn derive(&self, cumulative: &Payload, own: &Payload) -> Result<Payload> {
        if cumulative.dtype() != Dtype::I32 || own.dtype() != Dtype::I32 {
            bail!("derive is only exact for MPI_INT (paper SSIII-C)");
        }
        if cumulative.len() != own.len() {
            bail!("derive length mismatch");
        }
        let c = cumulative.to_i32();
        let o = own.to_i32();
        Ok(Payload::from_i32(&zip_op!(c, o, |x: i32, y: i32| x.wrapping_sub(y))))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combine_all_ops_i32() {
        let e = NativeEngine::new();
        let a = Payload::from_i32(&[6, -3, 0b1100]);
        let b = Payload::from_i32(&[2, 5, 0b1010]);
        let cases = [
            (Op::Sum, vec![8, 2, 22]),
            (Op::Prod, vec![12, -15, 120]),
            (Op::Max, vec![6, 5, 12]),
            (Op::Min, vec![2, -3, 10]),
            (Op::Band, vec![2, 5, 0b1000]),
            (Op::Bor, vec![6, -3, 0b1110]),
            (Op::Bxor, vec![4, -8, 0b0110]),
        ];
        for (op, want) in cases {
            assert_eq!(e.combine(&a, &b, op).unwrap().to_i32(), want, "{op:?}");
        }
    }

    #[test]
    fn combine_wraps_like_jnp_int32() {
        let e = NativeEngine::new();
        let a = Payload::from_i32(&[i32::MAX]);
        let b = Payload::from_i32(&[1]);
        assert_eq!(e.combine(&a, &b, Op::Sum).unwrap().to_i32(), vec![i32::MIN]);
    }

    #[test]
    fn combine_floats() {
        let e = NativeEngine::new();
        let a = Payload::from_f64(&[1.5, -2.0]);
        let b = Payload::from_f64(&[0.5, 3.0]);
        assert_eq!(e.combine(&a, &b, Op::Sum).unwrap().to_f64(), vec![2.0, 1.0]);
        assert_eq!(e.combine(&a, &b, Op::Max).unwrap().to_f64(), vec![1.5, 3.0]);
    }

    #[test]
    fn mismatches_rejected() {
        let e = NativeEngine::new();
        let a = Payload::from_i32(&[1]);
        let b = Payload::from_i32(&[1, 2]);
        assert!(e.combine(&a, &b, Op::Sum).is_err());
        let f = Payload::from_f32(&[1.0]);
        assert!(e.combine(&a, &f, Op::Sum).is_err());
        assert!(e.combine(&f, &f, Op::Band).is_err());
    }

    #[test]
    fn scan_matches_definition() {
        let e = NativeEngine::new();
        let x = Payload::from_i32(&[1, 2, 3, 4]);
        assert_eq!(e.scan(&x, Op::Sum, true).unwrap().to_i32(), vec![1, 3, 6, 10]);
        assert_eq!(e.scan(&x, Op::Sum, false).unwrap().to_i32(), vec![0, 1, 3, 6]);
        assert_eq!(e.scan(&x, Op::Max, true).unwrap().to_i32(), vec![1, 2, 3, 4]);
        let f = Payload::from_f32(&[2.0, 0.5]);
        assert_eq!(e.scan(&f, Op::Prod, true).unwrap().to_f32(), vec![2.0, 1.0]);
    }

    #[test]
    fn derive_inverts_sum() {
        let e = NativeEngine::new();
        let own = Payload::from_i32(&[5, -7, i32::MAX]);
        let peer = Payload::from_i32(&[3, 11, 1]);
        let cum = e.combine(&peer, &own, Op::Sum).unwrap();
        assert_eq!(e.derive(&cum, &own).unwrap().to_i32(), peer.to_i32());
    }

    #[test]
    fn derive_rejects_floats() {
        let e = NativeEngine::new();
        let f = Payload::from_f32(&[1.0]);
        assert!(e.derive(&f, &f).is_err());
    }
}
