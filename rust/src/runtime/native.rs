//! Pure-Rust compute engine: the reference datapath.
//!
//! Semantics must match `python/compile/kernels/ref.py` exactly — integer
//! ops wrap (two's complement, like jnp.int32), float ops follow IEEE.
//! This engine is the correctness oracle for the XLA engine and the
//! baseline for the `runtime_combine` ablation bench.

use anyhow::{bail, Result};

use crate::data::{payload, Dtype, Op, Payload};

use super::engine::Compute;

#[derive(Default)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        NativeEngine
    }
}

macro_rules! zip_op {
    ($a:expr, $b:expr, $f:expr) => {
        $a.iter().zip($b.iter()).map(|(&x, &y)| $f(x, y)).collect::<Vec<_>>()
    };
}

// In-place variants over the arena-backed typed views: `acc[i] = f(acc[i],
// b[i])` (fwd) / `acc[i] = f(b[i], acc[i])` (rev).  Same closures, same
// per-element order as zip_op, so results are bit-identical to `combine` —
// the fold-equivalence prop test (tests/fold_equivalence.rs) pins this.
macro_rules! fold_fwd {
    ($acc:expr, $b:expr, $f:expr) => {
        for (x, &y) in $acc.iter_mut().zip($b.iter()) {
            *x = $f(*x, y);
        }
    };
}

macro_rules! fold_rev {
    ($acc:expr, $b:expr, $f:expr) => {
        for (x, &y) in $acc.iter_mut().zip($b.iter()) {
            *x = $f(y, *x);
        }
    };
}

// SSPerf iteration 4 (REVERTED): a byte-level combine loop (one output
// allocation, no typed intermediates) measured 66% SLOWER than this
// typed-vector path — per-element [u8;N] encode/decode defeats the
// autovectorizer, while to_i32/apply/from_i32 compiles to clean SIMD.
// Kept as a negative result in EXPERIMENTS.md SSPerf.

// NOTE (SSPerf): the per-op match must stay INSIDE each apply fn with
// inline closures — hoisting it into a fn-pointer lookup blocked inlining
// and with it autovectorization (measured regression, see EXPERIMENTS.md).
fn apply_i32(op: Op, a: &[i32], b: &[i32]) -> Vec<i32> {
    match op {
        Op::Sum => zip_op!(a, b, |x: i32, y: i32| x.wrapping_add(y)),
        Op::Prod => zip_op!(a, b, |x: i32, y: i32| x.wrapping_mul(y)),
        Op::Max => zip_op!(a, b, |x: i32, y: i32| x.max(y)),
        Op::Min => zip_op!(a, b, |x: i32, y: i32| x.min(y)),
        Op::Band => zip_op!(a, b, |x: i32, y: i32| x & y),
        Op::Bor => zip_op!(a, b, |x: i32, y: i32| x | y),
        Op::Bxor => zip_op!(a, b, |x: i32, y: i32| x ^ y),
    }
}

fn apply_f32(op: Op, a: &[f32], b: &[f32]) -> Vec<f32> {
    match op {
        Op::Sum => zip_op!(a, b, |x: f32, y: f32| x + y),
        Op::Prod => zip_op!(a, b, |x: f32, y: f32| x * y),
        Op::Max => zip_op!(a, b, |x: f32, y: f32| x.max(y)),
        Op::Min => zip_op!(a, b, |x: f32, y: f32| x.min(y)),
        _ => unreachable!("bitwise on float rejected earlier"),
    }
}

fn apply_f64(op: Op, a: &[f64], b: &[f64]) -> Vec<f64> {
    match op {
        Op::Sum => zip_op!(a, b, |x: f64, y: f64| x + y),
        Op::Prod => zip_op!(a, b, |x: f64, y: f64| x * y),
        Op::Max => zip_op!(a, b, |x: f64, y: f64| x.max(y)),
        Op::Min => zip_op!(a, b, |x: f64, y: f64| x.min(y)),
        _ => unreachable!("bitwise on float rejected earlier"),
    }
}

// NOTE (SSPerf): the per-op match stays INSIDE each fold fn, exactly like
// the apply fns above — the fn-pointer-hoisting regression applies to the
// in-place path just the same (EXPERIMENTS.md SSPerf iteration 3).
fn fold_i32(op: Op, acc: &mut [i32], b: &[i32]) {
    match op {
        Op::Sum => fold_fwd!(acc, b, |x: i32, y: i32| x.wrapping_add(y)),
        Op::Prod => fold_fwd!(acc, b, |x: i32, y: i32| x.wrapping_mul(y)),
        Op::Max => fold_fwd!(acc, b, |x: i32, y: i32| x.max(y)),
        Op::Min => fold_fwd!(acc, b, |x: i32, y: i32| x.min(y)),
        Op::Band => fold_fwd!(acc, b, |x: i32, y: i32| x & y),
        Op::Bor => fold_fwd!(acc, b, |x: i32, y: i32| x | y),
        Op::Bxor => fold_fwd!(acc, b, |x: i32, y: i32| x ^ y),
    }
}

fn fold_rev_i32(op: Op, acc: &mut [i32], a: &[i32]) {
    match op {
        Op::Sum => fold_rev!(acc, a, |x: i32, y: i32| x.wrapping_add(y)),
        Op::Prod => fold_rev!(acc, a, |x: i32, y: i32| x.wrapping_mul(y)),
        Op::Max => fold_rev!(acc, a, |x: i32, y: i32| x.max(y)),
        Op::Min => fold_rev!(acc, a, |x: i32, y: i32| x.min(y)),
        Op::Band => fold_rev!(acc, a, |x: i32, y: i32| x & y),
        Op::Bor => fold_rev!(acc, a, |x: i32, y: i32| x | y),
        Op::Bxor => fold_rev!(acc, a, |x: i32, y: i32| x ^ y),
    }
}

fn fold_f32(op: Op, acc: &mut [f32], b: &[f32]) {
    match op {
        Op::Sum => fold_fwd!(acc, b, |x: f32, y: f32| x + y),
        Op::Prod => fold_fwd!(acc, b, |x: f32, y: f32| x * y),
        Op::Max => fold_fwd!(acc, b, |x: f32, y: f32| x.max(y)),
        Op::Min => fold_fwd!(acc, b, |x: f32, y: f32| x.min(y)),
        _ => unreachable!("bitwise on float rejected earlier"),
    }
}

fn fold_rev_f32(op: Op, acc: &mut [f32], a: &[f32]) {
    match op {
        Op::Sum => fold_rev!(acc, a, |x: f32, y: f32| x + y),
        Op::Prod => fold_rev!(acc, a, |x: f32, y: f32| x * y),
        Op::Max => fold_rev!(acc, a, |x: f32, y: f32| x.max(y)),
        Op::Min => fold_rev!(acc, a, |x: f32, y: f32| x.min(y)),
        _ => unreachable!("bitwise on float rejected earlier"),
    }
}

fn fold_f64(op: Op, acc: &mut [f64], b: &[f64]) {
    match op {
        Op::Sum => fold_fwd!(acc, b, |x: f64, y: f64| x + y),
        Op::Prod => fold_fwd!(acc, b, |x: f64, y: f64| x * y),
        Op::Max => fold_fwd!(acc, b, |x: f64, y: f64| x.max(y)),
        Op::Min => fold_fwd!(acc, b, |x: f64, y: f64| x.min(y)),
        _ => unreachable!("bitwise on float rejected earlier"),
    }
}

fn fold_rev_f64(op: Op, acc: &mut [f64], a: &[f64]) {
    match op {
        Op::Sum => fold_rev!(acc, a, |x: f64, y: f64| x + y),
        Op::Prod => fold_rev!(acc, a, |x: f64, y: f64| x * y),
        Op::Max => fold_rev!(acc, a, |x: f64, y: f64| x.max(y)),
        Op::Min => fold_rev!(acc, a, |x: f64, y: f64| x.min(y)),
        _ => unreachable!("bitwise on float rejected earlier"),
    }
}

/// Shape/dtype/op validation shared by the allocating and in-place paths.
fn check_combine(a: &Payload, b: &Payload, op: Op) -> Result<()> {
    if a.dtype() != b.dtype() || a.len() != b.len() {
        bail!(
            "combine shape/dtype mismatch: {:?}x{} vs {:?}x{}",
            a.dtype(),
            a.len(),
            b.dtype(),
            b.len()
        );
    }
    if !op.valid_for(a.dtype()) {
        bail!("{} invalid for {}", op.name(), a.dtype().name());
    }
    Ok(())
}

impl Compute for NativeEngine {
    fn combine(&self, a: &Payload, b: &Payload, op: Op) -> Result<Payload> {
        check_combine(a, b, op)?;
        Ok(match a.dtype() {
            Dtype::I32 => Payload::from_i32(&apply_i32(op, &a.to_i32(), &b.to_i32())),
            Dtype::F32 => Payload::from_f32(&apply_f32(op, &a.to_f32(), &b.to_f32())),
            Dtype::F64 => Payload::from_f64(&apply_f64(op, &a.to_f64(), &b.to_f64())),
        })
    }

    fn combine_into(&self, acc: &mut Payload, b: &Payload, op: Op) -> Result<()> {
        check_combine(acc, b, op)?;
        // the accumulator view is always producible in place (as_mut_*
        // materializes shared/unaligned windows); only an unaligned `b`
        // window needs the copying fallback — structurally impossible for
        // arena-backed payloads, kept for hand-built wire slices.
        match acc.dtype() {
            Dtype::I32 => match b.try_as_i32() {
                Some(bs) => fold_i32(op, acc.as_mut_i32(), bs),
                None => fold_i32(op, acc.as_mut_i32(), &b.to_i32()),
            },
            Dtype::F32 => match b.try_as_f32() {
                Some(bs) => fold_f32(op, acc.as_mut_f32(), bs),
                None => fold_f32(op, acc.as_mut_f32(), &b.to_f32()),
            },
            Dtype::F64 => match b.try_as_f64() {
                Some(bs) => fold_f64(op, acc.as_mut_f64(), bs),
                None => fold_f64(op, acc.as_mut_f64(), &b.to_f64()),
            },
        }
        Ok(())
    }

    fn combine_into_rev(&self, acc: &mut Payload, a: &Payload, op: Op) -> Result<()> {
        check_combine(a, acc, op)?;
        match acc.dtype() {
            Dtype::I32 => match a.try_as_i32() {
                Some(xs) => fold_rev_i32(op, acc.as_mut_i32(), xs),
                None => fold_rev_i32(op, acc.as_mut_i32(), &a.to_i32()),
            },
            Dtype::F32 => match a.try_as_f32() {
                Some(xs) => fold_rev_f32(op, acc.as_mut_f32(), xs),
                None => fold_rev_f32(op, acc.as_mut_f32(), &a.to_f32()),
            },
            Dtype::F64 => match a.try_as_f64() {
                Some(xs) => fold_rev_f64(op, acc.as_mut_f64(), xs),
                None => fold_rev_f64(op, acc.as_mut_f64(), &a.to_f64()),
            },
        }
        Ok(())
    }

    fn scan(&self, x: &Payload, op: Op, inclusive: bool) -> Result<Payload> {
        if !op.valid_for(x.dtype()) {
            bail!("{} invalid for {}", op.name(), x.dtype().name());
        }
        fn scan_vec<T: Copy>(xs: &[T], f: impl Fn(T, T) -> T, ident: T, inclusive: bool) -> Vec<T> {
            let mut acc = ident;
            xs.iter()
                .map(|&v| {
                    if inclusive {
                        acc = f(acc, v);
                        acc
                    } else {
                        let out = acc;
                        acc = f(acc, v);
                        out
                    }
                })
                .collect()
        }
        Ok(match x.dtype() {
            Dtype::I32 => Payload::from_i32(&scan_vec(
                &x.to_i32(),
                |a, b| apply_i32(op, &[a], &[b])[0],
                payload::identity_i32(op),
                inclusive,
            )),
            Dtype::F32 => Payload::from_f32(&scan_vec(
                &x.to_f32(),
                |a, b| apply_f32(op, &[a], &[b])[0],
                payload::identity_f32(op),
                inclusive,
            )),
            Dtype::F64 => Payload::from_f64(&scan_vec(
                &x.to_f64(),
                |a, b| apply_f64(op, &[a], &[b])[0],
                payload::identity_f64(op),
                inclusive,
            )),
        })
    }

    fn derive(&self, cumulative: &Payload, own: &Payload) -> Result<Payload> {
        if cumulative.dtype() != Dtype::I32 || own.dtype() != Dtype::I32 {
            bail!("derive is only exact for MPI_INT (paper SSIII-C)");
        }
        if cumulative.len() != own.len() {
            bail!("derive length mismatch");
        }
        let c = cumulative.to_i32();
        let o = own.to_i32();
        Ok(Payload::from_i32(&zip_op!(c, o, |x: i32, y: i32| x.wrapping_sub(y))))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combine_all_ops_i32() {
        let e = NativeEngine::new();
        let a = Payload::from_i32(&[6, -3, 0b1100]);
        let b = Payload::from_i32(&[2, 5, 0b1010]);
        let cases = [
            (Op::Sum, vec![8, 2, 22]),
            (Op::Prod, vec![12, -15, 120]),
            (Op::Max, vec![6, 5, 12]),
            (Op::Min, vec![2, -3, 10]),
            (Op::Band, vec![2, 5, 0b1000]),
            (Op::Bor, vec![6, -3, 0b1110]),
            (Op::Bxor, vec![4, -8, 0b0110]),
        ];
        for (op, want) in cases {
            assert_eq!(e.combine(&a, &b, op).unwrap().to_i32(), want, "{op:?}");
        }
    }

    #[test]
    fn combine_wraps_like_jnp_int32() {
        let e = NativeEngine::new();
        let a = Payload::from_i32(&[i32::MAX]);
        let b = Payload::from_i32(&[1]);
        assert_eq!(e.combine(&a, &b, Op::Sum).unwrap().to_i32(), vec![i32::MIN]);
    }

    #[test]
    fn combine_floats() {
        let e = NativeEngine::new();
        let a = Payload::from_f64(&[1.5, -2.0]);
        let b = Payload::from_f64(&[0.5, 3.0]);
        assert_eq!(e.combine(&a, &b, Op::Sum).unwrap().to_f64(), vec![2.0, 1.0]);
        assert_eq!(e.combine(&a, &b, Op::Max).unwrap().to_f64(), vec![1.5, 3.0]);
    }

    #[test]
    fn mismatches_rejected() {
        let e = NativeEngine::new();
        let a = Payload::from_i32(&[1]);
        let b = Payload::from_i32(&[1, 2]);
        assert!(e.combine(&a, &b, Op::Sum).is_err());
        let f = Payload::from_f32(&[1.0]);
        assert!(e.combine(&a, &f, Op::Sum).is_err());
        assert!(e.combine(&f, &f, Op::Band).is_err());
    }

    #[test]
    fn scan_matches_definition() {
        let e = NativeEngine::new();
        let x = Payload::from_i32(&[1, 2, 3, 4]);
        assert_eq!(e.scan(&x, Op::Sum, true).unwrap().to_i32(), vec![1, 3, 6, 10]);
        assert_eq!(e.scan(&x, Op::Sum, false).unwrap().to_i32(), vec![0, 1, 3, 6]);
        assert_eq!(e.scan(&x, Op::Max, true).unwrap().to_i32(), vec![1, 2, 3, 4]);
        let f = Payload::from_f32(&[2.0, 0.5]);
        assert_eq!(e.scan(&f, Op::Prod, true).unwrap().to_f32(), vec![2.0, 1.0]);
    }

    #[test]
    fn derive_inverts_sum() {
        let e = NativeEngine::new();
        let own = Payload::from_i32(&[5, -7, i32::MAX]);
        let peer = Payload::from_i32(&[3, 11, 1]);
        let cum = e.combine(&peer, &own, Op::Sum).unwrap();
        assert_eq!(e.derive(&cum, &own).unwrap().to_i32(), peer.to_i32());
    }

    #[test]
    fn derive_rejects_floats() {
        let e = NativeEngine::new();
        let f = Payload::from_f32(&[1.0]);
        assert!(e.derive(&f, &f).is_err());
    }

    #[test]
    fn combine_into_matches_combine_all_ops() {
        let e = NativeEngine::new();
        let a = Payload::from_i32(&[6, -3, 0b1100, i32::MAX]);
        let b = Payload::from_i32(&[2, 5, 0b1010, 1]);
        for op in Op::ALL {
            let want = e.combine(&a, &b, op).unwrap();
            let mut acc = a.clone();
            e.combine_into(&mut acc, &b, op).unwrap();
            assert_eq!(acc.bytes(), want.bytes(), "{op:?} fwd");
            let want_rev = e.combine(&b, &a, op).unwrap();
            let mut acc = a.clone();
            e.combine_into_rev(&mut acc, &b, op).unwrap();
            assert_eq!(acc.bytes(), want_rev.bytes(), "{op:?} rev");
        }
    }

    #[test]
    fn combine_into_floats_bit_identical() {
        let e = NativeEngine::new();
        let a = Payload::from_f64(&[1.5, -0.0, f64::MAX]);
        let b = Payload::from_f64(&[0.5, 0.0, f64::MAX]);
        for op in [Op::Sum, Op::Prod, Op::Max, Op::Min] {
            let want = e.combine(&a, &b, op).unwrap();
            let mut acc = a.clone();
            e.combine_into(&mut acc, &b, op).unwrap();
            assert_eq!(acc.bytes(), want.bytes(), "{op:?}");
        }
    }

    #[test]
    fn combine_into_unique_acc_runs_in_place() {
        let e = NativeEngine::new();
        let mut acc = Payload::from_i32(&[1, 2, 3]);
        let b = Payload::from_i32(&[10, 20, 30]);
        e.combine_into(&mut acc, &b, Op::Sum).unwrap(); // acc unique from birth
        let before = acc.bytes().as_ptr();
        e.combine_into(&mut acc, &b, Op::Sum).unwrap();
        assert_eq!(acc.bytes().as_ptr(), before, "unique accumulator must not copy");
        assert_eq!(acc.to_i32(), vec![21, 42, 63]);
    }

    #[test]
    fn combine_into_shared_acc_leaves_original_untouched() {
        let e = NativeEngine::new();
        let orig = Payload::from_i32(&[1, 2]);
        let mut acc = orig.clone();
        e.combine_into(&mut acc, &Payload::from_i32(&[5, 5]), Op::Sum).unwrap();
        assert_eq!(acc.to_i32(), vec![6, 7]);
        assert_eq!(orig.to_i32(), vec![1, 2], "CoW fork must protect the sharer");
    }

    #[test]
    fn combine_into_rejects_mismatches() {
        let e = NativeEngine::new();
        let mut a = Payload::from_i32(&[1]);
        assert!(e.combine_into(&mut a, &Payload::from_i32(&[1, 2]), Op::Sum).is_err());
        let mut f = Payload::from_f32(&[1.0]);
        assert!(e.combine_into(&mut f, &Payload::from_f32(&[2.0]), Op::Band).is_err());
    }

    #[test]
    fn combine_into_unaligned_operand_uses_copying_fallback() {
        // a sub-element-aligned window (only constructible via the test
        // hook) must route through the to_* fallback and still match the
        // allocating path bit-for-bit, in both operand positions
        let e = NativeEngine::new();
        let vals = [1.5f64, -2.5];
        let mut raw = vec![0u8; 4];
        for v in vals {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        let misaligned = Payload::misaligned_for_test(Dtype::F64, &raw, 4);
        let a = Payload::from_f64(&[10.0, 20.0]);
        let want = e.combine(&a, &misaligned, Op::Sum).unwrap();
        let mut acc = a.clone();
        e.combine_into(&mut acc, &misaligned, Op::Sum).unwrap();
        assert_eq!(acc.bytes(), want.bytes(), "fwd with unaligned b");
        let want_rev = e.combine(&misaligned, &a, Op::Sum).unwrap();
        let mut acc = a.clone();
        e.combine_into_rev(&mut acc, &misaligned, Op::Sum).unwrap();
        assert_eq!(acc.bytes(), want_rev.bytes(), "rev with unaligned a");
        // unaligned ACCUMULATOR: as_mut_* realigns by materializing
        let mut acc = misaligned.clone();
        e.combine_into(&mut acc, &a, Op::Sum).unwrap();
        let want_acc = e.combine(&misaligned, &a, Op::Sum).unwrap();
        assert_eq!(acc.bytes(), want_acc.bytes(), "unaligned accumulator");
    }

    #[test]
    fn combine_into_on_windows() {
        // non-zero-offset windows (MTU chunks) fold correctly and do not
        // disturb the rest of the shared message
        let e = NativeEngine::new();
        let msg = Payload::from_i32(&(0..8).collect::<Vec<_>>());
        let mut acc = msg.slice(3, 4);
        let b = Payload::from_i32(&[100, 100, 100, 100]);
        e.combine_into(&mut acc, &b, Op::Sum).unwrap();
        assert_eq!(acc.to_i32(), vec![103, 104, 105, 106]);
        assert_eq!(msg.to_i32(), (0..8).collect::<Vec<_>>());
    }
}
