//! The [`Compute`] trait both engines implement, and engine construction.

use anyhow::Result;

use crate::config::EngineKind;
use crate::data::{Op, Payload};

/// Payload reductions used across the system.  Implementations must be
/// deterministic: the simulator's reproducibility property depends on it.
pub trait Compute {
    /// Elementwise `a (op) b`; shapes and dtypes must match.
    fn combine(&self, a: &Payload, b: &Payload, op: Op) -> Result<Payload>;

    /// In-place left fold: `acc = acc (op) b`.  The native engine folds
    /// over the payloads' zero-copy typed views with zero steady-state
    /// allocations (a shared accumulator is materialized once into a
    /// pooled arena buffer); the default delegates to [`Compute::combine`]
    /// so engines without an in-place path stay bit-identical.
    fn combine_into(&self, acc: &mut Payload, b: &Payload, op: Op) -> Result<()> {
        let r = self.combine(acc, b, op)?;
        *acc = r;
        Ok(())
    }

    /// In-place right fold: `acc = a (op) acc`.  Kept separate from
    /// [`Compute::combine_into`] because operand order must be preserved
    /// bit-for-bit (Max/Min on IEEE floats are not symmetric in the
    /// signed-zero/NaN corners), and the state machines fold from both
    /// sides.
    fn combine_into_rev(&self, acc: &mut Payload, a: &Payload, op: Op) -> Result<()> {
        let r = self.combine(a, acc, op)?;
        *acc = r;
        Ok(())
    }

    /// Prefix scan of a payload (any length; engines chunk internally).
    fn scan(&self, x: &Payload, op: Op, inclusive: bool) -> Result<Payload>;

    /// Inverse-subtract of the multicast optimization (SSIII-C):
    /// `peer = cumulative - own`.  Only valid where `op.invertible_for`
    /// holds (MPI_SUM over MPI_INT).
    fn derive(&self, cumulative: &Payload, own: &Payload) -> Result<Payload>;

    /// Engine label for logs and tables.
    fn name(&self) -> &'static str;
}

/// Build the configured engine.  `Xla` probes the artifact directory and
/// falls back to native (with a visible warning) when artifacts are
/// missing — unit tests must run without `make artifacts`.
pub fn make_engine(kind: EngineKind, artifact_dir: &str) -> std::rc::Rc<dyn Compute> {
    match kind {
        EngineKind::Native => std::rc::Rc::new(super::NativeEngine::new()),
        EngineKind::Xla => match super::XlaEngine::load(artifact_dir) {
            Ok(e) => std::rc::Rc::new(e),
            Err(err) => {
                eprintln!(
                    "warning: XLA engine unavailable ({err}); falling back to native compute"
                );
                std::rc::Rc::new(super::NativeEngine::new())
            }
        },
    }
}

/// Oracle helper: prefix over a slice of per-rank payloads, as MPI_Scan
/// (or MPI_Exscan) defines it.  Used by tests and the verify path.
pub fn oracle_prefix(
    engine: &dyn Compute,
    contributions: &[Payload],
    op: Op,
    inclusive: bool,
    rank: usize,
) -> Result<Payload> {
    assert!(rank < contributions.len());
    if !inclusive && rank == 0 {
        let c = &contributions[0];
        return Ok(Payload::identity(c.dtype(), op, c.len()));
    }
    let last = if inclusive { rank } else { rank - 1 };
    // k-way in-place fold: the first combine_into materializes the cloned
    // head into a pooled buffer, every later step folds allocation-free —
    // O(1) buffer traffic instead of O(k) allocations.
    let mut acc = contributions[0].clone();
    for c in &contributions[1..=last] {
        engine.combine_into(&mut acc, c, op)?;
    }
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dtype;

    #[test]
    fn oracle_prefix_inclusive_exclusive() {
        let e = super::super::NativeEngine::new();
        let xs: Vec<Payload> =
            (1..=4).map(|r| Payload::from_i32(&[r, 10 * r])).collect();
        let inc = oracle_prefix(&e, &xs, Op::Sum, true, 3).unwrap();
        assert_eq!(inc.to_i32(), vec![10, 100]);
        let exc = oracle_prefix(&e, &xs, Op::Sum, false, 3).unwrap();
        assert_eq!(exc.to_i32(), vec![6, 60]);
        let exc0 = oracle_prefix(&e, &xs, Op::Sum, false, 0).unwrap();
        assert_eq!(exc0.to_i32(), vec![0, 0]);
        assert_eq!(exc0.dtype(), Dtype::I32);
    }

    #[test]
    fn make_engine_native_always_works() {
        let e = make_engine(crate::config::EngineKind::Native, "/nonexistent");
        assert_eq!(e.name(), "native");
    }

    #[test]
    fn make_engine_xla_falls_back_when_missing() {
        let e = make_engine(crate::config::EngineKind::Xla, "/definitely/not/here");
        assert_eq!(e.name(), "native");
    }
}
