//! Stub [`XlaEngine`] for builds without the `xla` cargo feature.
//!
//! The offline build cannot vendor the `xla` crate (PJRT bindings), so
//! this stub keeps the public surface of `xla_rt.rs` compiling: `load`
//! always fails, which makes `make_engine(EngineKind::Xla, ..)` fall back
//! to native compute and lets callers (selftest, integration tests) skip
//! gracefully.  No instance can ever be constructed, so the trait methods
//! are unreachable.

use anyhow::{bail, Result};

use crate::data::{Op, Payload};

use super::engine::Compute;

/// Placeholder with the same API as the real PJRT engine.
pub struct XlaEngine {
    _unconstructible: (),
}

impl XlaEngine {
    /// Always errors: the XLA runtime is not compiled in.
    pub fn load(artifact_dir: &str) -> Result<XlaEngine> {
        bail!(
            "XLA runtime not compiled in (enable the `xla` cargo feature and \
             provide the xla crate); cannot load artifacts from {artifact_dir}"
        )
    }

    pub fn artifact_count(&self) -> usize {
        0
    }

    pub fn probe_breakdown(&self, _reps: usize) -> Result<(u64, u64, u64)> {
        unreachable!("stub XlaEngine cannot be constructed")
    }

    pub fn probe_output_structure(&self) -> Result<()> {
        unreachable!("stub XlaEngine cannot be constructed")
    }
}

impl Compute for XlaEngine {
    fn combine(&self, _a: &Payload, _b: &Payload, _op: Op) -> Result<Payload> {
        unreachable!("stub XlaEngine cannot be constructed")
    }

    fn scan(&self, _x: &Payload, _op: Op, _inclusive: bool) -> Result<Payload> {
        unreachable!("stub XlaEngine cannot be constructed")
    }

    fn derive(&self, _cumulative: &Payload, _own: &Payload) -> Result<Payload> {
        unreachable!("stub XlaEngine cannot be constructed")
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_always_errors_without_feature() {
        let err = XlaEngine::load("artifacts").unwrap_err();
        assert!(format!("{err}").contains("not compiled in"));
    }
}
