//! The PJRT engine: runs the AOT-compiled HLO artifacts.
//!
//! Load path (see /opt/xla-example/load_hlo/ and DESIGN.md): HLO *text*
//! -> `HloModuleProto::from_text_file` -> `XlaComputation` -> PJRT CPU
//! `compile` -> `execute`.  Executables are compiled lazily on first use
//! and cached for the life of the engine; the simulation hot path then
//! only pays literal creation + execution.
//!
//! Payloads of arbitrary length are chunked to the fixed AOT block
//! (2048 elements), the tail padded with the op identity — the same
//! identity-padding contract `python/compile/model.py` documents.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::{Dtype, Op, Payload};

use super::engine::Compute;
use super::manifest::{ArtifactKind, Manifest};
use super::{NativeEngine, AOT_BLOCK};

pub struct XlaEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Lazily compiled executables.
    cache: RefCell<HashMap<(ArtifactKind, Op, Dtype), Rc<xla::PjRtLoadedExecutable>>>,
    /// Ops without artifacts (e.g. scan for non-sum ops) fall back here;
    /// the fallback is logged once per key.
    native: NativeEngine,
    warned: RefCell<std::collections::HashSet<String>>,
}

impl XlaEngine {
    /// Load the manifest and bring up the PJRT CPU client.
    pub fn load(artifact_dir: &str) -> Result<XlaEngine> {
        let dir = Path::new(artifact_dir);
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(XlaEngine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            native: NativeEngine::new(),
            warned: RefCell::new(std::collections::HashSet::new()),
        })
    }

    pub fn artifact_count(&self) -> usize {
        self.manifest.len()
    }

    /// Diagnostics: wallclock breakdown of one combine call on a full
    /// block — (literal creation, execute, readback) in ns.  Drives the
    /// SSPerf iteration in EXPERIMENTS.md.
    pub fn probe_breakdown(&self, reps: usize) -> Result<(u64, u64, u64)> {
        let exe = self
            .executable(ArtifactKind::Combine, Op::Sum, Dtype::I32)?
            .context("combine_sum_i32 artifact required")?;
        let a = Payload::from_i32(&(0..AOT_BLOCK as i32).collect::<Vec<_>>());
        let b = Payload::from_i32(&vec![1i32; AOT_BLOCK]);
        // warmup
        let la = Self::literal_of(&a)?;
        let lb = Self::literal_of(&b)?;
        let _ = exe.execute::<xla::Literal>(&[la, lb]);
        let (mut t_lit, mut t_exec, mut t_read) = (0u64, 0u64, 0u64);
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let la = Self::literal_of(&a)?;
            let lb = Self::literal_of(&b)?;
            let t1 = std::time::Instant::now();
            let out = exe.execute::<xla::Literal>(&[la, lb]).map_err(|e| anyhow!("{e:?}"))?;
            let t2 = std::time::Instant::now();
            let p = Self::read_block(&out[0][0], Dtype::I32)?;
            std::hint::black_box(&p);
            let t3 = std::time::Instant::now();
            t_lit += (t1 - t0).as_nanos() as u64;
            t_exec += (t2 - t1).as_nanos() as u64;
            t_read += (t3 - t2).as_nanos() as u64;
        }
        let n = reps as u64;
        Ok((t_lit / n, t_exec / n, t_read / n))
    }

    /// Compile (or fetch cached) the executable for a key.
    fn executable(
        &self,
        kind: ArtifactKind,
        op: Op,
        dtype: Dtype,
    ) -> Result<Option<Rc<xla::PjRtLoadedExecutable>>> {
        if let Some(exe) = self.cache.borrow().get(&(kind, op, dtype)) {
            return Ok(Some(exe.clone()));
        }
        let Some(entry) = self.manifest.get(kind, op, dtype) else {
            return Ok(None);
        };
        let proto = xla::HloModuleProto::from_text_file(&entry.path)
            .map_err(|e| anyhow!("loading {}: {e:?}", entry.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", entry.name))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert((kind, op, dtype), exe.clone());
        Ok(Some(exe))
    }

    fn warn_fallback(&self, what: &str) {
        if self.warned.borrow_mut().insert(what.to_string()) {
            eprintln!("xla engine: no artifact for {what}; using native fallback");
        }
    }

    fn element_type(dtype: Dtype) -> xla::ElementType {
        match dtype {
            Dtype::I32 => xla::ElementType::S32,
            Dtype::F32 => xla::ElementType::F32,
            Dtype::F64 => xla::ElementType::F64,
        }
    }

    /// Payload (exactly AOT_BLOCK elements) -> literal.
    fn literal_of(block: &Payload) -> Result<xla::Literal> {
        debug_assert_eq!(block.len(), AOT_BLOCK);
        xla::Literal::create_from_shape_and_untyped_data(
            Self::element_type(block.dtype()),
            &[AOT_BLOCK],
            block.bytes(),
        )
        .map_err(|e| anyhow!("literal: {e:?}"))
    }

    /// Literal (array root, or legacy 1-tuple root) -> payload.
    fn payload_of(lit: xla::Literal, dtype: Dtype) -> Result<Payload> {
        let out = match lit.shape() {
            Ok(xla::Shape::Tuple(_)) => lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?,
            _ => lit,
        };
        Ok(match dtype {
            Dtype::I32 => {
                Payload::from_i32(&out.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?)
            }
            Dtype::F32 => {
                Payload::from_f32(&out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?)
            }
            Dtype::F64 => {
                Payload::from_f64(&out.to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?)
            }
        })
    }

    /// Read one output block from the result buffer.
    ///
    /// SSPerf notes: artifacts are emitted with a plain *array* root
    /// (aot.py return_tuple=False), so this is to_literal_sync + one
    /// typed copy — no tuple decomposition.  We measured the seemingly
    /// cheaper `PjRtBuffer::copy_raw_to_host_sync` at ~126us/block on
    /// the TFRT CPU plugin (it stages through a slow raw-copy event
    /// path), vs ~17us for literal materialization — so the literal path
    /// stays (see EXPERIMENTS.md SSPerf iteration log).
    fn read_block(buffer: &xla::PjRtBuffer, dtype: Dtype) -> Result<Payload> {
        let lit = buffer.to_literal_sync().map_err(|e| anyhow!("sync: {e:?}"))?;
        Self::payload_of(lit, dtype)
    }

    /// Run a 2-arg block executable over payload chunks.
    fn run_binary_chunked(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        a: &Payload,
        b: &Payload,
        pad_op: Op,
    ) -> Result<Payload> {
        let n = a.len();
        let mut out_chunks = Vec::with_capacity(n.div_ceil(AOT_BLOCK));
        let mut i = 0;
        while i < n {
            let len = AOT_BLOCK.min(n - i);
            let mut ca = a.slice(i, len);
            let mut cb = b.slice(i, len);
            ca.pad_to(pad_op, AOT_BLOCK);
            cb.pad_to(pad_op, AOT_BLOCK);
            let la = Self::literal_of(&ca)?;
            let lb = Self::literal_of(&cb)?;
            let out =
                exe.execute::<xla::Literal>(&[la, lb]).map_err(|e| anyhow!("execute: {e:?}"))?;
            let mut chunk = Self::read_block(&out[0][0], a.dtype())?;
            chunk.truncate(len);
            out_chunks.push(chunk);
            i += len;
        }
        Ok(Payload::concat(&out_chunks))
    }
}

impl Compute for XlaEngine {
    fn combine(&self, a: &Payload, b: &Payload, op: Op) -> Result<Payload> {
        if a.dtype() != b.dtype() || a.len() != b.len() {
            bail!("combine shape/dtype mismatch");
        }
        if a.is_empty() {
            return Ok(a.clone());
        }
        match self.executable(ArtifactKind::Combine, op, a.dtype())? {
            Some(exe) => self.run_binary_chunked(&exe, a, b, op),
            None => {
                self.warn_fallback(&format!("combine/{}/{}", op.name(), a.dtype().name()));
                self.native.combine(a, b, op)
            }
        }
    }

    fn scan(&self, x: &Payload, op: Op, inclusive: bool) -> Result<Payload> {
        if x.is_empty() {
            return Ok(x.clone());
        }
        let kind = if inclusive { ArtifactKind::ScanInc } else { ArtifactKind::ScanExc };
        let Some(exe) = self.executable(kind, op, x.dtype())? else {
            self.warn_fallback(&format!(
                "scan_{}/{}/{}",
                if inclusive { "inc" } else { "exc" },
                op.name(),
                x.dtype().name()
            ));
            return self.native.scan(x, op, inclusive);
        };
        // inclusive-scan executable per block + carry across blocks; the
        // exclusive artifact is only valid for the first block (later
        // blocks must shift by the *inclusive* carry), so multi-block
        // exclusive scans compose inclusive blocks and shift locally.
        let n = x.len();
        if n <= AOT_BLOCK {
            let mut cx = x.clone();
            cx.pad_to(op, AOT_BLOCK);
            let lit = Self::literal_of(&cx)?;
            let result =
                exe.execute::<xla::Literal>(&[lit]).map_err(|e| anyhow!("execute: {e:?}"))?;
            let mut out = Self::read_block(&result[0][0], x.dtype())?;
            out.truncate(n);
            return Ok(out);
        }
        // multi-block: inclusive scan each block, combine with broadcast
        // carry, then (if exclusive) shift right by one with the identity.
        let inc_exe = self
            .executable(ArtifactKind::ScanInc, op, x.dtype())?
            .context("multi-block scan needs the inclusive artifact")?;
        let mut chunks = Vec::new();
        let mut carry: Option<Payload> = None;
        let mut i = 0;
        while i < n {
            let len = AOT_BLOCK.min(n - i);
            let mut cx = x.slice(i, len);
            cx.pad_to(op, AOT_BLOCK);
            let lit = Self::literal_of(&cx)?;
            let result =
                inc_exe.execute::<xla::Literal>(&[lit]).map_err(|e| anyhow!("execute: {e:?}"))?;
            let mut blk = Self::read_block(&result[0][0], x.dtype())?;
            blk.truncate(len);
            if let Some(c) = &carry {
                // broadcast the scalar carry over the block and combine
                let cb = broadcast_last(c, len);
                blk = self.combine(&cb, &blk, op)?;
            }
            carry = Some(blk.slice(len - 1, 1));
            chunks.push(blk);
            i += len;
        }
        let inc = Payload::concat(&chunks);
        if inclusive {
            Ok(inc)
        } else {
            // exclusive = identity ++ inclusive[..n-1]
            let mut out = Payload::identity(x.dtype(), op, 1);
            if n > 1 {
                out = Payload::concat(&[out, inc.slice(0, n - 1)]);
            }
            Ok(out)
        }
    }

    fn derive(&self, cumulative: &Payload, own: &Payload) -> Result<Payload> {
        if cumulative.dtype() != Dtype::I32 {
            bail!("derive is only exact for MPI_INT (paper SSIII-C)");
        }
        if cumulative.len() != own.len() {
            bail!("derive length mismatch");
        }
        if cumulative.is_empty() {
            return Ok(cumulative.clone());
        }
        match self.executable(ArtifactKind::Derive, Op::Sum, Dtype::I32)? {
            // padding with 0 is sound: 0 - 0 = 0 in the pad region
            Some(exe) => self.run_binary_chunked(&exe, cumulative, own, Op::Sum),
            None => {
                self.warn_fallback("derive/sub/i32");
                self.native.derive(cumulative, own)
            }
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Payload of `n` copies of `p`'s last element (carry broadcast).
fn broadcast_last(p: &Payload, n: usize) -> Payload {
    let last = p.slice(p.len() - 1, 1);
    Payload::concat(&vec![last; n])
}

#[cfg(test)]
mod tests {
    // Integration tests that need real artifacts live in
    // rust/tests/integration_runtime.rs (they skip when `make artifacts`
    // hasn't run).  Here: pure helpers only.
    use super::*;

    #[test]
    fn broadcast_last_repeats() {
        let p = Payload::from_i32(&[1, 2, 3]);
        assert_eq!(broadcast_last(&p, 4).to_i32(), vec![3, 3, 3, 3]);
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(XlaEngine::load("/no/such/dir").is_err());
    }
}

impl XlaEngine {
    /// TEMPORARY probe for perf investigation.
    pub fn probe_output_structure(&self) -> Result<()> {
        let exe = self
            .executable(ArtifactKind::Combine, Op::Sum, Dtype::I32)?
            .context("artifact")?;
        let a = Payload::from_i32(&(0..AOT_BLOCK as i32).collect::<Vec<_>>());
        let la = Self::literal_of(&a)?;
        let lb = Self::literal_of(&a)?;
        let out = exe.execute::<xla::Literal>(&[la, lb]).map_err(|e| anyhow!("{e:?}"))?;
        println!("replicas={} buffers_per_replica={}", out.len(), out[0].len());
        for (i, b) in out[0].iter().enumerate() {
            println!("buffer {i}: shape={:?}", b.on_device_shape());
        }
        // try raw copy from buffer 0
        let mut dst = vec![0i32; AOT_BLOCK];
        match out[0][0].copy_raw_to_host_sync(&mut dst, 0) {
            Ok(()) => println!("raw copy OK: dst[0..4]={:?} (want [0,2,4,6])", &dst[..4]),
            Err(e) => println!("raw copy failed: {e:?}"),
        }
        Ok(())
    }
}
