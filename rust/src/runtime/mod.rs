//! The compute runtime: executes payload reductions for hosts and NICs.
//!
//! Two engines implement the same [`Compute`] trait:
//!
//! - [`native::NativeEngine`] — pure Rust, always available, the oracle
//!   and ablation baseline;
//! - [`xla_rt::XlaEngine`] — loads the AOT HLO artifacts produced by
//!   `python/compile/aot.py` (Pallas kernel -> JAX graph -> HLO text),
//!   compiles them once on the PJRT CPU client, and runs every combine /
//!   scan / derive through the compiled executables.  Python never runs
//!   at simulation time.

pub mod engine;
pub mod manifest;
pub mod native;
// Without the `xla` cargo feature (the offline default) the PJRT engine
// is replaced by a stub whose `load` always errors; `make_engine` then
// falls back to native compute.  See xla_stub.rs.
#[cfg(feature = "xla")]
pub mod xla_rt;
#[cfg(not(feature = "xla"))]
#[path = "xla_stub.rs"]
pub mod xla_rt;

pub use engine::{make_engine, Compute};
pub use manifest::{Manifest, ManifestEntry};
pub use native::NativeEngine;
pub use xla_rt::XlaEngine;

/// Block size (elements) the AOT artifacts were compiled for; must match
/// `python/compile/kernels/__init__.py::BLOCK`.
pub const AOT_BLOCK: usize = 2048;

/// Default artifact directory, relative to the repo root.
pub const ARTIFACT_DIR: &str = "artifacts";
