//! Structured span tracing: a bounded recorder of typed simulation
//! spans plus renderers — the ASCII timeline (`nfscan run --trace`),
//! a raw event dump, and a Chrome-trace/Perfetto JSON export
//! (`nfscan trace`).
//!
//! Every record is a fixed-size `Copy` value ([`SpanData`]), so the
//! recorder never allocates per event: the backing ring is sized once
//! at `Trace::new` and at capacity a push recycles the slot the
//! oldest event vacates.  A disabled trace ([`Trace::disabled`])
//! rejects records before touching any payload — the hot path pays
//! one branch, zero allocations, and the event schedule is untouched.

use crate::metrics::json::Json;
use crate::net::Rank;
use crate::sim::SimTime;

/// Span/instant taxonomy.  The first seven kinds are the original
/// milestone glyphs; the rest arrived with latency attribution and
/// cover where time actually goes between a host call and its
/// completion.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TraceKind {
    /// Host process issues the collective (instant).
    HostCall,
    /// Offload request arrived at the local NIC (instant).
    Offload,
    /// NIC put a frame on the wire (span: serialization + propagation).
    NicSend,
    /// Frame fully arrived at a NIC port (instant).
    NicRecvd,
    /// End-to-end reliability ack consumed (instant).
    NicAck,
    /// NIC releases the Result packet up to the host (instant).
    NicResult,
    /// Host observed the completed collective (instant).
    HostComplete,
    /// Frame waited for an output port / switch trunk FIFO (span).
    SwitchQueue,
    /// Handler activation waited for a free HPU (span).
    HpuQueue,
    /// Handler/engine activation executed on the NIC (span).
    HandlerExec,
    /// One combine fold inside an activation (instant; `a` = cycles).
    Combine,
    /// Retransmit timer fired for a pending transaction (instant).
    Timeout,
    /// NIC retransmitted a timed-out frame (instant; `a` = retry no.).
    Retransmit,
    /// The fault plan dropped a frame on the wire (instant).
    Dropped,
}

impl TraceKind {
    fn glyph(self) -> char {
        match self {
            TraceKind::HostCall => 'C',
            TraceKind::Offload => 'O',
            TraceKind::NicSend => '>',
            TraceKind::NicRecvd => '<',
            TraceKind::NicAck => 'a',
            TraceKind::NicResult => 'R',
            TraceKind::HostComplete => '*',
            TraceKind::SwitchQueue => 'q',
            TraceKind::HpuQueue => 'h',
            TraceKind::HandlerExec => 'x',
            TraceKind::Combine => '+',
            TraceKind::Timeout => 'T',
            TraceKind::Retransmit => '!',
            TraceKind::Dropped => 'D',
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TraceKind::HostCall => "host_call",
            TraceKind::Offload => "offload",
            TraceKind::NicSend => "nic_send",
            TraceKind::NicRecvd => "nic_recv",
            TraceKind::NicAck => "nic_ack",
            TraceKind::NicResult => "nic_result",
            TraceKind::HostComplete => "host_complete",
            TraceKind::SwitchQueue => "switch_queue",
            TraceKind::HpuQueue => "hpu_queue",
            TraceKind::HandlerExec => "handler_exec",
            TraceKind::Combine => "combine",
            TraceKind::Timeout => "timeout",
            TraceKind::Retransmit => "retransmit",
            TraceKind::Dropped => "dropped",
        }
    }
}

/// Fixed-size, `Copy` payload of one record.  `end == at` marks an
/// instant; `end > at` a span.  `txn` links records of one reliable
/// transaction across ranks (0 = none); `a` is kind-specific (peer
/// rank for sends, cycles for combines, retry ordinal for
/// retransmits).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpanData {
    pub end: SimTime,
    pub txn: u64,
    pub epoch: u16,
    pub a: u64,
}

impl SpanData {
    /// A zero-duration record at the record's own timestamp.
    pub fn instant(epoch: u16) -> SpanData {
        SpanData { end: SimTime::ZERO, txn: 0, epoch, a: 0 }
    }

    /// A record spanning from its timestamp to `end`.
    pub fn span(end: SimTime, epoch: u16) -> SpanData {
        SpanData { end, txn: 0, epoch, a: 0 }
    }

    pub fn txn(mut self, txn: u64) -> SpanData {
        self.txn = txn;
        self
    }

    pub fn arg(mut self, a: u64) -> SpanData {
        self.a = a;
        self
    }
}

#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub at: SimTime,
    pub rank: Rank,
    pub kind: TraceKind,
    pub data: SpanData,
}

impl TraceEvent {
    /// Span end (== `at` for instants).
    pub fn end(&self) -> SimTime {
        if self.data.end.as_ns() > self.at.as_ns() {
            self.data.end
        } else {
            self.at
        }
    }
}

/// Bounded trace recorder (keeps the most recent `cap` events).
#[derive(Debug)]
pub struct Trace {
    events: std::collections::VecDeque<TraceEvent>,
    cap: usize,
    enabled: bool,
}

impl Trace {
    pub fn new(cap: usize, enabled: bool) -> Trace {
        // the ring is sized here, once: at capacity a record recycles
        // the popped slot, so steady-state recording never allocates
        Trace { events: std::collections::VecDeque::with_capacity(cap), cap, enabled }
    }

    pub fn disabled() -> Trace {
        Trace::new(0, false)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn record(&mut self, at: SimTime, rank: Rank, kind: TraceKind, data: SpanData) {
        if !self.enabled {
            return;
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
        }
        self.events.push_back(TraceEvent { at, rank, kind, data });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    /// Events of one rank in time order.
    pub fn of_rank(&self, rank: Rank) -> Vec<&TraceEvent> {
        self.events.iter().filter(|e| e.rank == rank).collect()
    }

    /// Render an ASCII timeline: one row per rank, one column per time
    /// bucket, the last event glyph in each bucket.
    pub fn timeline(&self, p: usize, cols: usize) -> String {
        if self.events.is_empty() {
            return "(empty trace)".to_string();
        }
        let t0 = self.events.front().unwrap().at.as_ns();
        let t1 = self.events.back().unwrap().at.as_ns().max(t0 + 1);
        let bucket = ((t1 - t0) / cols as u64).max(1);
        let mut grid = vec![vec![' '; cols]; p];
        for e in &self.events {
            if e.rank < p {
                let col = (((e.at.as_ns() - t0) / bucket) as usize).min(cols - 1);
                grid[e.rank][col] = e.kind.glyph();
            }
        }
        let mut out = format!(
            "timeline {:.1}us..{:.1}us ({:.2}us/col)\n",
            t0 as f64 / 1e3,
            t1 as f64 / 1e3,
            bucket as f64 / 1e3
        );
        for (r, row) in grid.iter().enumerate() {
            out.push_str(&format!("r{r:<2}|{}|\n", row.iter().collect::<String>()));
        }
        out.push_str(
            "    C=call O=offload >=send <=recv a=ack R=result *=complete\n    \
             q=switch-queue h=hpu-queue x=exec +=combine T=timeout !=retx D=drop\n",
        );
        out
    }

    /// Raw event listing, newest-truncated to `limit` lines (0 = all).
    pub fn dump(&self, limit: usize) -> String {
        let total = self.events.len();
        let skip = if limit > 0 && total > limit { total - limit } else { 0 };
        let mut out = format!("{total} events (showing {})\n", total - skip);
        out.push_str("        at_ns       end_ns rank kind            txn epoch     a\n");
        for e in self.events.iter().skip(skip) {
            out.push_str(&format!(
                "{:>13} {:>12} {:>4} {:<13} {:>6} {:>5} {:>5}\n",
                e.at.as_ns(),
                e.end().as_ns(),
                e.rank,
                e.kind.name(),
                e.data.txn,
                e.data.epoch,
                e.data.a,
            ));
        }
        out
    }

    /// Ordering assertion helper: first index of each kind for a rank.
    pub fn first_of(&self, rank: Rank, kind: TraceKind) -> Option<SimTime> {
        self.events.iter().find(|e| e.rank == rank && e.kind == kind).map(|e| e.at)
    }

    /// Chrome-trace ("Trace Event Format") JSON, loadable in Perfetto
    /// or chrome://tracing.  One process per node (ranks then
    /// switches), three threads per process (host / nic / hpu), `X`
    /// duration events for spans, `i` instants, and `s`/`t`/`f` flow
    /// arrows stitching every record of one reliable transaction id —
    /// so a retransmitted frame reads as one arrow chain across drops.
    pub fn chrome_trace(&self, p: usize) -> Json {
        fn tid_of(kind: TraceKind) -> (i128, &'static str) {
            match kind {
                TraceKind::HostCall | TraceKind::HostComplete => (0, "host"),
                TraceKind::HpuQueue | TraceKind::HandlerExec | TraceKind::Combine => (2, "hpu"),
                _ => (1, "nic"),
            }
        }
        let mut events: Vec<Json> = Vec::new();
        // metadata: name every process/thread that has at least one event
        let mut seen: Vec<(Rank, [bool; 3])> = Vec::new();
        for e in &self.events {
            let (tid, _) = tid_of(e.kind);
            match seen.iter_mut().find(|(r, _)| *r == e.rank) {
                Some((_, tids)) => tids[tid as usize] = true,
                None => {
                    let mut tids = [false; 3];
                    tids[tid as usize] = true;
                    seen.push((e.rank, tids));
                }
            }
        }
        seen.sort_by_key(|(r, _)| *r);
        for (r, tids) in &seen {
            let pname =
                if *r < p { format!("rank {r}") } else { format!("switch {}", *r - p) };
            events.push(Json::Obj(vec![
                ("ph".into(), Json::str("M")),
                ("name".into(), Json::str("process_name")),
                ("pid".into(), Json::int(*r as u64)),
                ("args".into(), Json::Obj(vec![("name".into(), Json::str(pname))])),
            ]));
            for (tid, tname) in [(0usize, "host"), (1, "nic"), (2, "hpu")] {
                if tids[tid] {
                    events.push(Json::Obj(vec![
                        ("ph".into(), Json::str("M")),
                        ("name".into(), Json::str("thread_name")),
                        ("pid".into(), Json::int(*r as u64)),
                        ("tid".into(), Json::int(tid as u64)),
                        ("args".into(), Json::Obj(vec![("name".into(), Json::str(tname))])),
                    ]));
                }
            }
        }
        // flow endpoints: first and last record index per transaction
        let mut txn_span: Vec<(u64, usize, usize)> = Vec::new(); // (txn, first, last)
        for (i, e) in self.events.iter().enumerate() {
            if e.data.txn != 0 {
                match txn_span.iter_mut().find(|(t, _, _)| *t == e.data.txn) {
                    Some((_, _, last)) => *last = i,
                    None => txn_span.push((e.data.txn, i, i)),
                }
            }
        }
        for (i, e) in self.events.iter().enumerate() {
            let (tid, _) = tid_of(e.kind);
            let ts = e.at.as_ns() as f64 / 1000.0;
            let dur_ns = e.end() - e.at;
            let mut fields: Vec<(String, Json)> = vec![
                ("name".into(), Json::str(e.kind.name())),
                ("ph".into(), Json::str(if dur_ns > 0 { "X" } else { "i" })),
                ("ts".into(), Json::Num(ts)),
                ("pid".into(), Json::int(e.rank as u64)),
                ("tid".into(), Json::int(tid)),
            ];
            if dur_ns > 0 {
                fields.push(("dur".into(), Json::Num(dur_ns as f64 / 1000.0)));
            } else {
                fields.push(("s".into(), Json::str("t")));
            }
            fields.push((
                "args".into(),
                Json::Obj(vec![
                    ("epoch".into(), Json::int(e.data.epoch as u64)),
                    ("txn".into(), Json::int(e.data.txn)),
                    ("a".into(), Json::int(e.data.a)),
                ]),
            ));
            events.push(Json::Obj(fields));
            // flow arrow through this record's transaction
            if e.data.txn != 0 {
                let &(_, first, last) = txn_span
                    .iter()
                    .find(|(t, _, _)| *t == e.data.txn)
                    .expect("txn indexed above");
                if first != last {
                    let ph = if i == first {
                        "s"
                    } else if i == last {
                        "f"
                    } else {
                        "t"
                    };
                    let mut flow: Vec<(String, Json)> = vec![
                        ("name".into(), Json::str("txn")),
                        ("cat".into(), Json::str("txn")),
                        ("ph".into(), Json::str(ph)),
                        ("id".into(), Json::int(e.data.txn)),
                        ("ts".into(), Json::Num(ts)),
                        ("pid".into(), Json::int(e.rank as u64)),
                        ("tid".into(), Json::int(tid)),
                    ];
                    if ph == "f" {
                        flow.push(("bp".into(), Json::str("e")));
                    }
                    events.push(Json::Obj(flow));
                }
            }
        }
        Json::Obj(vec![
            ("displayTimeUnit".into(), Json::str("ns")),
            ("traceEvents".into(), Json::Arr(events)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::new(16, true);
        t.record(SimTime::us(1), 0, TraceKind::HostCall, SpanData::instant(0));
        t.record(SimTime::us(2), 0, TraceKind::Offload, SpanData::instant(0));
        t.record(SimTime::us(3), 1, TraceKind::NicRecvd, SpanData::instant(0).txn(7));
        t.record(SimTime::us(4), 0, TraceKind::HostComplete, SpanData::instant(0));
        t
    }

    #[test]
    fn records_in_order() {
        let t = sample();
        assert_eq!(t.len(), 4);
        assert_eq!(t.of_rank(0).len(), 3);
        assert!(t.first_of(0, TraceKind::HostCall) < t.first_of(0, TraceKind::HostComplete));
    }

    #[test]
    fn ring_buffer_caps() {
        let mut t = Trace::new(2, true);
        for i in 0..5 {
            t.record(SimTime::us(i), 0, TraceKind::NicSend, SpanData::instant(0));
        }
        assert_eq!(t.len(), 2);
        assert_eq!(t.iter().next().unwrap().at, SimTime::us(3));
    }

    #[test]
    fn disabled_records_nothing() {
        let mut t = Trace::disabled();
        t.record(SimTime::us(1), 0, TraceKind::HostCall, SpanData::instant(0));
        assert!(t.is_empty());
    }

    #[test]
    fn timeline_renders() {
        let t = sample();
        let s = t.timeline(2, 20);
        assert!(s.contains("r0 |"));
        assert!(s.contains('C'));
        assert!(s.contains('*'));
        assert_eq!(Trace::disabled().timeline(2, 10), "(empty trace)");
    }

    #[test]
    fn spans_know_their_duration() {
        let mut t = Trace::new(4, true);
        t.record(SimTime::ns(100), 0, TraceKind::NicSend, SpanData::span(SimTime::ns(600), 1));
        t.record(SimTime::ns(700), 0, TraceKind::NicAck, SpanData::instant(1));
        let evs: Vec<_> = t.iter().collect();
        assert_eq!(evs[0].end() - evs[0].at, 500);
        assert_eq!(evs[1].end(), evs[1].at);
    }

    #[test]
    fn dump_lists_and_truncates() {
        let t = sample();
        let all = t.dump(0);
        assert!(all.contains("host_call"));
        assert!(all.contains("host_complete"));
        let last2 = t.dump(2);
        assert!(!last2.contains("host_call"));
        assert!(last2.contains("host_complete"));
        assert!(last2.starts_with("4 events (showing 2)"));
    }

    #[test]
    fn chrome_trace_structure_and_flows() {
        let mut t = Trace::new(16, true);
        // one txn seen at three points: send, drop, retransmit
        t.record(SimTime::ns(0), 0, TraceKind::NicSend, SpanData::span(SimTime::ns(80), 0).txn(9));
        t.record(SimTime::ns(40), 1, TraceKind::Dropped, SpanData::instant(0).txn(9));
        t.record(SimTime::ns(500), 0, TraceKind::Retransmit, SpanData::instant(0).txn(9).arg(1));
        let doc = t.chrome_trace(2);
        assert_eq!(doc.get("displayTimeUnit").unwrap().as_str(), Some("ns"));
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let phs = |ph: &str| {
            evs.iter().filter(|e| e.get("ph").unwrap().as_str() == Some(ph)).count()
        };
        assert_eq!(phs("X"), 1, "one duration span");
        assert_eq!(phs("i"), 2, "two instants");
        assert_eq!(phs("s"), 1, "flow start");
        assert_eq!(phs("t"), 1, "flow step");
        assert_eq!(phs("f"), 1, "flow finish");
        // the export round-trips through our own parser byte-stably
        let text = doc.pretty();
        assert_eq!(Json::parse(&text).unwrap().pretty(), text);
    }
}
