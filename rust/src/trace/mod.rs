//! Event tracing: a ring buffer of simulation milestones and an ASCII
//! timeline renderer for debugging scan schedules.
//!
//! Used by `nfscan run --trace true` style debugging and by tests that
//! assert event ordering (e.g. "the ACK precedes the result delivery").

use crate::net::Rank;
use crate::sim::SimTime;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TraceKind {
    HostCall,
    Offload,
    NicSend,
    NicRecvd,
    NicAck,
    NicResult,
    HostComplete,
}

impl TraceKind {
    fn glyph(self) -> char {
        match self {
            TraceKind::HostCall => 'C',
            TraceKind::Offload => 'O',
            TraceKind::NicSend => '>',
            TraceKind::NicRecvd => '<',
            TraceKind::NicAck => 'a',
            TraceKind::NicResult => 'R',
            TraceKind::HostComplete => '*',
        }
    }
}

#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub at: SimTime,
    pub rank: Rank,
    pub kind: TraceKind,
    pub detail: String,
}

/// Bounded trace recorder (keeps the most recent `cap` events).
#[derive(Debug)]
pub struct Trace {
    events: std::collections::VecDeque<TraceEvent>,
    cap: usize,
    enabled: bool,
}

impl Trace {
    pub fn new(cap: usize, enabled: bool) -> Trace {
        Trace { events: std::collections::VecDeque::new(), cap, enabled }
    }

    pub fn disabled() -> Trace {
        Trace::new(0, false)
    }

    pub fn record(&mut self, at: SimTime, rank: Rank, kind: TraceKind, detail: impl Into<String>) {
        if !self.enabled {
            return;
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
        }
        self.events.push_back(TraceEvent { at, rank, kind, detail: detail.into() });
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    /// Events of one rank in time order.
    pub fn of_rank(&self, rank: Rank) -> Vec<&TraceEvent> {
        self.events.iter().filter(|e| e.rank == rank).collect()
    }

    /// Render an ASCII timeline: one row per rank, one column per time
    /// bucket, the last event glyph in each bucket.
    pub fn timeline(&self, p: usize, cols: usize) -> String {
        if self.events.is_empty() {
            return "(empty trace)".to_string();
        }
        let t0 = self.events.front().unwrap().at.as_ns();
        let t1 = self.events.back().unwrap().at.as_ns().max(t0 + 1);
        let bucket = ((t1 - t0) / cols as u64).max(1);
        let mut grid = vec![vec![' '; cols]; p];
        for e in &self.events {
            if e.rank < p {
                let col = (((e.at.as_ns() - t0) / bucket) as usize).min(cols - 1);
                grid[e.rank][col] = e.kind.glyph();
            }
        }
        let mut out = format!(
            "timeline {:.1}us..{:.1}us ({:.2}us/col)\n",
            t0 as f64 / 1e3,
            t1 as f64 / 1e3,
            bucket as f64 / 1e3
        );
        for (r, row) in grid.iter().enumerate() {
            out.push_str(&format!("r{r:<2}|{}|\n", row.iter().collect::<String>()));
        }
        out.push_str("    C=call O=offload >=send <=recv a=ack R=result *=complete\n");
        out
    }

    /// Ordering assertion helper: first index of each kind for a rank.
    pub fn first_of(&self, rank: Rank, kind: TraceKind) -> Option<SimTime> {
        self.events.iter().find(|e| e.rank == rank && e.kind == kind).map(|e| e.at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::new(16, true);
        t.record(SimTime::us(1), 0, TraceKind::HostCall, "call");
        t.record(SimTime::us(2), 0, TraceKind::Offload, "offload");
        t.record(SimTime::us(3), 1, TraceKind::NicRecvd, "data");
        t.record(SimTime::us(4), 0, TraceKind::HostComplete, "done");
        t
    }

    #[test]
    fn records_in_order() {
        let t = sample();
        assert_eq!(t.len(), 4);
        assert_eq!(t.of_rank(0).len(), 3);
        assert!(t.first_of(0, TraceKind::HostCall) < t.first_of(0, TraceKind::HostComplete));
    }

    #[test]
    fn ring_buffer_caps() {
        let mut t = Trace::new(2, true);
        for i in 0..5 {
            t.record(SimTime::us(i), 0, TraceKind::NicSend, "");
        }
        assert_eq!(t.len(), 2);
        assert_eq!(t.iter().next().unwrap().at, SimTime::us(3));
    }

    #[test]
    fn disabled_records_nothing() {
        let mut t = Trace::disabled();
        t.record(SimTime::us(1), 0, TraceKind::HostCall, "");
        assert!(t.is_empty());
    }

    #[test]
    fn timeline_renders() {
        let t = sample();
        let s = t.timeline(2, 20);
        assert!(s.contains("r0 |"));
        assert!(s.contains('C'));
        assert!(s.contains('*'));
        assert_eq!(Trace::disabled().timeline(2, 10), "(empty trace)");
    }
}
