//! The OSU-style benchmark harness and the figure generators.
//!
//! The paper's evaluation runs a modified OSU Micro-Benchmark: back-to-
//! back MPI_Scan calls per message size, reporting average (Fig. 4) and
//! minimum (Fig. 5) host-observed latency for five series — sw_seq,
//! sw_rd, NF_seq, NF_rd, NF_binomial — plus the NIC-timestamped
//! offload->release latency for the NF series (Figs. 6/7).  Each
//! `figN_table` regenerates one figure as an aligned table / CSV.

use std::rc::Rc;

use crate::config::ExpConfig;
use crate::metrics::{us, LatencyStats, RunMetrics, Table};
use crate::packet::AlgoType;
use crate::runtime::Compute;
use crate::util::fmt_bytes;

/// Message sizes of the sweep (bytes).  OSU's classic power-of-four
/// ladder, up to multi-fragment territory.
pub const OSU_SIZES: &[usize] = &[4, 16, 64, 256, 1024, 4096, 16384];

/// One line in a figure: (prefix, algorithm).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Series {
    pub algo: AlgoType,
    pub offloaded: bool,
}

impl Series {
    pub fn name(&self) -> String {
        let prefix = if self.offloaded { "NF" } else { "sw" };
        let a = match self.algo {
            AlgoType::Sequential => "seq",
            AlgoType::RecursiveDoubling => "rd",
            AlgoType::BinomialTree => "binomial",
        };
        format!("{prefix}_{a}")
    }

    /// Inverse of [`Series::name`] — how grid specs name their series
    /// axis (`series = ["sw_seq", "NF_rd"]`).
    pub fn from_name(s: &str) -> Option<Series> {
        let (prefix, algo) = s.split_once('_')?;
        let offloaded = match prefix {
            "NF" => true,
            "sw" => false,
            _ => return None,
        };
        let algo = match algo {
            "seq" => AlgoType::Sequential,
            "rd" => AlgoType::RecursiveDoubling,
            "binomial" => AlgoType::BinomialTree,
            _ => return None,
        };
        Some(Series { algo, offloaded })
    }
}

/// Fig. 4/5 series set.  The paper omits software binomial ("it produced
/// the worst performance"); we keep the measured set faithful and expose
/// the omitted series through `all_series` for the ablation benches.
pub fn paper_series() -> Vec<Series> {
    vec![
        Series { algo: AlgoType::Sequential, offloaded: false },
        Series { algo: AlgoType::RecursiveDoubling, offloaded: false },
        Series { algo: AlgoType::Sequential, offloaded: true },
        Series { algo: AlgoType::RecursiveDoubling, offloaded: true },
        Series { algo: AlgoType::BinomialTree, offloaded: true },
    ]
}

pub fn nf_series() -> Vec<Series> {
    paper_series().into_iter().filter(|s| s.offloaded).collect()
}

pub fn all_series() -> Vec<Series> {
    let mut v = paper_series();
    v.push(Series { algo: AlgoType::BinomialTree, offloaded: false });
    v
}

/// Run one (series, msg_size) cell and return its metrics.
pub fn run_cell(
    base: &ExpConfig,
    series: Series,
    msg_bytes: usize,
    compute: Rc<dyn Compute>,
) -> RunMetrics {
    let mut cfg = base.clone();
    cfg.algo = series.algo;
    cfg.offloaded = series.offloaded;
    cfg.msg_bytes = msg_bytes;
    cfg.topology = "auto".into();
    let mut cluster = crate::cluster::Cluster::new(cfg, compute);
    cluster.run().expect("benchmark run deadlocked")
}

/// A full sweep: per series, per size, (host latency, nic latency).
pub struct Sweep {
    pub series: Vec<Series>,
    pub sizes: Vec<usize>,
    /// `cells[series][size] = (host, nic)`.
    pub cells: Vec<Vec<(LatencyStats, LatencyStats)>>,
}

pub fn run_sweep(
    base: &ExpConfig,
    series: &[Series],
    sizes: &[usize],
    compute: Rc<dyn Compute>,
) -> Sweep {
    let mut cells = Vec::with_capacity(series.len());
    for s in series {
        let mut row = Vec::with_capacity(sizes.len());
        for &size in sizes {
            let m = run_cell(base, *s, size, compute.clone());
            row.push((m.host_overall(), m.nic_overall()));
        }
        cells.push(row);
    }
    Sweep { series: series.to_vec(), sizes: sizes.to_vec(), cells }
}

impl Sweep {
    /// Render one figure: rows = message sizes, columns = series.
    /// `metric` selects avg/min of host/NIC latency.
    pub fn table(&self, metric: Metric) -> Table {
        let mut headers = vec!["msg_size".to_string()];
        headers.extend(self.series.iter().map(|s| format!("{}_us", s.name())));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&hdr_refs);
        for (i, &size) in self.sizes.iter().enumerate() {
            let mut row = vec![fmt_bytes(size)];
            for (j, _) in self.series.iter().enumerate() {
                let (host, nic) = &self.cells[j][i];
                let v = match metric {
                    Metric::HostAvg => host.avg_us(),
                    Metric::HostMin => host.min_us(),
                    Metric::NicAvg => nic.avg_us(),
                    Metric::NicMin => nic.min_us(),
                };
                row.push(us(v));
            }
            t.row(row);
        }
        t
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Metric {
    HostAvg,
    HostMin,
    NicAvg,
    NicMin,
}

/// Fig. 4: average end-to-end MPI_Scan latency, five series.
pub fn fig4_table(base: &ExpConfig, compute: Rc<dyn Compute>, sizes: &[usize]) -> Table {
    run_sweep(base, &paper_series(), sizes, compute).table(Metric::HostAvg)
}

/// Fig. 5: minimum end-to-end latency, five series.
pub fn fig5_table(base: &ExpConfig, compute: Rc<dyn Compute>, sizes: &[usize]) -> Table {
    run_sweep(base, &paper_series(), sizes, compute).table(Metric::HostMin)
}

/// Fig. 6: average on-NIC (offload->release) latency, NF series.
pub fn fig6_table(base: &ExpConfig, compute: Rc<dyn Compute>, sizes: &[usize]) -> Table {
    run_sweep(base, &nf_series(), sizes, compute).table(Metric::NicAvg)
}

/// Fig. 7: minimum on-NIC latency, NF series.
pub fn fig7_table(base: &ExpConfig, compute: Rc<dyn Compute>, sizes: &[usize]) -> Table {
    run_sweep(base, &nf_series(), sizes, compute).table(Metric::NicMin)
}

/// Default experiment base for figure regeneration (paper's setup:
/// 8 nodes, MPI_INT + MPI_SUM, 10M iterations scaled down).
pub fn figure_base(iters: usize) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.p = 8;
    cfg.iters = iters;
    cfg.warmup = 32;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::runtime::make_engine;

    fn quick_base() -> ExpConfig {
        let mut cfg = figure_base(40);
        cfg.warmup = 8;
        cfg
    }

    #[test]
    fn fig4_shape_holds() {
        let compute = make_engine(EngineKind::Native, "artifacts");
        let sizes = [4usize, 1024];
        let sweep = run_sweep(&quick_base(), &paper_series(), &sizes, compute);
        // columns: 0 sw_seq, 1 sw_rd, 2 NF_seq, 3 NF_rd, 4 NF_binomial
        for (i, _) in sizes.iter().enumerate() {
            let avg = |j: usize| sweep.cells[j][i].0.avg_ns();
            assert!(avg(0) < avg(1), "sw_seq lowest avg (paper Fig. 4)");
            assert!(avg(3) < avg(1), "NF_rd beats sw_rd (offload win)");
        }
    }

    #[test]
    fn fig6_nic_latency_far_below_end_to_end() {
        let compute = make_engine(EngineKind::Native, "artifacts");
        let sizes = [64usize];
        let sweep = run_sweep(&quick_base(), &nf_series(), &sizes, compute);
        for (j, s) in sweep.series.iter().enumerate() {
            let (host, nic) = &sweep.cells[j][0];
            assert!(
                nic.avg_ns() * 2.0 < host.avg_ns(),
                "{}: on-NIC {} must sit far below end-to-end {}",
                s.name(),
                nic.avg_ns(),
                host.avg_ns()
            );
        }
    }

    #[test]
    fn tables_render() {
        let compute = make_engine(EngineKind::Native, "artifacts");
        let t = fig4_table(&quick_base(), compute, &[4]);
        let s = t.render();
        assert!(s.contains("sw_seq_us"));
        assert!(s.contains("NF_binomial_us"));
        assert!(s.contains("4B"));
    }

    #[test]
    fn series_names_match_paper() {
        let names: Vec<String> = paper_series().iter().map(|s| s.name()).collect();
        assert_eq!(names, vec!["sw_seq", "sw_rd", "NF_seq", "NF_rd", "NF_binomial"]);
    }

    #[test]
    fn series_name_round_trips() {
        for s in all_series() {
            assert_eq!(Series::from_name(&s.name()), Some(s));
        }
        assert_eq!(Series::from_name("hw_rd"), None);
        assert_eq!(Series::from_name("NF_bogus"), None);
        assert_eq!(Series::from_name("seq"), None);
    }
}
