//! The OSU-style benchmark harness and the figure generators.
//!
//! The paper's evaluation runs a modified OSU Micro-Benchmark: back-to-
//! back MPI_Scan calls per message size, reporting average (Fig. 4) and
//! minimum (Fig. 5) host-observed latency for five series — sw_seq,
//! sw_rd, NF_seq, NF_rd, NF_binomial — plus the NIC-timestamped
//! offload->release latency for the NF series (Figs. 6/7).  Each
//! `figN_table` regenerates one figure as an aligned table / CSV.

pub mod micro;

use std::rc::Rc;

use crate::config::{ExecPath, ExpConfig};
use crate::metrics::{us, LatencyStats, RunMetrics, Table};
use crate::packet::{AlgoType, CollType};
use crate::runtime::Compute;
use crate::util::fmt_bytes;

/// Message sizes of the sweep (bytes).  OSU's classic power-of-four
/// ladder, up to multi-fragment territory.
pub const OSU_SIZES: &[usize] = &[4, 16, 64, 256, 1024, 4096, 16384];

/// Which datapath a series measures.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SeriesPath {
    /// Host software MPI over the kernel stack (`sw_*`).
    Sw,
    /// Fixed-function NetFPGA state machines (`NF_*`).
    Offload,
    /// sPIN-style handler-VM programs (`handler[:coll]`).
    Handler,
}

/// One line in a figure: datapath x algorithm, plus (for handler
/// series) an optionally pinned collective.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Series {
    pub algo: AlgoType,
    pub path: SeriesPath,
    /// `handler:<coll>` series pin the collective; None = base config's.
    pub coll: Option<CollType>,
}

impl Series {
    pub fn sw(algo: AlgoType) -> Series {
        Series { algo, path: SeriesPath::Sw, coll: None }
    }

    pub fn nf(algo: AlgoType) -> Series {
        Series { algo, path: SeriesPath::Offload, coll: None }
    }

    /// Handler-VM series; programs pick their own algorithm, so the
    /// `algo` field only steers "auto" topology resolution.
    pub fn handler(coll: Option<CollType>) -> Series {
        Series { algo: AlgoType::RecursiveDoubling, path: SeriesPath::Handler, coll }
    }

    /// The series a bare config runs as (the default grid axis).
    /// Handler configs pin their collective so the artifact label
    /// round-trips with `ExpConfig::series_name` ("handler:exscan").
    pub fn of_config(cfg: &ExpConfig) -> Series {
        let path = match cfg.path {
            ExecPath::Handler => SeriesPath::Handler,
            ExecPath::Fpga => SeriesPath::Offload,
            ExecPath::Sw => SeriesPath::Sw,
        };
        let coll = if cfg.handler() { Some(cfg.coll) } else { None };
        Series { algo: cfg.algo, path, coll }
    }

    pub fn offloaded(&self) -> bool {
        self.path != SeriesPath::Sw
    }

    /// Overwrite the config fields this series pins.
    pub fn apply(&self, cfg: &mut ExpConfig) {
        cfg.algo = self.algo;
        cfg.path = match self.path {
            SeriesPath::Sw => ExecPath::Sw,
            SeriesPath::Offload => ExecPath::Fpga,
            SeriesPath::Handler => ExecPath::Handler,
        };
        if let Some(coll) = self.coll {
            cfg.coll = coll;
        }
    }

    pub fn name(&self) -> String {
        if self.path == SeriesPath::Handler {
            return match self.coll {
                Some(c) => format!("handler:{}", c.name()),
                None => "handler".to_string(),
            };
        }
        let prefix = if self.offloaded() { "NF" } else { "sw" };
        let a = match self.algo {
            AlgoType::Sequential => "seq",
            AlgoType::RecursiveDoubling => "rd",
            AlgoType::BinomialTree => "binomial",
        };
        format!("{prefix}_{a}")
    }

    /// Inverse of [`Series::name`] — how grid specs name their series
    /// axis (`series = ["sw_seq", "NF_rd", "handler:exscan"]`).
    pub fn from_name(s: &str) -> Option<Series> {
        if s == "handler" {
            return Some(Series::handler(None));
        }
        if let Some(coll) = s.strip_prefix("handler:") {
            let coll = CollType::from_name(coll).filter(|c| *c != CollType::Reduce)?;
            return Some(Series::handler(Some(coll)));
        }
        let (prefix, algo) = s.split_once('_')?;
        let path = match prefix {
            "NF" => SeriesPath::Offload,
            "sw" => SeriesPath::Sw,
            _ => return None,
        };
        let algo = match algo {
            "seq" => AlgoType::Sequential,
            "rd" => AlgoType::RecursiveDoubling,
            "binomial" => AlgoType::BinomialTree,
            _ => return None,
        };
        Some(Series { algo, path, coll: None })
    }

    /// Expand one series-axis token: the bare `"handler"` token fans out
    /// to all five handler collectives (the sweepable "which collective
    /// is offloaded" axis); every other token is a single series.
    pub fn expand_name(s: &str) -> Option<Vec<Series>> {
        if s == "handler" {
            return Some(handler_series());
        }
        Series::from_name(s).map(|one| vec![one])
    }

    /// Expand a whole series axis (grid list or comma-split CLI value);
    /// the error names the offending token.  Shared by `sweep::grid` and
    /// the `--series` override so the vocabulary can't drift.
    pub fn expand_list<S: AsRef<str>>(tokens: &[S]) -> Result<Vec<Series>, String> {
        let mut v = Vec::new();
        for tok in tokens {
            let tok = tok.as_ref().trim();
            v.extend(Series::expand_name(tok).ok_or_else(|| {
                format!("series {tok:?}: unknown ((sw|NF)_(seq|rd|binomial) or handler[:coll])")
            })?);
        }
        Ok(v)
    }
}

/// Fig. 4/5 series set.  The paper omits software binomial ("it produced
/// the worst performance"); we keep the measured set faithful and expose
/// the omitted series through `all_series` for the ablation benches.
pub fn paper_series() -> Vec<Series> {
    vec![
        Series::sw(AlgoType::Sequential),
        Series::sw(AlgoType::RecursiveDoubling),
        Series::nf(AlgoType::Sequential),
        Series::nf(AlgoType::RecursiveDoubling),
        Series::nf(AlgoType::BinomialTree),
    ]
}

pub fn nf_series() -> Vec<Series> {
    paper_series().into_iter().filter(|s| s.offloaded()).collect()
}

pub fn all_series() -> Vec<Series> {
    let mut v = paper_series();
    v.push(Series::sw(AlgoType::BinomialTree));
    v
}

/// One handler series per VM collective — what the bare `"handler"`
/// series token expands to.
pub fn handler_series() -> Vec<Series> {
    CollType::HANDLER_SET.iter().map(|&c| Series::handler(Some(c))).collect()
}

/// Run one (series, msg_size) cell and return its metrics.
pub fn run_cell(
    base: &ExpConfig,
    series: Series,
    msg_bytes: usize,
    compute: Rc<dyn Compute>,
) -> RunMetrics {
    let mut cfg = base.clone();
    series.apply(&mut cfg);
    cfg.msg_bytes = msg_bytes;
    cfg.topology = "auto".into();
    let mut cluster = crate::cluster::Cluster::new(cfg, compute);
    cluster.run().expect("benchmark run deadlocked")
}

/// A full sweep: per series, per size, (host latency, nic latency).
pub struct Sweep {
    pub series: Vec<Series>,
    pub sizes: Vec<usize>,
    /// `cells[series][size] = (host, nic)`.
    pub cells: Vec<Vec<(LatencyStats, LatencyStats)>>,
}

pub fn run_sweep(
    base: &ExpConfig,
    series: &[Series],
    sizes: &[usize],
    compute: Rc<dyn Compute>,
) -> Sweep {
    let mut cells = Vec::with_capacity(series.len());
    for s in series {
        let mut row = Vec::with_capacity(sizes.len());
        for &size in sizes {
            let m = run_cell(base, *s, size, compute.clone());
            row.push((m.host_overall(), m.nic_overall()));
        }
        cells.push(row);
    }
    Sweep { series: series.to_vec(), sizes: sizes.to_vec(), cells }
}

impl Sweep {
    /// Render one figure: rows = message sizes, columns = series.
    /// `metric` selects avg/min of host/NIC latency.
    pub fn table(&self, metric: Metric) -> Table {
        let mut headers = vec!["msg_size".to_string()];
        headers.extend(self.series.iter().map(|s| format!("{}_us", s.name())));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&hdr_refs);
        for (i, &size) in self.sizes.iter().enumerate() {
            let mut row = vec![fmt_bytes(size)];
            for (j, _) in self.series.iter().enumerate() {
                let (host, nic) = &self.cells[j][i];
                let v = match metric {
                    Metric::HostAvg => host.avg_us(),
                    Metric::HostMin => host.min_us(),
                    Metric::NicAvg => nic.avg_us(),
                    Metric::NicMin => nic.min_us(),
                };
                row.push(us(v));
            }
            t.row(row);
        }
        t
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Metric {
    HostAvg,
    HostMin,
    NicAvg,
    NicMin,
}

/// Fig. 4: average end-to-end MPI_Scan latency, five series.
pub fn fig4_table(base: &ExpConfig, compute: Rc<dyn Compute>, sizes: &[usize]) -> Table {
    run_sweep(base, &paper_series(), sizes, compute).table(Metric::HostAvg)
}

/// Fig. 5: minimum end-to-end latency, five series.
pub fn fig5_table(base: &ExpConfig, compute: Rc<dyn Compute>, sizes: &[usize]) -> Table {
    run_sweep(base, &paper_series(), sizes, compute).table(Metric::HostMin)
}

/// Fig. 6: average on-NIC (offload->release) latency, NF series.
pub fn fig6_table(base: &ExpConfig, compute: Rc<dyn Compute>, sizes: &[usize]) -> Table {
    run_sweep(base, &nf_series(), sizes, compute).table(Metric::NicAvg)
}

/// Fig. 7: minimum on-NIC latency, NF series.
pub fn fig7_table(base: &ExpConfig, compute: Rc<dyn Compute>, sizes: &[usize]) -> Table {
    run_sweep(base, &nf_series(), sizes, compute).table(Metric::NicMin)
}

/// Default experiment base for figure regeneration (paper's setup:
/// 8 nodes, MPI_INT + MPI_SUM, 10M iterations scaled down).
pub fn figure_base(iters: usize) -> ExpConfig {
    let mut cfg = ExpConfig::default();
    cfg.p = 8;
    cfg.iters = iters;
    cfg.warmup = 32;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineKind;
    use crate::runtime::make_engine;

    fn quick_base() -> ExpConfig {
        let mut cfg = figure_base(40);
        cfg.warmup = 8;
        cfg
    }

    #[test]
    fn fig4_shape_holds() {
        let compute = make_engine(EngineKind::Native, "artifacts");
        let sizes = [4usize, 1024];
        let sweep = run_sweep(&quick_base(), &paper_series(), &sizes, compute);
        // columns: 0 sw_seq, 1 sw_rd, 2 NF_seq, 3 NF_rd, 4 NF_binomial
        for (i, _) in sizes.iter().enumerate() {
            let avg = |j: usize| sweep.cells[j][i].0.avg_ns();
            assert!(avg(0) < avg(1), "sw_seq lowest avg (paper Fig. 4)");
            assert!(avg(3) < avg(1), "NF_rd beats sw_rd (offload win)");
        }
    }

    #[test]
    fn fig6_nic_latency_far_below_end_to_end() {
        let compute = make_engine(EngineKind::Native, "artifacts");
        let sizes = [64usize];
        let sweep = run_sweep(&quick_base(), &nf_series(), &sizes, compute);
        for (j, s) in sweep.series.iter().enumerate() {
            let (host, nic) = &sweep.cells[j][0];
            assert!(
                nic.avg_ns() * 2.0 < host.avg_ns(),
                "{}: on-NIC {} must sit far below end-to-end {}",
                s.name(),
                nic.avg_ns(),
                host.avg_ns()
            );
        }
    }

    #[test]
    fn tables_render() {
        let compute = make_engine(EngineKind::Native, "artifacts");
        let t = fig4_table(&quick_base(), compute, &[4]);
        let s = t.render();
        assert!(s.contains("sw_seq_us"));
        assert!(s.contains("NF_binomial_us"));
        assert!(s.contains("4B"));
    }

    #[test]
    fn series_names_match_paper() {
        let names: Vec<String> = paper_series().iter().map(|s| s.name()).collect();
        assert_eq!(names, vec!["sw_seq", "sw_rd", "NF_seq", "NF_rd", "NF_binomial"]);
    }

    #[test]
    fn series_name_round_trips() {
        for s in all_series().into_iter().chain(handler_series()) {
            assert_eq!(Series::from_name(&s.name()), Some(s));
        }
        assert_eq!(Series::from_name("hw_rd"), None);
        assert_eq!(Series::from_name("NF_bogus"), None);
        assert_eq!(Series::from_name("seq"), None);
        assert_eq!(Series::from_name("handler:reduce"), None);
        assert_eq!(Series::from_name("handler:warp"), None);
    }

    #[test]
    fn handler_token_expands_to_all_five_collectives() {
        let all = Series::expand_name("handler").unwrap();
        let names: Vec<String> = all.iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            vec![
                "handler:scan",
                "handler:exscan",
                "handler:allreduce",
                "handler:bcast",
                "handler:barrier"
            ]
        );
        assert_eq!(Series::expand_name("NF_rd").unwrap().len(), 1);
        assert_eq!(Series::expand_name("warp"), None);
    }

    #[test]
    fn series_apply_pins_the_path_and_collective() {
        let mut cfg = ExpConfig::default();
        Series::from_name("handler:exscan").unwrap().apply(&mut cfg);
        assert!(cfg.handler() && cfg.offloaded());
        assert_eq!(cfg.coll, CollType::Exscan);
        cfg.validate().unwrap();
        Series::from_name("sw_seq").unwrap().apply(&mut cfg);
        assert!(!cfg.handler() && !cfg.offloaded());
        assert_eq!(cfg.coll, CollType::Exscan, "non-handler series keep the collective");
    }
}
