//! Hot-datapath microbenchmarks behind `nfscan bench` — the perf
//! trajectory's data source.
//!
//! Each entry measures one steady-state hot-path operation in host
//! wallclock ns/op plus allocations/op (when the counting allocator is
//! installed — the `nfscan` binary installs it).  `nfscan bench --json
//! --out BENCH_N.json` emits the machine-readable trajectory point CI
//! uploads; `nfscan benchdiff` compares two points and warns on >10%
//! ns/op regressions (advisory).
//!
//! Measured entries:
//! - `combine_into_*` — steady-state in-place combine on a uniquely-owned
//!   accumulator (the tentpole's zero-alloc claim);
//! - `combine_alloc_*` — the allocating `combine` path, kept as the
//!   in-repo baseline the speedup is measured against;
//! - `fold_k64_*` — a 64-way `oracle_prefix` fold (verify-path shape);
//! - `reassembly_16k` — streaming reassembly of a 16 KB message from MTU
//!   fragments;
//! - `handler_dispatch` — one handler-VM `on_host_request` activation
//!   (engine construction included, as the cluster pays it per epoch);
//! - `event_queue_hold256` — calendar-queue hold-model pop+push;
//! - `fault_gate_loss0` — the per-hop fault-plan gate a loss-free run
//!   pays (one `lossy()` + `degrades()` check on a quiet plan; the
//!   hostile-network tentpole's ~zero-overhead claim);
//! - `crash_gate_quiet` — the per-event fail-stop gate a crash-free run
//!   pays (one `has_crashes()` + `rank_crash_epoch()` check on a quiet
//!   plan; the fault-tolerance stack's ~zero-overhead claim).

use std::time::Instant;

use crate::config::CostModel;
use crate::data::{Op, Payload};
use crate::fpga::engine::{CollEngine as _, EngineCtx};
use crate::fpga::reassembly::Reassembler;
use crate::metrics::json::Json;
use crate::metrics::Table;
use crate::net::frame::fragment;
use crate::net::FaultPlan;
use crate::runtime::{engine::oracle_prefix, Compute, NativeEngine};
use crate::sim::{EventKind, EventQueue, SimTime, SplitMix64};
use crate::util::alloc as cnt;

/// One measured entry of the trajectory point.
pub struct BenchResult {
    pub name: &'static str,
    pub ns_per_op: f64,
    /// None when the counting allocator is not installed.
    pub allocs_per_op: Option<f64>,
}

/// Time `op` over `reps` iterations (after `warmup`), returning
/// (ns/op, allocs/op).
fn measure(
    warmup: usize,
    reps: usize,
    counting: bool,
    mut op: impl FnMut(),
) -> (f64, Option<f64>) {
    for _ in 0..warmup {
        op();
    }
    let a0 = cnt::allocation_count();
    let t0 = Instant::now();
    for _ in 0..reps {
        op();
    }
    let ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    let allocs = (cnt::allocation_count() - a0) as f64 / reps as f64;
    (ns, counting.then_some(allocs))
}

fn payload_i32(n: usize, salt: i32) -> Payload {
    Payload::from_i32(&(0..n as i32).map(|v| (v + salt) % 17 - 8).collect::<Vec<_>>())
}

fn bench_combine_into(n: usize, reps: usize, counting: bool) -> (f64, Option<f64>) {
    let e = NativeEngine::new();
    let mut acc = payload_i32(n, 1);
    let b = payload_i32(n, 5);
    measure(64, reps, counting, || {
        e.combine_into(&mut acc, &b, Op::Sum).unwrap();
        std::hint::black_box(&acc);
    })
}

fn bench_combine_alloc(n: usize, reps: usize, counting: bool) -> (f64, Option<f64>) {
    let e = NativeEngine::new();
    let mut acc = payload_i32(n, 1);
    let b = payload_i32(n, 5);
    measure(64, reps, counting, || {
        acc = e.combine(&acc, &b, Op::Sum).unwrap();
        std::hint::black_box(&acc);
    })
}

fn bench_fold_k64(n: usize, reps: usize, counting: bool) -> (f64, Option<f64>) {
    let e = NativeEngine::new();
    let contribs: Vec<Payload> = (0..64).map(|k| payload_i32(n, k)).collect();
    measure(8, reps, counting, || {
        let acc = oracle_prefix(&e, &contribs, Op::Sum, true, 63).unwrap();
        std::hint::black_box(&acc);
    })
}

fn bench_reassembly_16k(reps: usize, counting: bool) -> (f64, Option<f64>) {
    let msg = payload_i32(4096, 3); // 16 KB -> 12 MTU fragments
    let frags = fragment(&msg);
    let count = msg.len() as u32;
    let mut r: Reassembler<u32> = Reassembler::new(32);
    measure(16, reps, counting, || {
        let mut whole = None;
        for (idx, total, _off, chunk) in &frags {
            whole = r.add(1, *idx, *total, count, chunk.clone());
        }
        std::hint::black_box(whole.expect("message completes each rep"));
    })
}

fn bench_handler_dispatch(reps: usize, counting: bool) -> (f64, Option<f64>) {
    use crate::packet::{AlgoType, CollType};
    let compute = NativeEngine::new();
    let cost = CostModel::default();
    let req = crate::sim::OffloadRequest {
        rank: 0,
        comm: 0,
        epoch: 0,
        comm_size: 2,
        coll: CollType::Allreduce,
        algo: AlgoType::RecursiveDoubling,
        op: Op::Sum,
        dtype: crate::data::Dtype::I32,
        payload: payload_i32(16, 0),
    };
    measure(64, reps, counting, || {
        let mut engine = crate::nic::handler_engine(CollType::Allreduce);
        let mut ctx = EngineCtx {
            rank: 0,
            p: 2,
            inclusive: false,
            op: Op::Sum,
            coll: CollType::Allreduce,
            epoch: 0,
            compute: &compute,
            cost: &cost,
            cycles: 0,
            combine_cycles: 0,
            instrs: 0,
            stalls: 0,
        };
        let actions = engine.on_host_request(&mut ctx, &req);
        std::hint::black_box(&actions);
    })
}

fn bench_event_queue(reps: usize, counting: bool) -> (f64, Option<f64>) {
    const DELTAS: &[u64] = &[120, 500, 992, 2_000, 28_000, 120_000];
    let mut q = EventQueue::with_calendar();
    let mut rng = SplitMix64::new(0xBE9C4);
    for i in 0..256 {
        q.push(SimTime::ns(rng.next_below(100_000)), EventKind::HostStart { rank: i });
    }
    measure(1024, reps, counting, || {
        let (now, kind) = q.pop().expect("hold model never drains");
        let delta = DELTAS[rng.next_below(DELTAS.len() as u64) as usize];
        q.push(now + delta, kind);
    })
}

fn bench_fault_gate(reps: usize, counting: bool) -> (f64, Option<f64>) {
    // the per-hop cost a loss-free run pays for the fault layer: the
    // lossy()/degrades() gate transmit pays before skipping the fault
    // path entirely.  Expected ~0 ns/op and exactly 0 allocs/op.
    let plan = FaultPlan::quiet(0xF00D);
    measure(1024, reps, counting, || {
        let p = std::hint::black_box(&plan);
        std::hint::black_box(p.lossy() || p.degrades());
    })
}

fn bench_crash_gate(reps: usize, counting: bool) -> (f64, Option<f64>) {
    // the per-event cost a crash-free run pays for the fail-stop layer:
    // the has_crashes()/rank_crash_epoch() gate host-start and nic-recv
    // pay before skipping liveness bookkeeping entirely.  Expected ~0
    // ns/op and exactly 0 allocs/op.
    let plan = FaultPlan::quiet(0xF00D);
    measure(1024, reps, counting, || {
        let p = std::hint::black_box(&plan);
        std::hint::black_box(p.has_crashes() || p.rank_crash_epoch(3).is_some());
    })
}

/// Run the whole suite.  `quick` shrinks rep counts (CI smoke / tests).
pub fn run_all(quick: bool) -> Vec<BenchResult> {
    let counting = cnt::counting_installed();
    let r = |full: usize, quick_reps: usize| if quick { quick_reps } else { full };
    let mut out = Vec::new();
    let mut push = |name: &'static str, (ns, allocs): (f64, Option<f64>)| {
        out.push(BenchResult { name, ns_per_op: ns, allocs_per_op: allocs });
    };
    push("combine_into_256b", bench_combine_into(64, r(200_000, 2_000), counting));
    push("combine_into_4k", bench_combine_into(1024, r(100_000, 1_000), counting));
    push("combine_alloc_4k", bench_combine_alloc(1024, r(100_000, 1_000), counting));
    push("fold_k64_4k", bench_fold_k64(1024, r(2_000, 50), counting));
    push("reassembly_16k", bench_reassembly_16k(r(20_000, 200), counting));
    push("handler_dispatch", bench_handler_dispatch(r(100_000, 1_000), counting));
    push("event_queue_hold256", bench_event_queue(r(400_000, 4_000), counting));
    push("fault_gate_loss0", bench_fault_gate(r(400_000, 4_000), counting));
    push("crash_gate_quiet", bench_crash_gate(r(400_000, 4_000), counting));
    out
}

/// Render the suite as an aligned table.
pub fn table(results: &[BenchResult]) -> Table {
    let mut t = Table::new(&["bench", "ns_per_op", "allocs_per_op"]);
    for r in results {
        t.row(vec![
            r.name.to_string(),
            format!("{:.1}", r.ns_per_op),
            match r.allocs_per_op {
                Some(a) => format!("{a:.3}"),
                None => "n/a".to_string(),
            },
        ]);
    }
    t
}

/// Machine-readable trajectory point (`BENCH_N.json` schema).
pub fn to_json(results: &[BenchResult]) -> Json {
    let entries: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::Obj(vec![
                ("name".into(), Json::str(r.name)),
                ("ns_per_op".into(), Json::Num(r.ns_per_op)),
                (
                    "allocs_per_op".into(),
                    match r.allocs_per_op {
                        Some(a) => Json::Num(a),
                        None => Json::Null,
                    },
                ),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::str("nfscan-bench/1")),
        ("alloc_counting".into(), Json::Bool(results.iter().any(|r| r.allocs_per_op.is_some()))),
        ("entries".into(), Json::Arr(entries)),
    ])
}

/// Compare two trajectory points; returns (report lines, regression
/// count).  A regression = ns/op more than `threshold` above the
/// previous point (default callers use 0.10 = +10%).
pub fn compare(prev: &Json, cur: &Json, threshold: f64) -> (Vec<String>, usize) {
    let mut lines = Vec::new();
    let mut regressions = 0;
    let empty: &[Json] = &[];
    let prev_entries = prev.get("entries").and_then(|e| e.as_arr()).unwrap_or(empty);
    let cur_entries = cur.get("entries").and_then(|e| e.as_arr()).unwrap_or(empty);
    for e in cur_entries {
        let Some(name) = e.get("name").and_then(|n| n.as_str()) else { continue };
        let Some(cur_ns) = e.get("ns_per_op").and_then(|v| v.as_f64()) else { continue };
        let old = prev_entries
            .iter()
            .find(|p| p.get("name").and_then(|n| n.as_str()) == Some(name))
            .and_then(|p| p.get("ns_per_op").and_then(|v| v.as_f64()));
        match old {
            Some(old_ns) if old_ns > 0.0 => {
                let ratio = cur_ns / old_ns;
                let verdict = if ratio > 1.0 + threshold {
                    regressions += 1;
                    "REGRESSION"
                } else if ratio < 1.0 - threshold {
                    "improved"
                } else {
                    "ok"
                };
                lines.push(format!(
                    "{name}: {old_ns:.1} -> {cur_ns:.1} ns/op ({ratio:.2}x) {verdict}"
                ));
            }
            _ => lines.push(format!("{name}: {cur_ns:.1} ns/op (new entry, no baseline)")),
        }
    }
    // a bench that existed in the baseline but not in the current point is
    // shrinking coverage — say so instead of silently dropping its history
    for p in prev_entries {
        let Some(name) = p.get("name").and_then(|n| n.as_str()) else { continue };
        let in_cur =
            cur_entries.iter().any(|e| e.get("name").and_then(|n| n.as_str()) == Some(name));
        if !in_cur {
            lines.push(format!("{name}: MISSING from current point (was in baseline)"));
        }
    }
    (lines, regressions)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_suite_runs_and_serializes() {
        let results = run_all(true);
        assert_eq!(results.len(), 9);
        assert!(results.iter().all(|r| r.ns_per_op > 0.0));
        let doc = to_json(&results);
        let parsed = Json::parse(&doc.pretty()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("nfscan-bench/1"));
        assert_eq!(parsed.get("entries").unwrap().as_arr().unwrap().len(), 9);
        // lib tests install the counting allocator: allocs must be
        // *counted* (the zero-alloc value assertion lives in
        // tests/alloc_free.rs, whose binary has no concurrent tests
        // polluting the process-global counters)
        let combine = &results[1];
        assert_eq!(combine.name, "combine_into_4k");
        assert!(combine.allocs_per_op.is_some(), "counting installed in lib tests");
    }

    #[test]
    fn compare_flags_regressions_and_improvements() {
        let mk = |ns: f64| {
            Json::Obj(vec![(
                "entries".into(),
                Json::Arr(vec![Json::Obj(vec![
                    ("name".into(), Json::str("x")),
                    ("ns_per_op".into(), Json::Num(ns)),
                ])]),
            )])
        };
        let (lines, n) = compare(&mk(100.0), &mk(125.0), 0.10);
        assert_eq!(n, 1, "{lines:?}");
        assert!(lines[0].contains("REGRESSION"));
        let (lines, n) = compare(&mk(100.0), &mk(80.0), 0.10);
        assert_eq!(n, 0);
        assert!(lines[0].contains("improved"));
        let (lines, n) = compare(&mk(100.0), &mk(105.0), 0.10);
        assert_eq!(n, 0);
        assert!(lines[0].contains("ok"));
        // a baseline entry absent from the current point is called out
        let empty = Json::Obj(vec![("entries".into(), Json::Arr(vec![]))]);
        let (lines, n) = compare(&mk(100.0), &empty, 0.10);
        assert_eq!(n, 0);
        assert!(lines.iter().any(|l| l.contains("MISSING")), "{lines:?}");
    }
}
