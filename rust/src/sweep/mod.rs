//! The parallel experiment sweep engine behind `nfscan sweep`.
//!
//! The paper's results (Figs. 4-7) are grids — message sizes x process
//! counts x sw/NF paths — and this module turns such a grid into one
//! batch job: [`grid`] expands a TOML spec (or the built-in `figs` grid)
//! into an ordered list of `ExpConfig` jobs with derived seeds,
//! [`runner`] executes them on N worker threads (engine per thread; the
//! compute handle is `!Send`), and [`report`] merges the per-job
//! `RunMetrics` into deterministic JSON artifacts whose bytes do not
//! depend on `--jobs`.

pub mod grid;
pub mod report;
pub mod runner;

pub use grid::{derive_seed, GridSpec, Job, FIGS_GRID};
pub use report::{JobResult, SweepReport, FIGURES};
pub use runner::run_grid;
