//! The parallel sweep runner: N worker threads pull jobs off a shared
//! cursor and run independent `Cluster` simulations.
//!
//! Two properties make `--jobs` invisible in the output:
//!
//! - every job's `ExpConfig` (seed included) is fixed at expansion time,
//!   so a simulation result depends only on the job, never on which
//!   worker ran it or when;
//! - results land in a per-job slot and are merged back in grid order.
//!
//! The compute engine (`Rc<dyn Compute>`) is deliberately `!Send` — the
//! PJRT client is single-threaded — so each worker constructs its own
//! engine inside its thread and shares it across the jobs it happens to
//! claim.

use std::rc::Rc;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::cluster::Cluster;
use crate::config::EngineKind;
use crate::runtime::{make_engine, Compute, XlaEngine};

use super::grid::{GridSpec, Job};
use super::report::{JobResult, SweepReport};

/// Probe the XLA path once on the calling thread so the fallback
/// warning prints a single time — otherwise every worker would re-probe
/// the artifact directory and repeat it.
fn resolve_engine_kind(kind: EngineKind, artifact_dir: &str) -> EngineKind {
    match kind {
        EngineKind::Xla => match XlaEngine::load(artifact_dir) {
            Ok(_) => EngineKind::Xla,
            Err(err) => {
                eprintln!(
                    "warning: XLA engine unavailable ({err:#}); sweep falls back to native compute"
                );
                EngineKind::Native
            }
        },
        other => other,
    }
}

/// Run every cell of `spec` on up to `jobs` worker threads and merge the
/// results (in grid order) into one report.  Artifacts derived from the
/// report are byte-identical for any `jobs >= 1`.
pub fn run_grid(spec: &GridSpec, jobs: usize, artifact_dir: &str) -> Result<SweepReport> {
    let job_list = spec.expand().map_err(|e| anyhow!(e))?;
    let n = job_list.len();
    if n == 0 {
        bail!("grid {:?} expands to zero jobs", spec.name);
    }
    let workers = jobs.clamp(1, n);
    let engine_kind = resolve_engine_kind(spec.base.engine, artifact_dir);
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<JobResult, String>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                // per-thread engine: Rc<dyn Compute> must not cross threads
                let compute = make_engine(engine_kind, artifact_dir);
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let outcome = run_job(&job_list[i], compute.clone());
                    *slots[i].lock().expect("result slot poisoned") = Some(outcome);
                }
            });
        }
    });

    let mut results = Vec::with_capacity(n);
    for (i, slot) in slots.into_iter().enumerate() {
        match slot.into_inner().expect("result slot poisoned") {
            Some(Ok(r)) => results.push(r),
            Some(Err(e)) => {
                let job = &job_list[i];
                bail!(
                    "job {i} ({} p={} {}B) failed: {e}",
                    job.series.name(),
                    job.cfg.p,
                    job.cfg.msg_bytes
                );
            }
            None => bail!("job {i} was never executed (runner bug)"),
        }
    }
    Ok(SweepReport::new(spec, results))
}

fn run_job(job: &Job, compute: Rc<dyn Compute>) -> Result<JobResult, String> {
    let mut cluster = Cluster::new(job.cfg.clone(), compute);
    let metrics = cluster.run().map_err(|e| format!("{e:#}"))?;
    Ok(JobResult::from_metrics(job, &metrics))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_grid() -> GridSpec {
        GridSpec::from_toml(
            r#"
            [grid]
            name = "t"
            sizes = [4, 64]
            series = ["sw_seq", "NF_rd", "NF_binomial"]
            [run]
            p = 8
            iters = 12
            warmup = 2
            "#,
        )
        .unwrap()
    }

    #[test]
    fn serial_and_parallel_reports_are_identical() {
        let spec = tiny_grid();
        let serial = run_grid(&spec, 1, "artifacts").unwrap();
        for jobs in [2, 4, 16] {
            let parallel = run_grid(&spec, jobs, "artifacts").unwrap();
            assert_eq!(
                serial.to_json().pretty(),
                parallel.to_json().pretty(),
                "--jobs {jobs} must not change the merged report"
            );
        }
    }

    #[test]
    fn report_covers_every_cell_with_samples() {
        let spec = tiny_grid();
        let report = run_grid(&spec, 4, "artifacts").unwrap();
        assert_eq!(report.jobs.len(), spec.n_jobs());
        for (i, job) in report.jobs.iter().enumerate() {
            assert_eq!(job.index, i, "merged in grid order");
            assert_eq!(job.host.count(), 8 * 12, "iters x ranks samples");
            assert!(job.sim_ns > 0);
        }
        // NF series measured on-NIC latency, sw did not
        assert!(report.jobs.iter().any(|j| j.series == "NF_rd" && j.nic.count() > 0));
        assert!(report.jobs.iter().all(|j| j.series != "sw_seq" || j.nic.count() == 0));
    }

    #[test]
    fn oversubscribed_workers_cap_at_job_count() {
        let spec = GridSpec::from_toml("[grid]\nsizes = [4]\n[run]\niters = 5\nwarmup = 1")
            .unwrap();
        let report = run_grid(&spec, 64, "artifacts").unwrap();
        assert_eq!(report.jobs.len(), 1);
    }
}
