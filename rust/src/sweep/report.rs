//! Sweep results: per-job summaries merged (in grid order) into one
//! report, serialized to deterministic JSON artifacts.
//!
//! Byte-stability contract: everything here is a pure function of the
//! ordered job results, which are themselves a pure function of the grid
//! spec — so a sweep writes identical artifact bytes no matter how many
//! worker threads ran it.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::bench::Metric;
use crate::metrics::json::Json;
use crate::metrics::{us, Attribution, LatencyStats, RunMetrics, Table};
use crate::util::fmt_bytes;

use super::grid::{GridSpec, Job, FIGS_GRID};

/// One simulated grid cell, reduced to what reports need.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub index: usize,
    pub series: String,
    /// Topology spec of the cell, as the grid named it ("auto",
    /// "fattree", "star:8", ...).
    pub topology: String,
    pub p: usize,
    pub msg_bytes: usize,
    /// Per-hop loss probability of the cell (0.0 = reliable fabric).
    pub loss: f64,
    /// Forced-late rank of the cell (`None` = nobody held back).
    pub late_rank: Option<usize>,
    /// Fail-stop crash schedule of the cell (`""` = nobody dies).
    pub crash: String,
    pub seed: u64,
    pub host: LatencyStats,
    pub nic: LatencyStats,
    pub total_frames: u64,
    /// Frames the switch fabric transmitted (0 on direct wirings).
    pub switch_frames: u64,
    pub multicasts: u64,
    /// Handler-VM instructions retired / activations parked (0 outside
    /// `handler:*` series).
    pub handler_instrs: u64,
    pub handler_stalls: u64,
    /// Concurrent communicators in this cell (1 = classic runs).
    pub tenants: usize,
    /// Per-tenant host-latency tail percentiles, tenant order.
    pub tenant_p50_us: Vec<f64>,
    pub tenant_p99_us: Vec<f64>,
    /// Jain's fairness index over per-tenant completion rates.
    pub fairness: f64,
    /// Total handler queueing delay charged / background frames received.
    pub hpu_queue_ns: u64,
    pub bg_frames: u64,
    /// Recovery-protocol activity (all 0 on lossless cells).
    pub retransmits: u64,
    pub timeouts_fired: u64,
    pub recovery_ns: u64,
    /// Fail-stop recovery activity (all 0 on crash-free cells).
    pub crashes: u64,
    pub false_suspicions: u64,
    pub detection_ns: u64,
    pub reroutes: u64,
    pub degraded_completions: u64,
    /// Latency attribution breakdown (`None` unless the cell ran with
    /// `attribution = true`; its components sum exactly to
    /// `latency_ns`).
    pub attribution: Option<Attribution>,
    pub sim_ns: u64,
}

impl JobResult {
    pub fn from_metrics(job: &Job, m: &RunMetrics) -> JobResult {
        JobResult {
            index: job.index,
            series: job.series.name(),
            topology: job.cfg.topology.clone(),
            p: job.cfg.p,
            msg_bytes: job.cfg.msg_bytes,
            loss: job.cfg.loss,
            late_rank: job.cfg.late_rank,
            crash: job.cfg.crash_spec.clone(),
            seed: job.cfg.seed,
            host: m.host_overall(),
            nic: m.nic_overall(),
            total_frames: m.total_frames(),
            switch_frames: m.switch_frames_tx,
            multicasts: m.multicasts,
            handler_instrs: m.handler_instrs,
            handler_stalls: m.handler_stalls,
            tenants: job.cfg.tenants,
            tenant_p50_us: m
                .tenant_host
                .iter()
                .map(|t| crate::util::ns_to_us(t.percentile_ns(50.0)))
                .collect(),
            tenant_p99_us: m
                .tenant_host
                .iter()
                .map(|t| crate::util::ns_to_us(t.percentile_ns(99.0)))
                .collect(),
            fairness: m.fairness(),
            hpu_queue_ns: m.hpu_queue_ns,
            bg_frames: m.bg_frames_rx,
            retransmits: m.retransmits,
            timeouts_fired: m.timeouts_fired,
            recovery_ns: m.recovery_ns,
            crashes: m.crashes,
            false_suspicions: m.false_suspicions,
            detection_ns: m.detection_ns,
            reroutes: m.reroutes,
            degraded_completions: m.degraded_completions,
            attribution: m.attribution,
            sim_ns: m.sim_ns,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(String, Json)> = vec![
            ("index".into(), Json::int(self.index as u64)),
            ("series".into(), Json::str(self.series.clone())),
            ("topology".into(), Json::str(self.topology.clone())),
            ("p".into(), Json::int(self.p as u64)),
            ("msg_bytes".into(), Json::int(self.msg_bytes as u64)),
            ("loss".into(), Json::Num(self.loss)),
        ];
        // emitted only when somebody is held back: absence keeps every
        // pre-late_rank-axis artifact byte-identical
        if let Some(r) = self.late_rank {
            fields.push(("late_rank".into(), Json::int(r as u64)));
        }
        // emitted only when somebody is scheduled to die: absence keeps
        // every pre-crash-axis artifact byte-identical
        if !self.crash.is_empty() {
            fields.push(("crash".into(), Json::str(self.crash.clone())));
        }
        fields.extend([
            ("seed".into(), Json::int(self.seed)),
            ("host".into(), self.host.to_json()),
            ("nic".into(), self.nic.to_json()),
            ("total_frames".into(), Json::int(self.total_frames)),
            ("switch_frames".into(), Json::int(self.switch_frames)),
            ("multicasts".into(), Json::int(self.multicasts)),
            ("handler_instrs".into(), Json::int(self.handler_instrs)),
            ("handler_stalls".into(), Json::int(self.handler_stalls)),
            ("tenants".into(), Json::int(self.tenants as u64)),
            (
                "tenant_p50_us".into(),
                Json::Arr(self.tenant_p50_us.iter().map(|&v| Json::Num(v)).collect()),
            ),
            (
                "tenant_p99_us".into(),
                Json::Arr(self.tenant_p99_us.iter().map(|&v| Json::Num(v)).collect()),
            ),
            ("fairness".into(), Json::Num(self.fairness)),
            ("hpu_queue_ns".into(), Json::int(self.hpu_queue_ns)),
            ("bg_frames".into(), Json::int(self.bg_frames)),
            ("retransmits".into(), Json::int(self.retransmits)),
            ("timeouts_fired".into(), Json::int(self.timeouts_fired)),
            ("recovery_ns".into(), Json::int(self.recovery_ns)),
        ]);
        // fail-stop recovery activity, only when the cell saw any:
        // absence keeps every crash-free artifact byte-identical
        if self.crashes != 0
            || self.false_suspicions != 0
            || self.detection_ns != 0
            || self.reroutes != 0
            || self.degraded_completions != 0
        {
            fields.extend([
                ("crashes".into(), Json::int(self.crashes)),
                ("false_suspicions".into(), Json::int(self.false_suspicions)),
                ("detection_ns".into(), Json::int(self.detection_ns)),
                ("reroutes".into(), Json::int(self.reroutes)),
                ("degraded_completions".into(), Json::int(self.degraded_completions)),
            ]);
        }
        // breakdown object, only when the cell measured it: absence
        // keeps attribution-off artifacts byte-identical, and nesting
        // keeps the clamped wire_ns/... fields from colliding with the
        // raw hpu_queue_ns / recovery_ns accumulators above
        if let Some(a) = &self.attribution {
            fields.push(("attribution".into(), a.to_json()));
        }
        fields.push(("sim_ns".into(), Json::int(self.sim_ns)));
        Json::Obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<JobResult, String> {
        let get_u64 = |k: &str| {
            j.get(k).and_then(|v| v.as_u64()).ok_or_else(|| format!("job: missing field {k:?}"))
        };
        Ok(JobResult {
            index: get_u64("index")? as usize,
            series: j
                .get("series")
                .and_then(|v| v.as_str())
                .ok_or("job: missing series")?
                .to_string(),
            // absent in pre-topology artifacts: default to the old world
            topology: j
                .get("topology")
                .and_then(|v| v.as_str())
                .unwrap_or("auto")
                .to_string(),
            p: get_u64("p")? as usize,
            msg_bytes: get_u64("msg_bytes")? as usize,
            // absent in pre-fault artifacts: a reliable fabric
            loss: j.get("loss").and_then(|v| v.as_f64()).unwrap_or(0.0),
            // absent unless the cell forced a rank late
            late_rank: j.get("late_rank").and_then(|v| v.as_u64()).map(|r| r as usize),
            // absent in pre-crash artifacts and on quiet cells
            crash: j.get("crash").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            seed: get_u64("seed")?,
            host: LatencyStats::from_json(j.get("host").ok_or("job: missing host")?)?,
            nic: LatencyStats::from_json(j.get("nic").ok_or("job: missing nic")?)?,
            total_frames: get_u64("total_frames")?,
            switch_frames: j.get("switch_frames").and_then(|v| v.as_u64()).unwrap_or(0),
            multicasts: get_u64("multicasts")?,
            // absent in pre-handler artifacts
            handler_instrs: j.get("handler_instrs").and_then(|v| v.as_u64()).unwrap_or(0),
            handler_stalls: j.get("handler_stalls").and_then(|v| v.as_u64()).unwrap_or(0),
            // absent in pre-multi-tenant artifacts: one tenant, no queueing
            tenants: j.get("tenants").and_then(|v| v.as_u64()).unwrap_or(1) as usize,
            tenant_p50_us: j
                .get("tenant_p50_us")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
                .unwrap_or_default(),
            tenant_p99_us: j
                .get("tenant_p99_us")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
                .unwrap_or_default(),
            fairness: j.get("fairness").and_then(|v| v.as_f64()).unwrap_or(1.0),
            hpu_queue_ns: j.get("hpu_queue_ns").and_then(|v| v.as_u64()).unwrap_or(0),
            bg_frames: j.get("bg_frames").and_then(|v| v.as_u64()).unwrap_or(0),
            retransmits: j.get("retransmits").and_then(|v| v.as_u64()).unwrap_or(0),
            timeouts_fired: j.get("timeouts_fired").and_then(|v| v.as_u64()).unwrap_or(0),
            recovery_ns: j.get("recovery_ns").and_then(|v| v.as_u64()).unwrap_or(0),
            crashes: j.get("crashes").and_then(|v| v.as_u64()).unwrap_or(0),
            false_suspicions: j.get("false_suspicions").and_then(|v| v.as_u64()).unwrap_or(0),
            detection_ns: j.get("detection_ns").and_then(|v| v.as_u64()).unwrap_or(0),
            reroutes: j.get("reroutes").and_then(|v| v.as_u64()).unwrap_or(0),
            degraded_completions: j
                .get("degraded_completions")
                .and_then(|v| v.as_u64())
                .unwrap_or(0),
            // absent in legacy / attribution-off artifacts
            attribution: match j.get("attribution") {
                None => None,
                Some(a) => {
                    let f = |k: &str| {
                        a.get(k)
                            .and_then(|v| v.as_u64())
                            .ok_or_else(|| format!("job: missing attribution field {k:?}"))
                    };
                    Some(Attribution {
                        wire_ns: f("wire_ns")?,
                        switch_queue_ns: f("switch_queue_ns")?,
                        hpu_queue_ns: f("hpu_queue_ns")?,
                        handler_exec_ns: f("handler_exec_ns")?,
                        compute_ns: f("compute_ns")?,
                        recovery_ns: f("recovery_ns")?,
                        host_ns: f("host_ns")?,
                        latency_ns: f("latency_ns")?,
                    })
                }
            },
            sim_ns: get_u64("sim_ns")?,
        })
    }

    fn metric_us(&self, metric: Metric) -> f64 {
        match metric {
            Metric::HostAvg => self.host.avg_us(),
            Metric::HostMin => self.host.min_us(),
            Metric::NicAvg => self.nic.avg_us(),
            Metric::NicMin => self.nic.min_us(),
        }
    }
}

/// The four paper figures the built-in `figs` grid reproduces:
/// (artifact stem, title, metric, offloaded-series-only).
pub const FIGURES: &[(&str, &str, Metric, bool)] = &[
    ("fig4", "average MPI_Scan latency (us), 8 nodes", Metric::HostAvg, false),
    ("fig5", "minimum MPI_Scan latency (us), 8 nodes", Metric::HostMin, false),
    ("fig6", "average on-NIC latency after offload (us)", Metric::NicAvg, true),
    ("fig7", "minimum on-NIC latency after offload (us)", Metric::NicMin, true),
];

/// All job results of one sweep, in grid order.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub name: String,
    pub series: Vec<String>,
    pub topologies: Vec<String>,
    pub ps: Vec<usize>,
    pub tenants: Vec<usize>,
    pub losses: Vec<f64>,
    pub crashes: Vec<String>,
    pub late_ranks: Vec<Option<usize>>,
    pub sizes: Vec<usize>,
    pub jobs: Vec<JobResult>,
}

impl SweepReport {
    pub fn new(spec: &GridSpec, jobs: Vec<JobResult>) -> SweepReport {
        SweepReport {
            name: spec.name.clone(),
            series: spec.series.iter().map(|s| s.name()).collect(),
            topologies: spec.topologies.clone(),
            ps: spec.ps.clone(),
            tenants: spec.tenants.clone(),
            losses: spec.losses.clone(),
            crashes: spec.crashes.clone(),
            late_ranks: spec.late_ranks.clone(),
            sizes: spec.sizes.clone(),
            jobs,
        }
    }

    /// The full report as one JSON document.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(String, Json)> = vec![
            ("grid".into(), Json::str(self.name.clone())),
            (
                "series".into(),
                Json::Arr(self.series.iter().map(|s| Json::str(s.clone())).collect()),
            ),
            (
                "topology".into(),
                Json::Arr(self.topologies.iter().map(|t| Json::str(t.clone())).collect()),
            ),
            ("p".into(), Json::Arr(self.ps.iter().map(|&p| Json::int(p as u64)).collect())),
            (
                "tenants".into(),
                Json::Arr(self.tenants.iter().map(|&t| Json::int(t as u64)).collect()),
            ),
            (
                "loss".into(),
                Json::Arr(self.losses.iter().map(|&l| Json::Num(l)).collect()),
            ),
        ];
        // axis key only when the grid actually scheduled crashes:
        // absence keeps every pre-axis report byte-identical
        if self.crashes != [String::new()] {
            fields.push((
                "crash".into(),
                Json::Arr(self.crashes.iter().map(|c| Json::str(c.clone())).collect()),
            ));
        }
        // axis key only when the grid actually swept late ranks:
        // absence keeps every pre-axis report byte-identical
        if self.late_ranks != [None] {
            fields.push((
                "late_rank".into(),
                Json::Arr(
                    self.late_ranks
                        .iter()
                        .map(|lr| match lr {
                            Some(r) => Json::int(*r as u64),
                            None => Json::str("none"),
                        })
                        .collect(),
                ),
            ));
        }
        fields.extend([
            (
                "sizes".into(),
                Json::Arr(self.sizes.iter().map(|&s| Json::int(s as u64)).collect()),
            ),
            ("jobs".into(), Json::Arr(self.jobs.iter().map(|j| j.to_json()).collect())),
        ]);
        Json::Obj(fields)
    }

    fn job_at(&self, series: &str, p: usize, size: usize) -> Option<&JobResult> {
        self.jobs
            .iter()
            .find(|j| j.series == series && j.p == p && j.msg_bytes == size)
    }

    /// One figure as JSON: rows = sizes, one value column per series.
    /// Requires a single-p grid (the paper's figures fix the testbed at
    /// 8 nodes and sweep message size).
    pub fn figure_json(&self, stem: &str) -> Result<Json, String> {
        let &(_, title, metric, nf_only) = FIGURES
            .iter()
            .find(|(s, ..)| *s == stem)
            .ok_or_else(|| format!("unknown figure {stem:?}"))?;
        let &[p] = self.ps.as_slice() else {
            return Err(format!("figure {stem} needs a single-p grid, got {:?}", self.ps));
        };
        if self.topologies.len() > 1 {
            return Err(format!(
                "figure {stem} needs a single-topology grid, got {:?}",
                self.topologies
            ));
        }
        if self.tenants.len() > 1 {
            return Err(format!(
                "figure {stem} needs a single-tenants grid, got {:?}",
                self.tenants
            ));
        }
        if self.losses.len() > 1 {
            return Err(format!(
                "figure {stem} needs a single-loss grid, got {:?}",
                self.losses
            ));
        }
        if self.crashes != [String::new()] {
            return Err(format!(
                "figure {stem} needs a crash-free grid, got {:?}",
                self.crashes
            ));
        }
        if self.late_ranks.len() > 1 {
            return Err(format!(
                "figure {stem} needs a single-late_rank grid, got {:?}",
                self.late_ranks
            ));
        }
        let series: Vec<&String> = self
            .series
            .iter()
            .filter(|s| !nf_only || s.starts_with("NF"))
            .collect();
        if series.is_empty() {
            return Err(format!("figure {stem} has no matching series in this grid"));
        }
        let mut cols = Vec::with_capacity(series.len());
        for name in series {
            let mut values = Vec::with_capacity(self.sizes.len());
            for &size in &self.sizes {
                let job = self.job_at(name, p, size).ok_or_else(|| {
                    format!("figure {stem}: missing cell {name} p={p} {size}B")
                })?;
                values.push(Json::Num(job.metric_us(metric)));
            }
            cols.push(Json::Obj(vec![
                ("name".into(), Json::str(name.clone())),
                ("values_us".into(), Json::Arr(values)),
            ]));
        }
        Ok(Json::Obj(vec![
            ("figure".into(), Json::str(stem)),
            ("title".into(), Json::str(title)),
            (
                "metric".into(),
                Json::str(match metric {
                    Metric::HostAvg => "host_avg_us",
                    Metric::HostMin => "host_min_us",
                    Metric::NicAvg => "nic_avg_us",
                    Metric::NicMin => "nic_min_us",
                }),
            ),
            ("p".into(), Json::int(p as u64)),
            (
                "sizes".into(),
                Json::Arr(self.sizes.iter().map(|&s| Json::int(s as u64)).collect()),
            ),
            ("series".into(), Json::Arr(cols)),
        ]))
    }

    /// Recovery-cost figure: every cell's latency next to its fault
    /// knobs and recovery activity, so latency-vs-loss and
    /// latency-vs-crash curves can be read straight off the rows.
    /// Row order is grid order, a pure function of the spec.
    pub fn recovery_figure_json(&self) -> Json {
        let rows = self
            .jobs
            .iter()
            .map(|j| {
                Json::Obj(vec![
                    ("series".into(), Json::str(j.series.clone())),
                    ("topology".into(), Json::str(j.topology.clone())),
                    ("p".into(), Json::int(j.p as u64)),
                    ("msg_bytes".into(), Json::int(j.msg_bytes as u64)),
                    ("loss".into(), Json::Num(j.loss)),
                    ("crash".into(), Json::str(j.crash.clone())),
                    ("host_avg_us".into(), Json::Num(j.host.avg_us())),
                    ("host_min_us".into(), Json::Num(j.host.min_us())),
                    ("retransmits".into(), Json::int(j.retransmits)),
                    ("recovery_ns".into(), Json::int(j.recovery_ns)),
                    ("crashes".into(), Json::int(j.crashes)),
                    ("false_suspicions".into(), Json::int(j.false_suspicions)),
                    ("detection_ns".into(), Json::int(j.detection_ns)),
                    ("reroutes".into(), Json::int(j.reroutes)),
                    ("degraded_completions".into(), Json::int(j.degraded_completions)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("figure".into(), Json::str("fig_recovery")),
            (
                "title".into(),
                Json::str("recovery cost: MPI_Scan latency vs loss rate and crash schedule"),
            ),
            (
                "loss".into(),
                Json::Arr(self.losses.iter().map(|&l| Json::Num(l)).collect()),
            ),
            (
                "crash".into(),
                Json::Arr(self.crashes.iter().map(|c| Json::str(c.clone())).collect()),
            ),
            ("rows".into(), Json::Arr(rows)),
        ])
    }

    /// Write `<name>.json` (always) plus fig4..fig7.json for the
    /// built-in figs grid and fig_recovery.json whenever the grid
    /// sweeps a fault axis.  Returns the files written.
    pub fn write_artifacts(&self, out_dir: &Path) -> Result<Vec<PathBuf>> {
        std::fs::create_dir_all(out_dir)
            .with_context(|| format!("creating {}", out_dir.display()))?;
        let mut written = Vec::new();
        let mut emit = |stem: &str, doc: &Json| -> Result<()> {
            let path = out_dir.join(format!("{stem}.json"));
            std::fs::write(&path, doc.pretty())
                .with_context(|| format!("writing {}", path.display()))?;
            written.push(path);
            Ok(())
        };
        emit(&self.name, &self.to_json())?;
        if self.name == FIGS_GRID {
            for &(stem, _, _, nf_only) in FIGURES {
                // a figs grid re-pointed at non-NF series (e.g.
                // `--series handler`) has no on-NIC-only figures to draw
                if nf_only && !self.series.iter().any(|s| s.starts_with("NF")) {
                    println!("note: skipping {stem}.json (no NF_* series in this grid)");
                    continue;
                }
                let doc = self.figure_json(stem).map_err(anyhow::Error::msg)?;
                emit(stem, &doc)?;
            }
        }
        // recovery-cost figure only when a fault axis is actually swept
        // (or a crash is pinned): quiet sweeps keep their artifact list
        // — and therefore their bytes — unchanged
        if self.losses.len() > 1 || self.crashes != [String::new()] {
            emit("fig_recovery", &self.recovery_figure_json())?;
        }
        Ok(written)
    }

    /// Human summary: one row per job.
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(&[
            "job", "series", "topology", "p", "msg_size", "loss", "late", "host_avg_us",
            "host_min_us", "nic_avg_us", "frames", "retx",
        ]);
        for j in &self.jobs {
            t.row(vec![
                j.index.to_string(),
                j.series.clone(),
                j.topology.clone(),
                j.p.to_string(),
                fmt_bytes(j.msg_bytes),
                format!("{}", j.loss),
                match j.late_rank {
                    Some(r) => r.to_string(),
                    None => "-".into(),
                },
                us(j.host.avg_us()),
                us(j.host.min_us()),
                us(j.nic.avg_us()),
                j.total_frames.to_string(),
                j.retransmits.to_string(),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(samples: &[u64]) -> LatencyStats {
        let mut s = LatencyStats::new();
        for &v in samples {
            s.record(v);
        }
        s
    }

    fn tiny_report() -> SweepReport {
        let mk = |index: usize, series: &str, size: usize, base: u64| JobResult {
            index,
            series: series.into(),
            topology: "auto".into(),
            p: 8,
            msg_bytes: size,
            loss: 0.0,
            late_rank: None,
            crash: String::new(),
            seed: 1000 + index as u64,
            host: stats(&[base, base + 2_000]),
            nic: stats(&[base / 4]),
            total_frames: 7,
            switch_frames: 0,
            multicasts: 0,
            handler_instrs: 0,
            handler_stalls: 0,
            tenants: 1,
            tenant_p50_us: vec![],
            tenant_p99_us: vec![],
            fairness: 1.0,
            hpu_queue_ns: 0,
            bg_frames: 0,
            retransmits: 0,
            timeouts_fired: 0,
            recovery_ns: 0,
            crashes: 0,
            false_suspicions: 0,
            detection_ns: 0,
            reroutes: 0,
            degraded_completions: 0,
            attribution: None,
            sim_ns: 1_000_000,
        };
        SweepReport {
            name: "t".into(),
            series: vec!["sw_seq".into(), "NF_rd".into()],
            topologies: vec!["auto".into()],
            ps: vec![8],
            tenants: vec![1],
            losses: vec![0.0],
            crashes: vec![String::new()],
            late_ranks: vec![None],
            sizes: vec![4, 64],
            jobs: vec![
                mk(0, "sw_seq", 4, 40_000),
                mk(1, "sw_seq", 64, 44_000),
                mk(2, "NF_rd", 4, 20_000),
                mk(3, "NF_rd", 64, 26_000),
            ],
        }
    }

    #[test]
    fn job_result_json_round_trip() {
        let r = tiny_report();
        for job in &r.jobs {
            let text = job.to_json().pretty();
            let back = JobResult::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.index, job.index);
            assert_eq!(back.series, job.series);
            assert_eq!(back.seed, job.seed);
            assert_eq!(back.host, job.host);
            assert_eq!(back.nic, job.nic);
            assert_eq!(back.to_json().pretty(), text, "emission is stable");
        }
    }

    #[test]
    fn figure_json_selects_metric_and_series() {
        let r = tiny_report();
        let fig4 = r.figure_json("fig4").unwrap();
        assert_eq!(fig4.get("metric").unwrap().as_str(), Some("host_avg_us"));
        let cols = fig4.get("series").unwrap().as_arr().unwrap();
        assert_eq!(cols.len(), 2, "fig4 keeps software series");
        assert_eq!(cols[0].get("name").unwrap().as_str(), Some("sw_seq"));
        let values = cols[0].get("values_us").unwrap().as_arr().unwrap();
        // host avg of [40000, 42000] ns = 41 us
        assert_eq!(values[0].as_f64(), Some(41.0));

        let fig6 = r.figure_json("fig6").unwrap();
        let cols = fig6.get("series").unwrap().as_arr().unwrap();
        assert_eq!(cols.len(), 1, "fig6 is NF-only");
        assert_eq!(cols[0].get("name").unwrap().as_str(), Some("NF_rd"));

        assert!(r.figure_json("fig9").is_err());
    }

    #[test]
    fn figure_json_rejects_multi_topology_grids() {
        let mut r = tiny_report();
        r.topologies = vec!["auto".into(), "fattree".into()];
        let err = r.figure_json("fig4").unwrap_err();
        assert!(err.contains("single-topology"), "{err}");
    }

    #[test]
    fn figure_json_rejects_multi_tenant_grids() {
        let mut r = tiny_report();
        r.tenants = vec![1, 2];
        let err = r.figure_json("fig4").unwrap_err();
        assert!(err.contains("single-tenants"), "{err}");
    }

    #[test]
    fn figure_json_rejects_multi_loss_grids() {
        let mut r = tiny_report();
        r.losses = vec![0.0, 0.05];
        let err = r.figure_json("fig4").unwrap_err();
        assert!(err.contains("single-loss"), "{err}");
    }

    #[test]
    fn figure_json_rejects_crash_grids() {
        let mut r = tiny_report();
        r.crashes = vec![String::new(), "rank:3@epoch:2".into()];
        let err = r.figure_json("fig4").unwrap_err();
        assert!(err.contains("crash-free"), "{err}");
        // even a single pinned crash disqualifies the paper figures
        let mut r = tiny_report();
        r.crashes = vec!["rank:3@epoch:2".into()];
        assert!(r.figure_json("fig4").is_err());
    }

    #[test]
    fn recovery_figure_lists_every_cell_with_its_fault_knobs() {
        let mut r = tiny_report();
        r.losses = vec![0.0, 0.02];
        r.crashes = vec![String::new(), "rank:3@epoch:2".into()];
        r.jobs[3].crash = "rank:3@epoch:2".into();
        r.jobs[3].crashes = 1;
        r.jobs[3].detection_ns = 700;
        r.jobs[3].degraded_completions = 2;
        let doc = Json::parse(&r.recovery_figure_json().pretty()).unwrap();
        assert_eq!(doc.get("figure").unwrap().as_str(), Some("fig_recovery"));
        let crash_axis = doc.get("crash").unwrap().as_arr().unwrap();
        assert_eq!(crash_axis[1].as_str(), Some("rank:3@epoch:2"));
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), r.jobs.len(), "one row per grid cell");
        assert_eq!(rows[0].get("crash").unwrap().as_str(), Some(""));
        assert_eq!(rows[3].get("crashes").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(rows[3].get("detection_ns").and_then(|v| v.as_u64()), Some(700));
        assert_eq!(rows[3].get("degraded_completions").and_then(|v| v.as_u64()), Some(2));
        // emission is deterministic
        assert_eq!(r.recovery_figure_json().pretty(), r.recovery_figure_json().pretty());
    }

    #[test]
    fn figure_json_rejects_multi_late_rank_grids() {
        let mut r = tiny_report();
        r.late_ranks = vec![None, Some(3)];
        let err = r.figure_json("fig4").unwrap_err();
        assert!(err.contains("single-late_rank"), "{err}");
    }

    #[test]
    fn optional_schema_fields_stay_absent_until_used() {
        let r = tiny_report();
        // late_rank: off everywhere -> no job field, no axis key
        let doc = r.to_json().pretty();
        assert!(!doc.contains("late_rank"), "default report must stay byte-identical");
        assert!(!doc.contains("\"attribution\""), "default report must stay byte-identical");
        assert!(!doc.contains("\"crash"), "default report must stay byte-identical");
        assert!(!doc.contains("false_suspicions"), "default report must stay byte-identical");

        let mut r = r;
        r.late_ranks = vec![None, Some(3)];
        r.jobs[1].late_rank = Some(3);
        r.crashes = vec![String::new(), "rank:2@epoch:1".into()];
        r.jobs[2].crash = "rank:2@epoch:1".into();
        r.jobs[2].crashes = 1;
        r.jobs[2].reroutes = 1;
        r.jobs[2].degraded_completions = 3;
        r.jobs[1].attribution = Some(Attribution::finalize(10, 2, 0, 5, 3, 0, 300));
        let doc = Json::parse(&r.to_json().pretty()).unwrap();
        let axis = doc.get("late_rank").unwrap().as_arr().unwrap();
        assert_eq!(axis[0].as_str(), Some("none"));
        assert_eq!(axis[1].as_u64(), Some(3));
        let crash_axis = doc.get("crash").unwrap().as_arr().unwrap();
        assert_eq!(crash_axis[0].as_str(), Some(""));
        assert_eq!(crash_axis[1].as_str(), Some("rank:2@epoch:1"));
        let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
        assert!(jobs[0].get("late_rank").is_none());
        assert_eq!(jobs[1].get("late_rank").and_then(|v| v.as_u64()), Some(3));
        assert!(jobs[0].get("crash").is_none(), "quiet cell emits no crash fields");
        assert!(jobs[0].get("crashes").is_none(), "quiet cell emits no crash fields");
        assert_eq!(jobs[2].get("crash").and_then(|v| v.as_str()), Some("rank:2@epoch:1"));
        assert_eq!(jobs[2].get("crashes").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(jobs[2].get("false_suspicions").and_then(|v| v.as_u64()), Some(0));
        assert_eq!(jobs[2].get("reroutes").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(jobs[2].get("degraded_completions").and_then(|v| v.as_u64()), Some(3));
        let attr = jobs[1].get("attribution").unwrap();
        assert_eq!(attr.get("wire_ns").and_then(|v| v.as_u64()), Some(10));
        assert_eq!(attr.get("host_ns").and_then(|v| v.as_u64()), Some(280));
        assert_eq!(attr.get("latency_ns").and_then(|v| v.as_u64()), Some(300));

        // and the enriched job round-trips, including the breakdown
        let text = r.jobs[1].to_json().pretty();
        let back = JobResult::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.late_rank, Some(3));
        assert_eq!(back.attribution, r.jobs[1].attribution);
        assert_eq!(back.to_json().pretty(), text, "emission is stable");

        // the crashed job round-trips too, counters included
        let text = r.jobs[2].to_json().pretty();
        let back = JobResult::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.crash, "rank:2@epoch:1");
        assert_eq!(back.crashes, 1);
        assert_eq!(back.reroutes, 1);
        assert_eq!(back.degraded_completions, 3);
        assert_eq!(back.to_json().pretty(), text, "emission is stable");
    }

    #[test]
    fn figure_json_reports_missing_cells() {
        let mut r = tiny_report();
        r.jobs.remove(1);
        let err = r.figure_json("fig4").unwrap_err();
        assert!(err.contains("missing cell"), "{err}");
    }

    #[test]
    fn report_json_lists_jobs_in_grid_order() {
        let r = tiny_report();
        let doc = Json::parse(&r.to_json().pretty()).unwrap();
        let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
        let idx: Vec<u64> =
            jobs.iter().map(|j| j.get("index").unwrap().as_u64().unwrap()).collect();
        assert_eq!(idx, vec![0, 1, 2, 3]);
    }

    #[test]
    fn summary_table_has_a_row_per_job() {
        let r = tiny_report();
        let rendered = r.summary_table().render();
        assert_eq!(rendered.lines().count(), 2 + r.jobs.len());
        assert!(rendered.contains("NF_rd"));
        assert!(rendered.contains("64B"));
    }
}
