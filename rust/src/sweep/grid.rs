//! Grid specs: a cartesian product of experiment axes, expanded into an
//! ordered job list.
//!
//! A grid TOML has three sections:
//!
//! ```toml
//! [grid]
//! name     = "myexp"                  # artifact basename (default "sweep")
//! sizes    = [4, 64, 1024]            # msg_bytes axis
//! p        = [4, 8, 64, 256]          # cluster-size axis
//! series   = ["sw_rd", "NF_rd"]       # path x algorithm axis
//! topology = ["auto", "fattree"]      # wiring axis (see net::Topology)
//! tenants  = [1, 2, 4]                # concurrent-communicator axis
//! loss     = [0.0, 0.01, 0.05]        # per-hop loss-probability axis
//! crash    = ["", "rank:3@epoch:4"]   # fail-stop crash-schedule axis ("" = nobody dies)
//! late_rank = ["none", 3]             # forced-late-rank axis ("none" = nobody late)
//!
//! [run]                               # scalar ExpConfig overrides
//! iters = 300
//!
//! [cost]                              # cost-model overrides
//! link_prop_ns = 700
//! ```
//!
//! Expansion order is fixed — series outermost, then topology, then p,
//! then tenants, then loss, then crash, then late_rank, then sizes innermost — and each job derives
//! its own seed from (master seed, job index), so the job list is a pure
//! function of the spec: the parallel runner can execute it with any
//! `--jobs` and merge back into the same report bytes.

use crate::bench::{self, Series};
use crate::config::{ExpConfig, TomlDoc};
use crate::sim::SplitMix64;

/// The built-in grid name that reproduces Figs. 4-7 in one run.
pub const FIGS_GRID: &str = "figs";

/// A parsed sweep grid: base config + the three axes.
#[derive(Clone, Debug)]
pub struct GridSpec {
    /// Artifact basename; `"figs"` additionally emits fig4..fig7.json.
    pub name: String,
    /// Scalar config every job starts from ([run] + [cost] sections).
    pub base: ExpConfig,
    pub series: Vec<Series>,
    /// Topology specs (`auto`, `chain`, `fattree:8`, ...), one grid axis.
    pub topologies: Vec<String>,
    pub ps: Vec<usize>,
    /// Concurrent-communicator counts (1 = the classic single-job runs).
    pub tenants: Vec<usize>,
    /// Per-hop loss probabilities (0.0 = the classic reliable fabric).
    pub losses: Vec<f64>,
    /// Fail-stop crash schedules (`""` = nobody dies; see
    /// [`crate::net::fault::parse_crash_spec`] for the syntax).
    pub crashes: Vec<String>,
    /// Forced-late-rank scenarios (`None` = nobody is held back).
    pub late_ranks: Vec<Option<usize>>,
    pub sizes: Vec<usize>,
}

/// One cell of the grid, ready to simulate.
#[derive(Clone, Debug)]
pub struct Job {
    /// Position in grid order — the merge key the runner sorts by.
    pub index: usize,
    pub series: Series,
    pub cfg: ExpConfig,
}

/// Independent per-job seed: one SplitMix64 step over the master seed
/// mixed with the job index, so neighbouring jobs get uncorrelated
/// streams and the mapping never depends on worker scheduling.
pub fn derive_seed(master: u64, index: u64) -> u64 {
    SplitMix64::new(master ^ (index + 1).wrapping_mul(0xA076_1D64_78BD_642F)).next_u64()
}

impl GridSpec {
    /// Parse a grid TOML (see module docs for the format).
    pub fn from_toml(text: &str) -> Result<GridSpec, String> {
        let doc = TomlDoc::parse(text)?;
        for section in doc.sections() {
            if !matches!(section, "grid" | "run" | "cost") {
                return Err(format!(
                    "unknown section [{section}] (grid files have [grid]/[run]/[cost])"
                ));
            }
        }
        let mut base = ExpConfig::default();
        for (k, v) in doc.section("run") {
            base.set_run(k, v)?;
        }
        for (k, v) in doc.section("cost") {
            base.cost.set(k, v)?;
        }
        for (k, _) in doc.section("grid") {
            if !matches!(
                k,
                "name" | "sizes" | "p" | "series" | "topology" | "tenants" | "loss" | "crash"
                    | "late_rank"
            ) {
                return Err(format!(
                    "unknown grid key: {k} \
                     (expected name/sizes/p/series/topology/tenants/loss/crash/late_rank)"
                ));
            }
        }
        let name = doc.get("grid", "name").unwrap_or("sweep").to_string();
        let name_ok = |c: char| c.is_ascii_alphanumeric() || c == '_' || c == '-';
        if name.is_empty() || !name.chars().all(name_ok) {
            return Err(format!("grid.name {name:?} must be a safe file basename"));
        }

        let parse_usizes = |key: &str, default: usize| -> Result<Vec<usize>, String> {
            match doc.get_list("grid", key)? {
                None => Ok(vec![default]),
                Some(items) if items.is_empty() => Err(format!("grid.{key} is empty")),
                Some(items) => items
                    .iter()
                    .map(|v| v.parse::<usize>().map_err(|e| format!("grid.{key} item {v:?}: {e}")))
                    .collect(),
            }
        };
        let sizes = parse_usizes("sizes", base.msg_bytes)?;
        let ps = parse_usizes("p", base.p)?;
        let tenants = parse_usizes("tenants", base.tenants)?;
        let losses = match doc.get_list("grid", "loss")? {
            None => vec![base.loss],
            Some(items) if items.is_empty() => return Err("grid.loss is empty".into()),
            Some(items) => items
                .iter()
                .map(|v| v.parse::<f64>().map_err(|e| format!("grid.loss item {v:?}: {e}")))
                .collect::<Result<Vec<f64>, String>>()?,
        };
        let crashes = match doc.get_list("grid", "crash")? {
            None => vec![base.crash_spec.clone()],
            Some(items) if items.is_empty() => return Err("grid.crash is empty".into()),
            // items are crash schedules verbatim; cell validation below
            // rejects malformed specs and names the cell they came from
            Some(items) => items,
        };
        let late_ranks = match doc.get_list("grid", "late_rank")? {
            None => vec![base.late_rank],
            Some(items) if items.is_empty() => return Err("grid.late_rank is empty".into()),
            Some(items) => items
                .iter()
                .map(|v| match v.as_str() {
                    "none" => Ok(None),
                    _ => v
                        .parse::<usize>()
                        .map(Some)
                        .map_err(|e| format!("grid.late_rank item {v:?}: {e}")),
                })
                .collect::<Result<Vec<Option<usize>>, String>>()?,
        };
        let series = match doc.get_list("grid", "series")? {
            None => vec![Series::of_config(&base)],
            Some(items) if items.is_empty() => return Err("grid.series is empty".into()),
            Some(items) => Series::expand_list(&items).map_err(|e| format!("grid.{e}"))?,
        };

        let topologies = match doc.get_list("grid", "topology")? {
            None => vec![base.topology.clone()],
            Some(items) if items.is_empty() => return Err("grid.topology is empty".into()),
            Some(items) => items,
        };

        let spec = GridSpec {
            name,
            base,
            series,
            topologies,
            ps,
            tenants,
            losses,
            crashes,
            late_ranks,
            sizes,
        };
        spec.expand()?; // validate every cell loudly at parse time
        Ok(spec)
    }

    /// The built-in grid reproducing the paper's evaluation: all five
    /// measured series x the OSU size ladder on the 8-node testbed.
    /// `nfscan sweep --grid figs` turns its report into fig4..fig7.json.
    pub fn figs(iters: usize) -> GridSpec {
        GridSpec {
            name: FIGS_GRID.to_string(),
            base: bench::figure_base(iters),
            series: bench::paper_series(),
            topologies: vec!["auto".to_string()],
            ps: vec![8],
            // pinned to a single tenant and a lossless fabric so the
            // figs job indices (and therefore derived seeds and golden
            // figure bytes) are untouched by the tenants and loss axes
            tenants: vec![1],
            losses: vec![0.0],
            crashes: vec![String::new()],
            late_ranks: vec![None],
            sizes: bench::OSU_SIZES.to_vec(),
        }
    }

    pub fn n_jobs(&self) -> usize {
        self.series.len() * self.topologies.len() * self.ps.len() * self.tenants.len()
            * self.losses.len() * self.crashes.len() * self.late_ranks.len() * self.sizes.len()
    }

    /// Expand to the ordered job list (series, then topology, then p,
    /// then tenants, then loss, then crash, then late_rank, then sizes).  Every cell is validated; an invalid
    /// combination (e.g. rd on a non-power-of-two p, a hypercube cell at
    /// a p that isn't one) names the cell it came from.
    pub fn expand(&self) -> Result<Vec<Job>, String> {
        let mut jobs = Vec::with_capacity(self.n_jobs());
        for &series in &self.series {
            for topo in &self.topologies {
                for &p in &self.ps {
                    for &tenants in &self.tenants {
                        for &loss in &self.losses {
                            for crash in &self.crashes {
                                for &late_rank in &self.late_ranks {
                                    for &size in &self.sizes {
                                        let index = jobs.len();
                                        let mut cfg = self.base.clone();
                                        series.apply(&mut cfg);
                                        cfg.topology = topo.clone();
                                        cfg.p = p;
                                        cfg.tenants = tenants;
                                        cfg.loss = loss;
                                        cfg.crash_spec = crash.clone();
                                        cfg.late_rank = late_rank;
                                        cfg.msg_bytes = size;
                                        cfg.seed = derive_seed(self.base.seed, index as u64);
                                        cfg.validate().map_err(|e| {
                                            let late = match late_rank {
                                                Some(r) => r.to_string(),
                                                None => "none".into(),
                                            };
                                            format!(
                                                "grid cell {index} ({} {topo} p={p} \
                                                 tenants={tenants} loss={loss} crash={crash:?} \
                                                 late_rank={late} {size}B): {e}",
                                                series.name()
                                            )
                                        })?;
                                        jobs.push(Job { index, series, cfg });
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packet::AlgoType;

    const GRID: &str = r#"
        [grid]
        name = "t"
        sizes = [4, 64]
        p = [4, 8]
        series = ["sw_seq", "NF_rd"]
        [run]
        iters = 10
        seed = 7
        [cost]
        link_prop_ns = 700
    "#;

    #[test]
    fn expansion_is_the_ordered_cartesian_product() {
        let spec = GridSpec::from_toml(GRID).unwrap();
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs.len(), 2 * 2 * 2);
        // series outermost, p middle, sizes innermost
        let key = |j: &Job| (j.series.name(), j.cfg.p, j.cfg.msg_bytes);
        let got: Vec<_> = jobs.iter().map(key).collect();
        let want = vec![
            ("sw_seq".to_string(), 4, 4),
            ("sw_seq".to_string(), 4, 64),
            ("sw_seq".to_string(), 8, 4),
            ("sw_seq".to_string(), 8, 64),
            ("NF_rd".to_string(), 4, 4),
            ("NF_rd".to_string(), 4, 64),
            ("NF_rd".to_string(), 8, 4),
            ("NF_rd".to_string(), 8, 64),
        ];
        assert_eq!(got, want);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.index, i);
            assert_eq!(j.cfg.iters, 10, "[run] scalars apply to every job");
            assert_eq!(j.cfg.cost.link_prop_ns, 700, "[cost] applies to every job");
        }
    }

    #[test]
    fn run_topology_is_respected() {
        let spec = GridSpec::from_toml(
            "[grid]\nsizes = [4]\nseries = [\"NF_rd\"]\n[run]\ntopology = \"ring\"",
        )
        .unwrap();
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs[0].cfg.topology, "ring", "[run] topology seeds the default axis");
        let spec = GridSpec::from_toml("[grid]\nsizes = [4]").unwrap();
        assert_eq!(spec.expand().unwrap()[0].cfg.topology, "auto");
    }

    #[test]
    fn topology_axis_expands_between_series_and_p() {
        let spec = GridSpec::from_toml(
            r#"
            [grid]
            sizes = [4]
            p = [4, 8]
            series = ["NF_rd", "NF_binomial"]
            topology = ["auto", "star:4", "fattree"]
            [run]
            iters = 5
            "#,
        )
        .unwrap();
        assert_eq!(spec.n_jobs(), 2 * 3 * 2);
        let jobs = spec.expand().unwrap();
        let key = |j: &Job| (j.series.name(), j.cfg.topology.clone(), j.cfg.p);
        assert_eq!(key(&jobs[0]), ("NF_rd".into(), "auto".into(), 4));
        assert_eq!(key(&jobs[1]), ("NF_rd".into(), "auto".into(), 8));
        assert_eq!(key(&jobs[2]), ("NF_rd".into(), "star:4".into(), 4));
        assert_eq!(key(&jobs[5]), ("NF_rd".into(), "fattree".into(), 8));
        assert_eq!(key(&jobs[6]), ("NF_binomial".into(), "auto".into(), 4));
        // a bad topology cell is loud and names itself
        let err = GridSpec::from_toml(
            "[grid]\nsizes = [4]\ntopology = [\"hypercube\"]\np = [6]\n\
             [run]\nalgo = \"seq\"",
        )
        .unwrap_err();
        assert!(err.contains("hypercube"), "{err}");
    }

    #[test]
    fn seeds_are_derived_stable_and_distinct() {
        let spec = GridSpec::from_toml(GRID).unwrap();
        let a = spec.expand().unwrap();
        let b = spec.expand().unwrap();
        let seeds: Vec<u64> = a.iter().map(|j| j.cfg.seed).collect();
        assert_eq!(seeds, b.iter().map(|j| j.cfg.seed).collect::<Vec<_>>(), "stable");
        let mut uniq = seeds.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len(), "distinct");
        for (i, j) in a.iter().enumerate() {
            assert_eq!(j.cfg.seed, derive_seed(7, i as u64), "pure function of (master, index)");
            assert_ne!(j.cfg.seed, 7, "jobs never reuse the master seed verbatim");
        }
    }

    #[test]
    fn scalar_axes_promote_and_default() {
        let spec = GridSpec::from_toml("[grid]\nsizes = 256\n[run]\np = 4").unwrap();
        assert_eq!(spec.sizes, vec![256]);
        assert_eq!(spec.ps, vec![4], "missing axis falls back to [run] scalar");
        assert_eq!(spec.name, "sweep");
        let jobs = spec.expand().unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].series.algo, AlgoType::RecursiveDoubling);
        assert!(jobs[0].series.offloaded(), "series defaults to the base config path");
    }

    #[test]
    fn handler_series_axis_expands_and_validates() {
        use crate::packet::CollType;
        // the bare "handler" token fans out to all five VM collectives
        let spec = GridSpec::from_toml(
            "[grid]\nsizes = [4]\nseries = [\"handler\"]\n[run]\niters = 5\np = 8",
        )
        .unwrap();
        assert_eq!(spec.n_jobs(), 5);
        let jobs = spec.expand().unwrap();
        assert!(jobs.iter().all(|j| j.cfg.handler() && j.cfg.offloaded()));
        let colls: Vec<CollType> = jobs.iter().map(|j| j.cfg.coll).collect();
        assert_eq!(colls, CollType::HANDLER_SET.to_vec());

        // a pinned collective stays pinned
        let spec =
            GridSpec::from_toml("[grid]\nsizes = [4]\nseries = [\"handler:exscan\"]").unwrap();
        assert_eq!(spec.expand().unwrap()[0].cfg.coll, CollType::Exscan);

        // handler cells hit the power-of-two validation at parse time
        let err =
            GridSpec::from_toml("[grid]\np = [6]\nseries = [\"handler:scan\"]").unwrap_err();
        assert!(err.contains("power-of-two"), "{err}");
        assert!(GridSpec::from_toml("[grid]\nseries = [\"handler:reduce\"]").is_err());
    }

    #[test]
    fn bad_grids_are_loud() {
        assert!(GridSpec::from_toml("[grid]\nseries = [\"warp_rd\"]").is_err());
        assert!(GridSpec::from_toml("[grid]\nsizes = []").is_err());
        assert!(GridSpec::from_toml("[grid]\nbogus = 1").is_err());
        assert!(GridSpec::from_toml("[grid]\nname = \"../evil\"").is_err());
        assert!(GridSpec::from_toml("[bogus]\nk = 1").is_err());
        // rd needs power-of-two p: cell validation fires at parse time
        let err = GridSpec::from_toml("[grid]\np = [6]\nseries = [\"NF_rd\"]").unwrap_err();
        assert!(err.contains("power-of-two"), "{err}");
        // msg_bytes not a dtype multiple
        assert!(GridSpec::from_toml("[grid]\nsizes = [7]").is_err());
    }

    #[test]
    fn tenants_axis_expands_between_p_and_sizes() {
        let spec = GridSpec::from_toml(
            r#"
            [grid]
            sizes = [4, 64]
            tenants = [1, 2]
            series = ["NF_rd"]
            [run]
            iters = 5
            "#,
        )
        .unwrap();
        assert_eq!(spec.n_jobs(), 4);
        let jobs = spec.expand().unwrap();
        let key = |j: &Job| (j.cfg.tenants, j.cfg.msg_bytes);
        assert_eq!(key(&jobs[0]), (1, 4));
        assert_eq!(key(&jobs[1]), (1, 64));
        assert_eq!(key(&jobs[2]), (2, 4));
        assert_eq!(key(&jobs[3]), (2, 64));
        // default: the [run] scalar seeds a single-value axis
        let spec = GridSpec::from_toml("[grid]\nsizes = [4]\n[run]\ntenants = 2").unwrap();
        assert_eq!(spec.tenants, vec![2]);
        // invalid cells name themselves
        let err = GridSpec::from_toml("[grid]\ntenants = [3]").unwrap_err();
        assert!(err.contains("tenants=3"), "{err}");
    }

    #[test]
    fn loss_axis_expands_between_tenants_and_sizes() {
        let spec = GridSpec::from_toml(
            r#"
            [grid]
            sizes = [4, 64]
            loss = [0.0, 0.02]
            series = ["NF_rd"]
            [run]
            iters = 5
            "#,
        )
        .unwrap();
        assert_eq!(spec.n_jobs(), 4);
        let jobs = spec.expand().unwrap();
        let key = |j: &Job| (j.cfg.loss, j.cfg.msg_bytes);
        assert_eq!(key(&jobs[0]), (0.0, 4));
        assert_eq!(key(&jobs[1]), (0.0, 64));
        assert_eq!(key(&jobs[2]), (0.02, 4));
        assert_eq!(key(&jobs[3]), (0.02, 64));
        // default: the [run] scalar seeds a single-value axis
        let spec = GridSpec::from_toml("[grid]\nsizes = [4]\n[run]\nloss = 0.01").unwrap();
        assert_eq!(spec.losses, vec![0.01]);
        // an out-of-range rate hits config validation and names its cell
        let err = GridSpec::from_toml("[grid]\nloss = [1.5]").unwrap_err();
        assert!(err.contains("loss"), "{err}");
        // a lossless grid must not perturb job indices (seed stability)
        let with = GridSpec::from_toml("[grid]\nsizes = [4, 64]\nloss = [0.0]").unwrap();
        let without = GridSpec::from_toml("[grid]\nsizes = [4, 64]").unwrap();
        let seeds = |s: &GridSpec| -> Vec<u64> {
            s.expand().unwrap().iter().map(|j| j.cfg.seed).collect()
        };
        assert_eq!(seeds(&with), seeds(&without), "loss=[0.0] is index-neutral");
    }

    #[test]
    fn crash_axis_expands_between_loss_and_late_rank() {
        let spec = GridSpec::from_toml(
            r#"
            [grid]
            sizes = [4, 64]
            crash = ["", "rank:3@epoch:2"]
            series = ["NF_rd"]
            [run]
            iters = 5
            "#,
        )
        .unwrap();
        assert_eq!(spec.n_jobs(), 4);
        let jobs = spec.expand().unwrap();
        let key = |j: &Job| (j.cfg.crash_spec.clone(), j.cfg.msg_bytes);
        assert_eq!(key(&jobs[0]), (String::new(), 4));
        assert_eq!(key(&jobs[1]), (String::new(), 64));
        assert_eq!(key(&jobs[2]), ("rank:3@epoch:2".to_string(), 4));
        assert_eq!(key(&jobs[3]), ("rank:3@epoch:2".to_string(), 64));
        // default: the [run] scalar seeds a single-value axis
        let spec =
            GridSpec::from_toml("[grid]\nsizes = [4]\n[run]\ncrash = \"rank:1@epoch:0\"").unwrap();
        assert_eq!(spec.crashes, vec!["rank:1@epoch:0".to_string()]);
        // a malformed schedule hits cell validation and names its cell
        let err = GridSpec::from_toml("[grid]\ncrash = [\"rank:9000\"]").unwrap_err();
        assert!(err.contains("crash"), "{err}");
        // a crash rank out of range for p is loud too
        let err = GridSpec::from_toml("[grid]\ncrash = [\"rank:99@epoch:0\"]").unwrap_err();
        assert!(err.contains("crash"), "{err}");
        // a quiet crash axis must not perturb job indices (seed stability)
        let with = GridSpec::from_toml("[grid]\nsizes = [4, 64]\ncrash = [\"\"]").unwrap();
        let without = GridSpec::from_toml("[grid]\nsizes = [4, 64]").unwrap();
        let seeds = |s: &GridSpec| -> Vec<u64> {
            s.expand().unwrap().iter().map(|j| j.cfg.seed).collect()
        };
        assert_eq!(seeds(&with), seeds(&without), "crash=[\"\"] is index-neutral");
    }

    #[test]
    fn late_rank_axis_expands_between_loss_and_sizes() {
        let spec = GridSpec::from_toml(
            r#"
            [grid]
            sizes = [4, 64]
            late_rank = ["none", 3]
            series = ["NF_rd"]
            [run]
            iters = 5
            "#,
        )
        .unwrap();
        assert_eq!(spec.n_jobs(), 4);
        let jobs = spec.expand().unwrap();
        let key = |j: &Job| (j.cfg.late_rank, j.cfg.msg_bytes);
        assert_eq!(key(&jobs[0]), (None, 4));
        assert_eq!(key(&jobs[1]), (None, 64));
        assert_eq!(key(&jobs[2]), (Some(3), 4));
        assert_eq!(key(&jobs[3]), (Some(3), 64));
        // default: the [run] scalar seeds a single-value axis
        let spec = GridSpec::from_toml("[grid]\nsizes = [4]\n[run]\nlate_rank = 3").unwrap();
        assert_eq!(spec.late_ranks, vec![Some(3)]);
        // a non-numeric token other than "none" is loud
        let err = GridSpec::from_toml("[grid]\nlate_rank = [\"maybe\"]").unwrap_err();
        assert!(err.contains("late_rank"), "{err}");
        // an all-"none" grid must not perturb job indices (seed stability)
        let with = GridSpec::from_toml("[grid]\nsizes = [4, 64]\nlate_rank = [\"none\"]").unwrap();
        let without = GridSpec::from_toml("[grid]\nsizes = [4, 64]").unwrap();
        let seeds = |s: &GridSpec| -> Vec<u64> {
            s.expand().unwrap().iter().map(|j| j.cfg.seed).collect()
        };
        assert_eq!(seeds(&with), seeds(&without), "late_rank=[\"none\"] is index-neutral");
    }

    #[test]
    fn figs_grid_matches_the_paper_evaluation() {
        let spec = GridSpec::figs(300);
        assert_eq!(spec.name, FIGS_GRID);
        assert_eq!(spec.ps, vec![8]);
        assert_eq!(spec.tenants, vec![1], "figs indices must not shift under the tenants axis");
        assert_eq!(spec.losses, vec![0.0], "figs runs on a lossless fabric");
        assert_eq!(spec.crashes, vec![String::new()], "figs indices must not shift under crash");
        assert_eq!(spec.late_ranks, vec![None], "figs indices must not shift under late_rank");
        assert_eq!(spec.sizes, crate::bench::OSU_SIZES);
        let names: Vec<String> = spec.series.iter().map(|s| s.name()).collect();
        assert_eq!(names, vec!["sw_seq", "sw_rd", "NF_seq", "NF_rd", "NF_binomial"]);
        assert_eq!(spec.n_jobs(), 5 * crate::bench::OSU_SIZES.len());
        assert_eq!(spec.base.iters, 300);
        spec.expand().unwrap();
    }
}
