//! Hand-rolled JSON (the offline build has no serde): a tiny value tree,
//! deterministic emission, and a strict parser.
//!
//! This is what sweep artifacts (`fig4.json` …) are made of, so the
//! emitter is engineered for byte-stability:
//!
//! - objects keep insertion order (no hash-map iteration order leaks);
//! - integers are carried exactly as `i128` (latency sums in ns overflow
//!   f64's 2^53 integer range long before they overflow i128);
//! - floats print via Rust's shortest-round-trip `Display`, so
//!   emit -> parse -> emit is the identity on every finite value.

use std::fmt::Write as _;

/// A JSON value.  `Obj` preserves insertion order.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i128),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn int(v: impl Into<i128>) -> Json {
        Json::Int(v.into())
    }

    /// Object field lookup (first match; objects we build have no dups).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_i128(&self) -> Option<i128> {
        match self {
            Json::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i128().and_then(|v| u64::try_from(v).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            Json::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }

    /// Pretty-print with 2-space indentation and a trailing newline —
    /// the exact artifact byte format (deterministic by construction).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(v) => {
                let _ = write!(out, "{v}");
            }
            Json::Num(v) => {
                if v.is_finite() {
                    // Display is the shortest round-trip repr; it omits
                    // ".0" for integral floats, so append it to keep the
                    // token a float (parse preserves the variant).
                    let token = format!("{v}");
                    let is_float_token = token.contains(['.', 'e', 'E']);
                    out.push_str(&token);
                    if !is_float_token {
                        out.push_str(".0");
                    }
                } else {
                    // JSON has no NaN/inf; encode as null like most emitters
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }

    /// Strict parse of one JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(value)
    }
}

fn push_indent(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------------- parser

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, b: u8) -> Result<(), String> {
    if bytes.get(*pos) == Some(&b) {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected {:?} at byte {}", b as char, *pos))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'"') => Ok(Json::Str(parse_string(bytes, pos)?)),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, b':')?;
                let value = parse_value(bytes, pos)?;
                fields.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
                }
            }
        }
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json, String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("bad literal at byte {}", *pos))
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(bytes, pos, b'"')?;
    let mut out = Vec::new();
    while let Some(&b) = bytes.get(*pos) {
        *pos += 1;
        match b {
            b'"' => {
                return String::from_utf8(out).map_err(|_| "invalid utf8 in string".to_string())
            }
            b'\\' => {
                let esc = *bytes.get(*pos).ok_or("unterminated escape")?;
                *pos += 1;
                match esc {
                    b'"' => out.push(b'"'),
                    b'\\' => out.push(b'\\'),
                    b'/' => out.push(b'/'),
                    b'b' => out.push(0x08),
                    b'f' => out.push(0x0C),
                    b'n' => out.push(b'\n'),
                    b'r' => out.push(b'\r'),
                    b't' => out.push(b'\t'),
                    b'u' => {
                        let hex = bytes
                            .get(*pos..*pos + 4)
                            .ok_or("truncated \\u escape")?;
                        let hex = std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        *pos += 4;
                        let c = char::from_u32(code)
                            .ok_or_else(|| format!("bad codepoint \\u{hex}"))?;
                        let mut buf = [0u8; 4];
                        out.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                    }
                    other => return Err(format!("bad escape \\{}", other as char)),
                }
            }
            other => out.push(other),
        }
    }
    Err("unterminated string".into())
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while let Some(&b) = bytes.get(*pos) {
        if matches!(b, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
            *pos += 1;
        } else {
            break;
        }
    }
    let tok = std::str::from_utf8(&bytes[start..*pos]).map_err(|_| "bad number")?;
    if tok.is_empty() {
        return Err(format!("expected a value at byte {start}"));
    }
    if tok.contains(['.', 'e', 'E']) {
        tok.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {tok:?}: {e}"))
    } else {
        tok.parse::<i128>().map(Json::Int).map_err(|e| format!("bad number {tok:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip() {
        for v in [
            Json::Null,
            Json::Bool(true),
            Json::Bool(false),
            Json::Int(0),
            Json::Int(-42),
            Json::Int(u64::MAX as i128 * 1000),
            Json::Num(1.5),
            Json::Num(0.1),
            Json::Num(3.0),
            Json::str("hi \"there\"\nline2"),
        ] {
            let text = v.pretty();
            assert_eq!(Json::parse(&text).unwrap(), v, "round-trip of {text}");
        }
    }

    #[test]
    fn emit_parse_emit_is_identity() {
        let doc = Json::Obj(vec![
            ("name".into(), Json::str("fig4")),
            ("sizes".into(), Json::Arr(vec![Json::Int(4), Json::Int(64)])),
            (
                "series".into(),
                Json::Arr(vec![Json::Obj(vec![
                    ("name".into(), Json::str("sw_seq")),
                    ("values_us".into(), Json::Arr(vec![Json::Num(12.25), Json::Num(3.0)])),
                ])]),
            ),
            ("empty_arr".into(), Json::Arr(vec![])),
            ("empty_obj".into(), Json::Obj(vec![])),
        ]);
        let once = doc.pretty();
        let twice = Json::parse(&once).unwrap().pretty();
        assert_eq!(once, twice);
    }

    #[test]
    fn integral_floats_stay_floats() {
        let text = Json::Num(3.0).pretty();
        assert_eq!(text.trim(), "3.0");
        assert_eq!(Json::parse(&text).unwrap(), Json::Num(3.0));
    }

    #[test]
    fn object_order_preserved() {
        let doc = Json::Obj(vec![
            ("z".into(), Json::Int(1)),
            ("a".into(), Json::Int(2)),
        ]);
        let text = doc.pretty();
        assert!(text.find("\"z\"").unwrap() < text.find("\"a\"").unwrap());
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn accessors() {
        let doc = Json::parse(r#"{"a": 1, "b": [2.5, "x"], "c": null}"#).unwrap();
        assert_eq!(doc.get("a").unwrap().as_u64(), Some(1));
        let arr = doc.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(2.5));
        assert_eq!(arr[1].as_str(), Some("x"));
        assert_eq!(doc.get("c"), Some(&Json::Null));
        assert_eq!(doc.get("missing"), None);
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("{\"k\" 1}").is_err());
    }
}
