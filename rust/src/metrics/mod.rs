//! Latency statistics and table output — what Figs. 4-7 are made of.

use crate::util::ns_to_us;

/// Streaming min/avg/max over nanosecond samples.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl LatencyStats {
    pub fn new() -> Self {
        LatencyStats { count: 0, sum_ns: 0, min_ns: u64::MAX, max_ns: 0 }
    }

    pub fn record(&mut self, ns: u64) {
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn avg_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn avg_us(&self) -> f64 {
        self.avg_ns() / 1_000.0
    }

    pub fn min_us(&self) -> f64 {
        ns_to_us(self.min_ns())
    }
}

/// All measurements of one simulated experiment.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Host-observed MPI_Scan latency per rank (call -> result).
    pub host_latency: Vec<LatencyStats>,
    /// On-NIC elapsed time per rank (offload -> release timestamps,
    /// Figs. 6/7) — NF runs only.
    pub nic_elapsed: Vec<LatencyStats>,
    /// Frames / payload bytes that crossed each NIC's ports.
    pub frames_tx: Vec<u64>,
    pub bytes_tx: Vec<u64>,
    /// Frames forwarded in transit (multi-hop topology mismatch metric).
    pub frames_forwarded: Vec<u64>,
    /// Multicast packet generations taken (SSIII-C optimization metric).
    pub multicasts: u64,
    /// Total simulated duration.
    pub sim_ns: u64,
}

impl RunMetrics {
    pub fn new(p: usize) -> Self {
        RunMetrics {
            host_latency: vec![LatencyStats::new(); p],
            nic_elapsed: vec![LatencyStats::new(); p],
            frames_tx: vec![0; p],
            bytes_tx: vec![0; p],
            frames_forwarded: vec![0; p],
            multicasts: 0,
            sim_ns: 0,
        }
    }

    /// Cluster-wide host latency (all ranks' samples pooled — the OSU
    /// reporting convention the paper uses).
    pub fn host_overall(&self) -> LatencyStats {
        let mut all = LatencyStats::new();
        for s in &self.host_latency {
            all.merge(s);
        }
        all
    }

    pub fn nic_overall(&self) -> LatencyStats {
        let mut all = LatencyStats::new();
        for s in &self.nic_elapsed {
            all.merge(s);
        }
        all
    }

    pub fn total_frames(&self) -> u64 {
        self.frames_tx.iter().sum()
    }
}

/// Fixed-width table writer for figure harnesses (stdout + CSV string).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(out.len() - 1));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format microseconds for tables.
pub fn us(v: f64) -> String {
    format!("{v:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = LatencyStats::new();
        assert_eq!(s.min_ns(), 0);
        s.record(10);
        s.record(30);
        s.record(20);
        assert_eq!(s.count(), 3);
        assert_eq!(s.min_ns(), 10);
        assert_eq!(s.max_ns(), 30);
        assert!((s.avg_ns() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn merge_pools_samples() {
        let mut a = LatencyStats::new();
        a.record(5);
        let mut b = LatencyStats::new();
        b.record(15);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min_ns(), 5);
        assert_eq!(a.max_ns(), 15);
    }

    #[test]
    fn run_metrics_overall() {
        let mut m = RunMetrics::new(2);
        m.host_latency[0].record(100);
        m.host_latency[1].record(200);
        let all = m.host_overall();
        assert_eq!(all.count(), 2);
        assert!((all.avg_ns() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["size", "avg_us"]);
        t.row(vec!["4B".into(), "12.34".into()]);
        t.row(vec!["1KB".into(), "456.78".into()]);
        let s = t.render();
        assert!(s.contains("size"));
        assert!(s.lines().count() == 4);
        assert_eq!(t.to_csv().lines().next().unwrap(), "size,avg_us");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
