//! Latency statistics and table output — what Figs. 4-7 are made of —
//! plus the hand-rolled [`json`] tree that sweep artifacts serialize to.

pub mod json;

use crate::util::ns_to_us;

use self::json::Json;

/// Streaming min/avg/max over nanosecond samples.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LatencyStats {
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl LatencyStats {
    pub fn new() -> Self {
        LatencyStats { count: 0, sum_ns: 0, min_ns: u64::MAX, max_ns: 0 }
    }

    pub fn record(&mut self, ns: u64) {
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn avg_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn avg_us(&self) -> f64 {
        self.avg_ns() / 1_000.0
    }

    pub fn min_us(&self) -> f64 {
        ns_to_us(self.min_ns())
    }

    /// Serialize to the artifact JSON shape.  `min_ns` uses the accessor
    /// (0 when empty) so artifacts never carry the internal u64::MAX
    /// sentinel; `from_json` restores it.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("count".into(), Json::int(self.count)),
            ("sum_ns".into(), Json::Int(self.sum_ns as i128)),
            ("min_ns".into(), Json::int(self.min_ns())),
            ("max_ns".into(), Json::int(self.max_ns)),
        ])
    }

    /// Inverse of [`LatencyStats::to_json`].
    pub fn from_json(j: &Json) -> Result<LatencyStats, String> {
        let field = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_i128())
                .ok_or_else(|| format!("latency stats: missing integer field {k:?}"))
        };
        let count = u64::try_from(field("count")?).map_err(|e| format!("count: {e}"))?;
        let sum_ns = u128::try_from(field("sum_ns")?).map_err(|e| format!("sum_ns: {e}"))?;
        let min_ns = u64::try_from(field("min_ns")?).map_err(|e| format!("min_ns: {e}"))?;
        let max_ns = u64::try_from(field("max_ns")?).map_err(|e| format!("max_ns: {e}"))?;
        Ok(LatencyStats {
            count,
            sum_ns,
            // restore the empty-stats sentinel the accessor masked
            min_ns: if count == 0 { u64::MAX } else { min_ns },
            max_ns,
        })
    }
}

/// Sample-retaining latency statistics: what per-tenant tail percentiles
/// are computed from.  [`LatencyStats`] streams (count/sum/min/max) and
/// cannot answer p50/p99; tenants are few and their sample counts modest
/// (iters × group size), so retention is cheap where it is needed.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SampleStats {
    samples: Vec<u64>,
}

impl SampleStats {
    pub fn new() -> Self {
        SampleStats { samples: Vec::new() }
    }

    pub fn record(&mut self, ns: u64) {
        self.samples.push(ns);
    }

    pub fn count(&self) -> u64 {
        self.samples.len() as u64
    }

    pub fn sum_ns(&self) -> u128 {
        self.samples.iter().map(|&s| s as u128).sum()
    }

    pub fn avg_ns(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.sum_ns() as f64 / self.samples.len() as f64
        }
    }

    /// Nearest-rank percentile (`q` in [0, 100]): the smallest sample
    /// such that at least q% of samples are <= it.  0 when empty.
    pub fn percentile_ns(&self, q: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let rank = ((q / 100.0) * n as f64).ceil() as usize;
        sorted[rank.clamp(1, n) - 1]
    }
}

/// Streaming log-bucketed histogram over nanosecond values: bucket 0
/// holds zeros, bucket i (i >= 1) holds `[2^(i-1), 2^i)`.  Fixed
/// storage (no allocation after construction), deterministic, and
/// mergeable by elementwise addition — the shape that lets sweep
/// workers pool percentile-grade data without retaining samples the
/// way [`SampleStats`] does.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LogHistogram {
    buckets: [u64; 65],
    count: u64,
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram { buckets: [0; 65], count: 0 }
    }

    fn bucket_of(ns: u64) -> usize {
        if ns == 0 {
            0
        } else {
            64 - ns.leading_zeros() as usize
        }
    }

    /// Inclusive upper bound of bucket `i`'s value range.
    fn upper_of(i: usize) -> u64 {
        match i {
            0 => 0,
            64 => u64::MAX,
            _ => (1u64 << i) - 1,
        }
    }

    pub fn record(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Elementwise merge — order-independent, so pooling across sweep
    /// workers is deterministic regardless of completion order.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
    }

    /// Nearest-rank percentile, resolved to the holding bucket's upper
    /// bound (a conservative tail estimate; exact to within one power
    /// of two).  0 when empty.
    pub fn percentile_upper_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = (((q / 100.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::upper_of(i);
            }
        }
        u64::MAX
    }

    /// Sparse JSON: `[[bucket_index, count], ...]` for occupied buckets.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.buckets
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(i, &c)| Json::Arr(vec![Json::int(i as u64), Json::int(c)]))
                .collect(),
        )
    }

    /// Inverse of [`LogHistogram::to_json`].
    pub fn from_json(j: &Json) -> Result<LogHistogram, String> {
        let mut h = LogHistogram::new();
        for pair in j.as_arr().ok_or("histogram: expected array")? {
            let pair = pair.as_arr().ok_or("histogram: expected [index, count] pairs")?;
            let (i, c) = match pair {
                [i, c] => (
                    i.as_u64().ok_or("histogram: bad bucket index")? as usize,
                    c.as_u64().ok_or("histogram: bad bucket count")?,
                ),
                _ => return Err("histogram: expected [index, count] pairs".into()),
            };
            if i >= h.buckets.len() {
                return Err(format!("histogram: bucket index {i} out of range"));
            }
            h.buckets[i] += c;
            h.count += c;
        }
        Ok(h)
    }
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

/// Where one run's measured latency went: every component in
/// nanoseconds, summing *exactly* to `latency_ns` (the pooled
/// host-observed latency).  Built by [`Attribution::finalize`], which
/// clamps raw accumulators in a fixed priority order — concurrent work
/// (two ranks' frames on the wire at once) legitimately accumulates
/// more component time than wall-clock latency, so later components
/// absorb the truncation and `host_ns` is the exact residual.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Attribution {
    /// Frame serialization + link propagation.
    pub wire_ns: u64,
    /// Output-port / switch-trunk FIFO queueing.
    pub switch_queue_ns: u64,
    /// Handler activations parked waiting for an HPU.
    pub hpu_queue_ns: u64,
    /// NIC activation time excluding combine folds (pipeline, packet
    /// handling, handler-VM instruction retirement).
    pub handler_exec_ns: u64,
    /// Combine-fold arithmetic (NIC datapath + software path compute).
    pub compute_ns: u64,
    /// Timeout/retransmit episodes (first send to eventual ack of
    /// frames that needed at least one retransmit).
    pub recovery_ns: u64,
    /// Host-side residual: protocol-stack crossings, host compute gaps,
    /// and everything concurrency hides from the other components.
    pub host_ns: u64,
    /// The measured total the components sum to (pooled host latency).
    pub latency_ns: u64,
}

impl Attribution {
    /// Fold raw accumulators into a breakdown whose components sum
    /// exactly to `total_ns`.  Clamp priority: wire, switch-queue,
    /// hpu-queue, handler-exec, compute, recovery; `host_ns` takes the
    /// remainder.
    pub fn finalize(
        wire: u64,
        switch_queue: u64,
        hpu_queue: u64,
        handler_exec: u64,
        compute: u64,
        recovery: u64,
        total_ns: u64,
    ) -> Attribution {
        fn take(v: u64, rem: &mut u64) -> u64 {
            let c = v.min(*rem);
            *rem -= c;
            c
        }
        let mut rem = total_ns;
        let wire_ns = take(wire, &mut rem);
        let switch_queue_ns = take(switch_queue, &mut rem);
        let hpu_queue_ns = take(hpu_queue, &mut rem);
        let handler_exec_ns = take(handler_exec, &mut rem);
        let compute_ns = take(compute, &mut rem);
        let recovery_ns = take(recovery, &mut rem);
        Attribution {
            wire_ns,
            switch_queue_ns,
            hpu_queue_ns,
            handler_exec_ns,
            compute_ns,
            recovery_ns,
            host_ns: rem,
            latency_ns: total_ns,
        }
    }

    /// Sum of the seven components — equals `latency_ns` by
    /// construction; tests assert it anyway.
    pub fn components_sum(&self) -> u64 {
        self.wire_ns
            + self.switch_queue_ns
            + self.hpu_queue_ns
            + self.handler_exec_ns
            + self.compute_ns
            + self.recovery_ns
            + self.host_ns
    }

    /// Field names in artifact order (shared by emitters and docs).
    pub const FIELDS: [&'static str; 8] = [
        "wire_ns",
        "switch_queue_ns",
        "hpu_queue_ns",
        "handler_exec_ns",
        "compute_ns",
        "recovery_ns",
        "host_ns",
        "latency_ns",
    ];

    /// Values in [`Attribution::FIELDS`] order.
    pub fn values(&self) -> [u64; 8] {
        [
            self.wire_ns,
            self.switch_queue_ns,
            self.hpu_queue_ns,
            self.handler_exec_ns,
            self.compute_ns,
            self.recovery_ns,
            self.host_ns,
            self.latency_ns,
        ]
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            Self::FIELDS
                .iter()
                .zip(self.values())
                .map(|(k, v)| (k.to_string(), Json::int(v)))
                .collect(),
        )
    }
}

/// Jain's fairness index over per-tenant completion rates
/// (iterations per unit latency: count_i / sum_latency_i).  1.0 = every
/// tenant progresses at the same rate; 1/n = one tenant hogs everything.
/// Tenants with no samples are excluded; fewer than two rated tenants is
/// trivially fair.
pub fn jain_fairness(tenants: &[SampleStats]) -> f64 {
    let rates: Vec<f64> = tenants
        .iter()
        .filter(|t| t.count() > 0 && t.sum_ns() > 0)
        .map(|t| t.count() as f64 / t.sum_ns() as f64)
        .collect();
    if rates.len() < 2 {
        return 1.0;
    }
    let sum: f64 = rates.iter().sum();
    let sum_sq: f64 = rates.iter().map(|r| r * r).sum();
    (sum * sum) / (rates.len() as f64 * sum_sq)
}

/// All measurements of one simulated experiment.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Host-observed MPI_Scan latency per rank (call -> result).
    pub host_latency: Vec<LatencyStats>,
    /// On-NIC elapsed time per rank (offload -> release timestamps,
    /// Figs. 6/7) — NF runs only.
    pub nic_elapsed: Vec<LatencyStats>,
    /// Frames / payload bytes that crossed each NIC's ports.
    pub frames_tx: Vec<u64>,
    pub bytes_tx: Vec<u64>,
    /// Frames forwarded in transit (multi-hop topology mismatch metric).
    pub frames_forwarded: Vec<u64>,
    /// Traffic pooled over the switch nodes of hierarchical topologies
    /// (inter-switch trunks): frames / bytes transmitted and frames
    /// store-and-forwarded.  All zero on the direct-wired presets.
    pub switch_frames_tx: u64,
    pub switch_bytes_tx: u64,
    pub switch_frames_forwarded: u64,
    /// Multicast packet generations taken (SSIII-C optimization metric).
    pub multicasts: u64,
    /// Handler-VM instructions retired across all cards (0 on the
    /// fixed-function and software paths).
    pub handler_instrs: u64,
    /// Handler-VM activations that parked waiting for input (`drop`).
    pub handler_stalls: u64,
    /// Host-observed latency samples pooled per tenant (p50/p99 +
    /// fairness come from these).  One entry per tenant; a single-tenant
    /// run has exactly one.
    pub tenant_host: Vec<SampleStats>,
    /// Total ns handler activations spent parked waiting for a free
    /// handler processing unit (0 when `cost.hpus` is unconstrained).
    pub hpu_queue_ns: u64,
    /// Handler activations that had to queue for an HPU.
    pub hpu_queued: u64,
    /// Background-traffic frames that reached their destination NIC.
    pub bg_frames_rx: u64,
    /// Reliable frames replayed by the NIC recovery layer (0 unless the
    /// fault plan is lossy).
    pub retransmits: u64,
    /// Retransmit-timer expirations (each either replays or gives up;
    /// a timer whose ack arrived first is a no-op and not counted).
    pub timeouts_fired: u64,
    /// Total original-send -> eventual-ack latency over frames that
    /// needed at least one retransmit.
    pub recovery_ns: u64,
    /// Fail-stop crashes that fired (scheduled rank + switch deaths).
    pub crashes: u64,
    /// Live peers the suspicion protocol wrongly declared dead.
    pub false_suspicions: u64,
    /// Total crash -> declared-dead latency over true detections.
    pub detection_ns: u64,
    /// Route-table recomputations that kept the survivors connected.
    pub reroutes: u64,
    /// Iterations completed over a shrunk survivor communicator after a
    /// rank was declared dead.
    pub degraded_completions: u64,
    /// Total simulated duration.
    pub sim_ns: u64,
    /// Latency attribution breakdown (populated only when the run had
    /// `attribution = true`; `None` keeps artifact bytes identical to
    /// pre-attribution runs).
    pub attribution: Option<Attribution>,
    /// Log-bucketed histogram of measured host latency samples
    /// (populated only alongside `attribution`; empty otherwise).
    pub host_hist: LogHistogram,
}

impl RunMetrics {
    pub fn new(p: usize) -> Self {
        RunMetrics {
            host_latency: vec![LatencyStats::new(); p],
            nic_elapsed: vec![LatencyStats::new(); p],
            frames_tx: vec![0; p],
            bytes_tx: vec![0; p],
            frames_forwarded: vec![0; p],
            switch_frames_tx: 0,
            switch_bytes_tx: 0,
            switch_frames_forwarded: 0,
            multicasts: 0,
            handler_instrs: 0,
            handler_stalls: 0,
            tenant_host: vec![SampleStats::new()],
            hpu_queue_ns: 0,
            hpu_queued: 0,
            bg_frames_rx: 0,
            retransmits: 0,
            timeouts_fired: 0,
            recovery_ns: 0,
            crashes: 0,
            false_suspicions: 0,
            detection_ns: 0,
            reroutes: 0,
            degraded_completions: 0,
            sim_ns: 0,
            attribution: None,
            host_hist: LogHistogram::new(),
        }
    }

    /// True when any fail-stop machinery left a trace in this run —
    /// gates the conditional artifact fields below.
    pub fn has_failure_activity(&self) -> bool {
        self.crashes != 0
            || self.false_suspicions != 0
            || self.detection_ns != 0
            || self.reroutes != 0
            || self.degraded_completions != 0
    }

    /// Per-tenant pooled host latency sized for `tenants` tenants.
    pub fn with_tenants(p: usize, tenants: usize) -> Self {
        let mut m = RunMetrics::new(p);
        m.tenant_host = vec![SampleStats::new(); tenants.max(1)];
        m
    }

    /// Jain's fairness index over the per-tenant completion rates.
    pub fn fairness(&self) -> f64 {
        jain_fairness(&self.tenant_host)
    }

    /// Cluster-wide host latency (all ranks' samples pooled — the OSU
    /// reporting convention the paper uses).
    pub fn host_overall(&self) -> LatencyStats {
        let mut all = LatencyStats::new();
        for s in &self.host_latency {
            all.merge(s);
        }
        all
    }

    pub fn nic_overall(&self) -> LatencyStats {
        let mut all = LatencyStats::new();
        for s in &self.nic_elapsed {
            all.merge(s);
        }
        all
    }

    pub fn total_frames(&self) -> u64 {
        self.frames_tx.iter().sum()
    }

    /// Full-fidelity JSON: cluster-wide summaries plus per-rank detail.
    pub fn to_json(&self) -> Json {
        let u64_arr = |v: &[u64]| Json::Arr(v.iter().map(|&x| Json::int(x)).collect());
        let stats_arr =
            |v: &[LatencyStats]| Json::Arr(v.iter().map(|s| s.to_json()).collect());
        let mut fields: Vec<(String, Json)> = vec![
            ("host_overall".into(), self.host_overall().to_json()),
            ("nic_overall".into(), self.nic_overall().to_json()),
            ("total_frames".into(), Json::int(self.total_frames())),
            ("switch_frames_tx".into(), Json::int(self.switch_frames_tx)),
            ("switch_bytes_tx".into(), Json::int(self.switch_bytes_tx)),
            ("switch_frames_forwarded".into(), Json::int(self.switch_frames_forwarded)),
            ("multicasts".into(), Json::int(self.multicasts)),
            ("handler_instrs".into(), Json::int(self.handler_instrs)),
            ("handler_stalls".into(), Json::int(self.handler_stalls)),
            ("hpu_queue_ns".into(), Json::int(self.hpu_queue_ns)),
            ("hpu_queued".into(), Json::int(self.hpu_queued)),
            ("bg_frames_rx".into(), Json::int(self.bg_frames_rx)),
            ("retransmits".into(), Json::int(self.retransmits)),
            ("timeouts_fired".into(), Json::int(self.timeouts_fired)),
            ("recovery_ns".into(), Json::int(self.recovery_ns)),
        ];
        // Failure-model fields only exist when a crash/suspicion/reroute
        // actually happened: fault-free artifact bytes stay identical to
        // pre-failure-model runs, and legacy parsers default them to 0.
        if self.has_failure_activity() {
            fields.extend([
                ("crashes".into(), Json::int(self.crashes)),
                ("false_suspicions".into(), Json::int(self.false_suspicions)),
                ("detection_ns".into(), Json::int(self.detection_ns)),
                ("reroutes".into(), Json::int(self.reroutes)),
                ("degraded_completions".into(), Json::int(self.degraded_completions)),
            ]);
        }
        // Attribution / histogram fields only exist when the run opted
        // in — their absence keeps pre-attribution artifact bytes
        // byte-identical.
        if let Some(a) = &self.attribution {
            fields.push(("attribution".into(), a.to_json()));
        }
        if !self.host_hist.is_empty() {
            fields.push(("host_hist_log2".into(), self.host_hist.to_json()));
        }
        fields.extend([
            ("fairness".into(), Json::Num(self.fairness())),
            (
                "tenant_p50_us".into(),
                Json::Arr(
                    self.tenant_host
                        .iter()
                        .map(|t| Json::Num(ns_to_us(t.percentile_ns(50.0))))
                        .collect(),
                ),
            ),
            (
                "tenant_p99_us".into(),
                Json::Arr(
                    self.tenant_host
                        .iter()
                        .map(|t| Json::Num(ns_to_us(t.percentile_ns(99.0))))
                        .collect(),
                ),
            ),
            ("sim_ns".into(), Json::int(self.sim_ns)),
            ("host_latency".into(), stats_arr(&self.host_latency)),
            ("nic_elapsed".into(), stats_arr(&self.nic_elapsed)),
            ("frames_tx".into(), u64_arr(&self.frames_tx)),
            ("bytes_tx".into(), u64_arr(&self.bytes_tx)),
            ("frames_forwarded".into(), u64_arr(&self.frames_forwarded)),
        ]);
        Json::Obj(fields)
    }
}

/// Fixed-width table writer for figure harnesses (stdout + CSV string).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(out.len() - 1));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format microseconds for tables.
pub fn us(v: f64) -> String {
    format!("{v:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = LatencyStats::new();
        assert_eq!(s.min_ns(), 0);
        s.record(10);
        s.record(30);
        s.record(20);
        assert_eq!(s.count(), 3);
        assert_eq!(s.min_ns(), 10);
        assert_eq!(s.max_ns(), 30);
        assert!((s.avg_ns() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn merge_pools_samples() {
        let mut a = LatencyStats::new();
        a.record(5);
        let mut b = LatencyStats::new();
        b.record(15);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min_ns(), 5);
        assert_eq!(a.max_ns(), 15);
    }

    #[test]
    fn run_metrics_overall() {
        let mut m = RunMetrics::new(2);
        m.host_latency[0].record(100);
        m.host_latency[1].record(200);
        let all = m.host_overall();
        assert_eq!(all.count(), 2);
        assert!((all.avg_ns() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["size", "avg_us"]);
        t.row(vec!["4B".into(), "12.34".into()]);
        t.row(vec!["1KB".into(), "456.78".into()]);
        let s = t.render();
        assert!(s.contains("size"));
        assert!(s.lines().count() == 4);
        assert_eq!(t.to_csv().lines().next().unwrap(), "size,avg_us");
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn stats_json_round_trip() {
        let mut s = LatencyStats::new();
        s.record(1_234);
        s.record(99);
        s.record(5_000_000);
        let back = LatencyStats::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // and through actual bytes
        let text = s.to_json().pretty();
        let back = LatencyStats::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn empty_stats_json_round_trip() {
        let s = LatencyStats::new();
        let j = s.to_json();
        assert_eq!(j.get("min_ns").unwrap().as_u64(), Some(0), "no u64::MAX sentinel leaks");
        assert_eq!(LatencyStats::from_json(&j).unwrap(), s);
    }

    #[test]
    fn merge_then_serialize_equals_serialize_of_pooled() {
        // merge + JSON commute: merging two stats and serializing gives
        // the same artifact as recording all samples into one.
        let mut a = LatencyStats::new();
        let mut b = LatencyStats::new();
        let mut pooled = LatencyStats::new();
        for (i, ns) in [10u64, 20, 30, 40, 55].iter().enumerate() {
            if i % 2 == 0 { a.record(*ns) } else { b.record(*ns) }
            pooled.record(*ns);
        }
        a.merge(&b);
        assert_eq!(a.to_json().pretty(), pooled.to_json().pretty());
    }

    #[test]
    fn stats_json_rejects_malformed() {
        assert!(LatencyStats::from_json(&Json::Null).is_err());
        assert!(LatencyStats::from_json(&Json::Obj(vec![(
            "count".into(),
            Json::str("three")
        )]))
        .is_err());
        assert!(LatencyStats::from_json(&Json::Obj(vec![(
            "count".into(),
            Json::Int(-1)
        )]))
        .is_err());
    }

    #[test]
    fn percentiles_nearest_rank() {
        let mut s = SampleStats::new();
        assert_eq!(s.percentile_ns(50.0), 0, "empty stats have no tail");
        for ns in [50u64, 10, 40, 20, 30] {
            s.record(ns);
        }
        assert_eq!(s.count(), 5);
        assert_eq!(s.percentile_ns(50.0), 30);
        assert_eq!(s.percentile_ns(99.0), 50);
        assert_eq!(s.percentile_ns(0.0), 10, "q=0 clamps to the minimum");
        assert_eq!(s.percentile_ns(100.0), 50);
        assert!((s.avg_ns() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn fairness_index_bounds() {
        let fill = |ns: u64, n: usize| {
            let mut s = SampleStats::new();
            for _ in 0..n {
                s.record(ns);
            }
            s
        };
        // identical tenants: perfectly fair
        let even = vec![fill(100, 10), fill(100, 10), fill(100, 10)];
        assert!((jain_fairness(&even) - 1.0).abs() < 1e-12);
        // one tenant 100x slower: fairness well below 1
        let skewed = vec![fill(100, 10), fill(10_000, 10)];
        let j = jain_fairness(&skewed);
        assert!(j < 0.6, "skewed rates must show: {j}");
        assert!(j >= 0.5, "two tenants bound Jain at 1/2: {j}");
        // empty tenants are excluded, single tenant trivially fair
        assert_eq!(jain_fairness(&[fill(100, 5), SampleStats::new()]), 1.0);
        assert_eq!(jain_fairness(&[]), 1.0);
    }

    #[test]
    fn run_metrics_json_shape() {
        let mut m = RunMetrics::new(2);
        m.host_latency[0].record(100);
        m.host_latency[1].record(200);
        m.frames_tx = vec![3, 4];
        m.sim_ns = 12345;
        let j = m.to_json();
        assert_eq!(j.get("total_frames").unwrap().as_u64(), Some(7));
        assert_eq!(j.get("sim_ns").unwrap().as_u64(), Some(12345));
        let overall =
            LatencyStats::from_json(j.get("host_overall").unwrap()).unwrap();
        assert_eq!(overall.count(), 2);
        assert_eq!(j.get("host_latency").unwrap().as_arr().unwrap().len(), 2);
        // attribution off / hist empty: no such keys at all
        assert!(j.get("attribution").is_none());
        assert!(j.get("host_hist_log2").is_none());
        // no failure activity: the fail-stop fields are absent too
        assert!(j.get("crashes").is_none());
        assert!(j.get("degraded_completions").is_none());
        m.crashes = 1;
        m.detection_ns = 700;
        let j = m.to_json();
        assert_eq!(j.get("crashes").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("detection_ns").unwrap().as_u64(), Some(700));
        assert_eq!(j.get("false_suspicions").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("reroutes").unwrap().as_u64(), Some(0));
        m.crashes = 0;
        m.detection_ns = 0;
        m.attribution = Some(Attribution::finalize(10, 0, 0, 0, 5, 0, 300));
        m.host_hist.record(100);
        let j = m.to_json();
        let a = j.get("attribution").unwrap();
        assert_eq!(a.get("wire_ns").unwrap().as_u64(), Some(10));
        assert_eq!(a.get("host_ns").unwrap().as_u64(), Some(285));
        assert_eq!(a.get("latency_ns").unwrap().as_u64(), Some(300));
        assert!(j.get("host_hist_log2").is_some());
    }

    #[test]
    fn attribution_sums_exactly_and_clamps_in_order() {
        // normal case: components fit, host takes the residual
        let a = Attribution::finalize(100, 20, 30, 40, 50, 60, 1000);
        assert_eq!(a.components_sum(), a.latency_ns);
        assert_eq!(a.host_ns, 700);
        // concurrency overflow: raw sums exceed total; later components
        // are truncated in priority order, the identity still holds
        let b = Attribution::finalize(600, 300, 200, 100, 50, 25, 1000);
        assert_eq!(b.components_sum(), b.latency_ns);
        assert_eq!(b.wire_ns, 600);
        assert_eq!(b.switch_queue_ns, 300);
        assert_eq!(b.hpu_queue_ns, 100, "third component absorbs the clamp");
        assert_eq!(b.handler_exec_ns, 0);
        assert_eq!(b.compute_ns, 0);
        assert_eq!(b.recovery_ns, 0);
        assert_eq!(b.host_ns, 0);
        // degenerate totals
        let c = Attribution::finalize(5, 5, 5, 5, 5, 5, 0);
        assert_eq!(c.components_sum(), 0);
        assert_eq!(c.latency_ns, 0);
        // field/value arrays stay in lockstep
        assert_eq!(Attribution::FIELDS.len(), a.values().len());
        assert_eq!(a.values()[7], a.latency_ns);
    }

    #[test]
    fn log_histogram_buckets_merge_and_percentiles() {
        let mut h = LogHistogram::new();
        assert_eq!(h.percentile_upper_ns(50.0), 0, "empty hist has no tail");
        h.record(0); // bucket 0
        h.record(1); // [1,2)
        h.record(7); // [4,8)
        h.record(8); // [8,16)
        assert_eq!(h.count(), 4);
        // p100 lands in the [8,16) bucket; upper bound is 15
        assert_eq!(h.percentile_upper_ns(100.0), 15);
        // p25 is the zero bucket
        assert_eq!(h.percentile_upper_ns(25.0), 0);
        // merge is elementwise and order-independent
        let mut other = LogHistogram::new();
        other.record(1u64 << 40);
        let mut ab = h.clone();
        ab.merge(&other);
        let mut ba = other.clone();
        ba.merge(&h);
        assert_eq!(ab, ba);
        assert_eq!(ab.count(), 5);
        assert_eq!(ab.percentile_upper_ns(100.0), (1u64 << 41) - 1);
        // extreme value saturates the top bucket
        let mut top = LogHistogram::new();
        top.record(u64::MAX);
        assert_eq!(top.percentile_upper_ns(50.0), u64::MAX);
        // JSON round-trip is sparse and exact
        let j = ab.to_json();
        let back = LogHistogram::from_json(&j).unwrap();
        assert_eq!(back, ab);
        assert!(LogHistogram::from_json(&Json::Arr(vec![Json::Arr(vec![
            Json::int(99u64),
            Json::int(1u64),
        ])]))
        .is_err());
    }
}
