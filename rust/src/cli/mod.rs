//! Hand-rolled CLI (the offline build has no clap).
//!
//! `nfscan <command> [--key value ...]` — see `print_help` for the
//! command set.  Flag parsing is strict: unknown keys are errors.

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::bench;
use crate::config::{EngineKind, ExpConfig};
use crate::runtime::{make_engine, Compute};

pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `--key value` pairs after the subcommand.  `--flag` followed
    /// by another `--flag` or end-of-args is treated as boolean true.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let command = argv.first().cloned().unwrap_or_else(|| "help".to_string());
        let mut flags = BTreeMap::new();
        let mut i = 1;
        while i < argv.len() {
            let key = argv[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {:?}", argv[i]))?
                .to_string();
            if key.is_empty() {
                bail!("empty flag name");
            }
            let value = match argv.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    i += 1;
                    v.clone()
                }
                _ => "true".to_string(),
            };
            if flags.insert(key.clone(), value).is_some() {
                bail!("duplicate flag --{key}");
            }
            i += 1;
        }
        Ok(Args { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key}")),
            None => Ok(default),
        }
    }

    /// Error on any flag outside `allowed` (commands that don't route
    /// through [`Args::apply_run_flags`] use this so typos are loud).
    pub fn ensure_only(&self, allowed: &[&str]) -> Result<()> {
        for key in self.flags.keys() {
            if !allowed.contains(&key.as_str()) {
                bail!(
                    "unknown flag --{key} for {} (allowed: --{})",
                    self.command,
                    allowed.join(", --")
                );
            }
        }
        Ok(())
    }

    /// Apply recognized flags onto an ExpConfig (same keys as the TOML
    /// [run] section); unknown flags error.
    pub fn apply_run_flags(&self, cfg: &mut ExpConfig, extra_ok: &[&str]) -> Result<()> {
        for (k, v) in &self.flags {
            if extra_ok.contains(&k.as_str()) {
                continue;
            }
            cfg.set_run(k, v).map_err(|e| anyhow!("{e}"))?;
        }
        cfg.validate().map_err(|e| anyhow!("{e}"))?;
        Ok(())
    }
}

pub fn print_help() {
    println!(
        "nfscan — NetFPGA-offloaded MPI_Scan reproduction (Arap & Swany 2014)

USAGE: nfscan <command> [--key value ...]

COMMANDS
  quickstart             one offloaded MPI_Scan on 8 simulated nodes
  run                    one experiment cell; keys = [run] config keys
                         (--algo rd --path fpga --msg_bytes 64 ...);
                         --trace true prints the per-rank span timeline
                         (--trace_cols W sets its width, --trace_cap N the
                         ring capacity, --trace_raw true the raw span list),
                         --profile true the event-loop self-profile, and
                         --attribution true the latency breakdown
  trace                  one cell with span tracing on; emits Chrome-trace/
                         Perfetto JSON (--out trace.json, --cap N events;
                         same [run] keys as run).  Open in ui.perfetto.dev
                         or chrome://tracing; flow arrows follow each
                         reliable txn through drops and retransmits
  fig4|fig5|fig6|fig7    regenerate a paper figure (--iters N, --engine xla,
                         --sizes 4,64,1024)
  sweep --grid F.toml    expand a grid spec (sizes x p x tenants x loss x
                         series x topology) and run every cell in parallel:
                         --jobs N worker threads (default: all cores; the
                         banner shows the resolved count), JSON artifacts
                         under --out DIR (default out/).  --grid figs
                         reproduces Figs. 4-7 in one batch
                         (fig4.json..fig7.json); artifact bytes are
                         identical for any --jobs.  --topology a,b /
                         --sizes n,m / --series a,b / --tenants 1,2,4 /
                         --loss 0,0.01,0.05 / --late_rank none,3 /
                         --crash \";rank:3@epoch:2\" (';'-separated
                         schedules; a leading ';' is the quiet cell)
                         override the file's axes; --attribution true adds
                         the latency breakdown to every job's artifact row.
                         Any fault axis adds fig_recovery.json.
  sweep --config F.toml  legacy: run ONE experiment described by a TOML
  chaos                  seeded fail-stop soak campaign (--seed S --runs K
                         --iters N): every run draws a random hostile
                         scenario (crashes, loss, corruption, reordering)
                         and must end with verified values or a named
                         structured error — a hang or watchdog abort
                         fails the campaign
  values                 run ONE collective with deterministic per-rank
                         data and dump each rank's result bytes as JSON
                         (--series handler:scan --out f.json); used by CI
                         to prove handler results == offload/sw results
  bench                  hot-datapath microbenchmarks (combine, k-way
                         fold, reassembly, handler dispatch, event queue):
                         ns/op + allocs/op; --json --out BENCH_N.json
                         emits the machine-readable trajectory point,
                         --quick shrinks reps for smoke runs
  benchdiff              compare two bench JSONs (--prev OLD --cur NEW):
                         warns on >10% ns/op regressions; advisory unless
                         --strict
  lint                   statically verify handler programs: all shipped
                         images by default, or --file prog.hasm (text ISA);
                         prints the per-entry worst-case cost report and
                         every loop's bound, or the reject findings
                         (exit 1).  --quiet prints verdicts only
  selftest               verify the XLA artifact path against native compute
  perf                   wallclock breakdown of one PJRT combine call
  help                   this text

Collectives: --coll scan|exscan|allreduce|barrier|bcast (allreduce/barrier
need --algo rd or binomial; bcast needs the handler VM or the sw path).

Multi-tenant fabric: --tenants N splits the p ranks into N equal
communicators running concurrent collective streams; --hpus N bounds the
per-card handler execution units (0 = unconstrained); --bg_flows /
--bg_msgs / --bg_bytes / --bg_gap_ns add seeded background point-to-point
traffic.  Per-tenant p50/p99 and a Jain fairness index land in the sweep
artifacts.

Series: (sw|NF)_(seq|rd|binomial) plus the programmable-NIC path
handler[:coll] — `--series handler` sweeps all five handler collectives
(scan, exscan, allreduce, bcast, barrier) as sPIN-style packet programs
on the simulated card (`--path handler` on run/quickstart).

Topologies (--topology): chain | ring | hypercube (direct NetFPGA wiring,
the paper's testbed), star[:group] | fattree[:k] (hierarchical switch
fabrics for p = 64..512), auto (each algorithm's natural direct wiring).

Hostile networks: --loss P drops each frame independently with
probability P (per-link, seeded); --drop \"0->1:3,2->*:1\" drops exact
(link, nth-frame) pairs; --corrupt / --reorder use the same syntax to
mangle (wire-CRC-detected, treated as drops) or hold back exact frames;
--trunk_degrade F multiplies switch trunk serialization cost.  NICs
recover via timeout/retransmit: tune --timeout_ns / --max_retries /
--timeout_backoff.  Results still bit-match the lossless oracle;
recovery cost lands in the retransmits / timeouts_fired / recovery_ns
metrics (sweep artifacts carry them per job, and `--loss a,b` sweeps
loss as a grid axis).

Fail-stop faults: --crash \"rank:3@epoch:2\" kills a rank at the top of
an epoch, \"switch:1@ns:500000\" a switch at a sim time (comma-combined).
NIC heartbeats (ack piggyback + --probe_interval_ns probes) detect the
silence, BFS reroutes around dead switches, and the surviving group
completes a shrunk oracle-verified scan or surfaces a structured
(coll, epoch, dead_ranks) failure — never a hang (--watchdog_ns caps
any stall).  Detection/recovery activity lands in the crashes /
false_suspicions / detection_ns / reroutes / degraded_completions
metrics, present in artifacts only when nonzero.

Observability: span tracing and latency attribution are off by default
and cost nothing when off (artifact bytes stay identical).
--attribution true splits each run's measured latency into wire /
switch_queue / hpu_queue / handler_exec / compute / recovery / host
components that sum exactly to latency_ns, plus a log2 latency
histogram; `nfscan trace` exports the typed span stream as Perfetto
JSON; --profile true prints per-event-kind pop counts, wall-clock, and
allocations of the event loop itself.

Figures print aligned tables; add --csv true for CSV output."
    );
}

/// Build the configured compute engine (artifacts dir from --artifacts).
pub fn engine_from(args: &Args, cfg: &ExpConfig) -> Rc<dyn Compute> {
    let dir = args.get("artifacts").unwrap_or(crate::runtime::ARTIFACT_DIR);
    make_engine(cfg.engine, dir)
}

pub fn main_with_args(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        "quickstart" => cmd_quickstart(&args),
        "run" => cmd_run(&args),
        "trace" => cmd_trace(&args),
        "fig4" | "fig5" | "fig6" | "fig7" => cmd_figure(&args),
        "sweep" => cmd_sweep(&args),
        "chaos" => cmd_chaos(&args),
        "values" => cmd_values(&args),
        "bench" => cmd_bench(&args),
        "benchdiff" => cmd_benchdiff(&args),
        "lint" => cmd_lint(&args),
        "selftest" => cmd_selftest(&args),
        "perf" => cmd_perf(&args),
        other => bail!("unknown command {other:?} (try `nfscan help`)"),
    }
}

fn parse_sizes(args: &Args) -> Result<Vec<usize>> {
    match args.get("sizes") {
        Some(s) => s
            .split(',')
            .map(|v| v.trim().parse::<usize>().with_context(|| format!("--sizes item {v}")))
            .collect(),
        None => Ok(bench::OSU_SIZES.to_vec()),
    }
}

fn cmd_quickstart(args: &Args) -> Result<()> {
    let mut cfg = ExpConfig::default();
    cfg.iters = 100;
    cfg.warmup = 8;
    cfg.verify = true;
    args.apply_run_flags(&mut cfg, &["artifacts"])?;
    let compute = engine_from(args, &cfg);
    println!(
        "quickstart: {} on {} nodes, {} x {} ({} engine)",
        cfg.series_name(),
        cfg.p,
        cfg.msg_elems(),
        cfg.dtype.name(),
        compute.name()
    );
    let mut cluster = crate::cluster::Cluster::new(cfg, compute);
    let m = cluster.run()?;
    let all = m.host_overall();
    println!(
        "ok: {} scans verified | avg {:.2} us | min {:.2} us | on-NIC avg {:.2} us",
        all.count(),
        all.avg_us(),
        all.min_us(),
        m.nic_overall().avg_us()
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = ExpConfig::default();
    args.apply_run_flags(
        &mut cfg,
        &["artifacts", "csv", "trace", "trace_cols", "trace_cap", "trace_raw", "profile"],
    )?;
    let compute = engine_from(args, &cfg);
    let mut cluster = crate::cluster::Cluster::new(cfg.clone(), compute);
    let want_raw = args.get("trace_raw") == Some("true");
    let want_trace = args.get("trace") == Some("true") || want_raw;
    let trace_cols = args.get_usize("trace_cols", 100)?;
    let trace_cap = args.get_usize("trace_cap", 4096)?;
    if want_trace {
        cluster.enable_trace(trace_cap);
    }
    if args.get("profile") == Some("true") {
        cluster.enable_profile();
    }
    let m = cluster.run()?;
    if want_trace {
        println!("{}", cluster.trace.timeline(cfg.p, trace_cols));
    }
    if want_raw {
        println!("{}", cluster.trace.dump(trace_cap));
    }
    let all = m.host_overall();
    println!("series      : {}", cfg.series_name());
    println!("msg_bytes   : {}", cfg.msg_bytes);
    println!("iterations  : {} x {} ranks", cfg.iters, cfg.p);
    println!("avg latency : {:.2} us", all.avg_us());
    println!("min latency : {:.2} us", all.min_us());
    if cfg.offloaded() {
        let nic = m.nic_overall();
        println!("on-NIC avg  : {:.2} us", nic.avg_us());
        println!("on-NIC min  : {:.2} us", nic.min_us());
    }
    println!("frames      : {}", m.total_frames());
    println!("multicasts  : {}", m.multicasts);
    println!("sim time    : {:.3} ms", m.sim_ns as f64 / 1e6);
    if let Some(a) = m.attribution {
        println!("attribution (pooled measured latency, sums exactly):");
        for (k, v) in crate::metrics::Attribution::FIELDS.iter().zip(a.values()) {
            println!("  {k:<16}: {:>12.2} us", v as f64 / 1e3);
        }
        println!("  p50 <= {:.2} us | p99 <= {:.2} us (log2 histogram upper bounds)",
            m.host_hist.percentile_upper_ns(50.0) as f64 / 1e3,
            m.host_hist.percentile_upper_ns(99.0) as f64 / 1e3,
        );
    }
    if let Some(prof) = cluster.profile() {
        println!("event-loop self-profile:");
        print!("{}", prof.render());
    }
    Ok(())
}

/// `nfscan trace` — run one experiment cell with span tracing on and
/// export the Chrome-trace / Perfetto JSON (one track per rank's host,
/// NIC, and HPU lanes; flow arrows follow each reliable transaction
/// through drops and retransmits).
fn cmd_trace(args: &Args) -> Result<()> {
    let mut cfg = ExpConfig::default();
    args.apply_run_flags(&mut cfg, &["artifacts", "out", "cap"])?;
    let cap = args.get_usize("cap", 65_536)?;
    let compute = engine_from(args, &cfg);
    let mut cluster = crate::cluster::Cluster::new(cfg.clone(), compute);
    cluster.enable_trace(cap);
    cluster.run()?;
    let doc = cluster.trace.chrome_trace(cfg.p);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, doc.pretty()).with_context(|| format!("writing {path}"))?;
            println!(
                "wrote {path} ({} events; open in ui.perfetto.dev or chrome://tracing)",
                cluster.trace.len()
            );
        }
        None => print!("{}", doc.pretty()),
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let iters = args.get_usize("iters", 300)?;
    let mut cfg = bench::figure_base(iters);
    if let Some(e) = args.get("engine") {
        cfg.engine =
            EngineKind::from_name(e).ok_or_else(|| anyhow!("unknown engine {e}"))?;
    }
    let sizes = parse_sizes(args)?;
    let compute = engine_from(args, &cfg);
    let table = match args.command.as_str() {
        "fig4" => bench::fig4_table(&cfg, compute, &sizes),
        "fig5" => bench::fig5_table(&cfg, compute, &sizes),
        "fig6" => bench::fig6_table(&cfg, compute, &sizes),
        "fig7" => bench::fig7_table(&cfg, compute, &sizes),
        _ => unreachable!(),
    };
    let title = match args.command.as_str() {
        "fig4" => "Fig. 4 — average MPI_Scan latency (us), 8 nodes",
        "fig5" => "Fig. 5 — minimum MPI_Scan latency (us), 8 nodes",
        "fig6" => "Fig. 6 — average on-NIC latency after offload (us)",
        _ => "Fig. 7 — minimum on-NIC latency after offload (us)",
    };
    println!("{title}");
    if args.get("csv") == Some("true") {
        print!("{}", table.to_csv());
    } else {
        print!("{}", table.render());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    if args.get("config").is_some() {
        if args.get("grid").is_some() {
            bail!("--config (single run) and --grid (batch) are mutually exclusive");
        }
        return cmd_sweep_single(args);
    }
    args.ensure_only(&[
        "grid", "jobs", "out", "artifacts", "engine", "iters", "sizes", "topology", "series",
        "tenants", "loss", "crash", "late_rank", "attribution", "csv",
    ])?;
    let grid = args
        .get("grid")
        .ok_or_else(|| anyhow!("sweep needs --grid FILE|figs (or legacy --config FILE)"))?;
    let mut spec = if grid == crate::sweep::FIGS_GRID {
        crate::sweep::GridSpec::figs(args.get_usize("iters", 300)?)
    } else {
        let text = std::fs::read_to_string(grid).with_context(|| format!("reading {grid}"))?;
        let mut spec =
            crate::sweep::GridSpec::from_toml(&text).map_err(|e| anyhow!("{grid}: {e}"))?;
        // CLI overrides beat the file's [run]/[grid] values (re-validated
        // when run_grid expands)
        if let Some(iters) = args.get("iters") {
            spec.base.iters = iters.parse().with_context(|| "--iters")?;
        }
        spec
    };
    if args.get("sizes").is_some() {
        spec.sizes = parse_sizes(args)?;
    }
    if let Some(topos) = args.get("topology") {
        spec.topologies = topos.split(',').map(|t| t.trim().to_string()).collect();
    }
    if let Some(series) = args.get("series") {
        let tokens: Vec<&str> = series.split(',').collect();
        spec.series =
            crate::bench::Series::expand_list(&tokens).map_err(|e| anyhow!("--{e}"))?;
    }
    if let Some(tenants) = args.get("tenants") {
        spec.tenants = tenants
            .split(',')
            .map(|t| t.trim().parse::<usize>().with_context(|| format!("--tenants item {t}")))
            .collect::<Result<_>>()?;
    }
    if let Some(losses) = args.get("loss") {
        spec.losses = losses
            .split(',')
            .map(|l| l.trim().parse::<f64>().with_context(|| format!("--loss item {l}")))
            .collect::<Result<_>>()?;
    }
    if let Some(crashes) = args.get("crash") {
        // ';'-separated because crash schedules themselves use commas
        // ("rank:3@epoch:2,switch:1@ns:500"); a leading ';' encodes the
        // quiet schedule: --crash ";rank:3@epoch:2" sweeps none-vs-one
        spec.crashes = crashes.split(';').map(|c| c.trim().to_string()).collect();
    }
    if let Some(lates) = args.get("late_rank") {
        spec.late_ranks = lates
            .split(',')
            .map(|l| match l.trim() {
                "none" => Ok(None),
                t => t
                    .parse::<usize>()
                    .map(Some)
                    .with_context(|| format!("--late_rank item {t}")),
            })
            .collect::<Result<_>>()?;
    }
    if let Some(v) = args.get("attribution") {
        spec.base.attribution = v.parse().with_context(|| "--attribution")?;
    }
    if let Some(e) = args.get("engine") {
        spec.base.engine =
            EngineKind::from_name(e).ok_or_else(|| anyhow!("unknown engine {e}"))?;
    }
    // --jobs defaults to every core; the banner always shows the
    // RESOLVED worker count so batch logs are self-describing.
    let default_jobs = std::thread::available_parallelism().map_or(1, |n| n.get());
    let jobs = args.get_usize("jobs", default_jobs)?;
    let out = std::path::Path::new(args.get("out").unwrap_or("out"));
    let artifacts = args.get("artifacts").unwrap_or(crate::runtime::ARTIFACT_DIR);

    let n = spec.n_jobs();
    println!(
        "sweep {}: {} jobs ({} series x {} topologies x {} p x {} tenants x {} loss x {} crash x {} late_rank x {} sizes) on {} workers{}",
        spec.name,
        n,
        spec.series.len(),
        spec.topologies.len(),
        spec.ps.len(),
        spec.tenants.len(),
        spec.losses.len(),
        spec.crashes.len(),
        spec.late_ranks.len(),
        spec.sizes.len(),
        jobs.clamp(1, n.max(1)),
        if args.get("jobs").is_some() { "" } else { " (auto: available parallelism)" }
    );
    // direct (switchless) wirings past the first-gen card's 4 ports are
    // idealized hardware — simulate them, but say so loudly; the
    // hierarchical presets exist so real cards never need more ports.
    // Only unique (resolved spec, p) pairs are built — not the whole
    // job list, which run_grid expands anyway.
    let mut pairs = std::collections::BTreeSet::new();
    for &series in &spec.series {
        for topo in &spec.topologies {
            for &p in &spec.ps {
                let mut cfg = spec.base.clone();
                cfg.algo = series.algo;
                cfg.topology = topo.clone();
                cfg.p = p;
                pairs.insert((cfg.topology_spec().to_string(), p));
            }
        }
    }
    let mut overcabled = Vec::new();
    let mut fat_leaves = Vec::new();
    for (s, p) in pairs {
        let Ok(t) = crate::net::Topology::build(&s, p) else { continue };
        if t.switches() == 0 && !t.fits_card() {
            overcabled.push(format!("{} p={}", t.name(), p));
        } else if s.starts_with("star")
            && t.switches() > 1
            && t.max_leaf_radix() > crate::net::PORTS_PER_CARD
        {
            // star leaves are NetFPGA-class boxes: g hosts + 1 trunk
            // must fit the 4-port card, i.e. star:3 at most.  The core —
            // including the degenerate single-hub star, which models a
            // plain Ethernet switch — is a real switch with
            // unconstrained radix.
            fat_leaves.push(format!("{} p={} (leaf radix {})", t.name(), p, t.max_leaf_radix()));
        }
    }
    if !overcabled.is_empty() {
        println!(
            "warning: direct wirings exceeding the NetFPGA's 4 ports (idealized hardware, \
             not buildable on first-gen cards): {}",
            overcabled.join(", ")
        );
    }
    if !fat_leaves.is_empty() {
        println!(
            "warning: star leaf groups exceeding the NetFPGA's 4 ports (a leaf carries its \
             g hosts plus the trunk uplink; use star:3 or smaller on first-gen cards): {}",
            fat_leaves.join(", ")
        );
    }
    let t0 = std::time::Instant::now();
    let report = crate::sweep::run_grid(&spec, jobs, artifacts)?;
    let wallclock = t0.elapsed().as_secs_f64();
    if args.get("csv") == Some("true") {
        print!("{}", report.summary_table().to_csv());
    } else {
        print!("{}", report.summary_table().render());
    }
    let files = report.write_artifacts(out)?;
    for f in &files {
        println!("wrote {}", f.display());
    }
    println!("[{n} jobs in {wallclock:.2}s wallclock]");
    Ok(())
}

/// `nfscan chaos --seed S --runs K` — seeded fail-stop soak campaign.
/// Every run draws a random hostile scenario (a crash schedule, loss,
/// corruption, and/or reordering over an assorted topology) and must
/// terminate with oracle-verified values or one of the named structured
/// failures.  A watchdog abort fails the campaign: it means the
/// detection/degradation stack left survivors stuck, which is exactly
/// the hang class this command exists to rule out.
fn cmd_chaos(args: &Args) -> Result<()> {
    use crate::sim::SplitMix64;
    args.ensure_only(&["seed", "runs", "iters", "artifacts"])?;
    let master = args.get_usize("seed", 1)? as u64;
    let runs = args.get_usize("runs", 20)?;
    let iters = args.get_usize("iters", 8)?;
    if iters == 0 {
        bail!("chaos needs --iters >= 1");
    }
    let mut rng = SplitMix64::new(master ^ 0x5EED_C0DE);
    let (mut verified, mut degraded, mut named) = (0usize, 0usize, 0usize);
    for i in 0..runs {
        let mut cfg = ExpConfig::default();
        cfg.iters = iters;
        cfg.warmup = 2;
        cfg.verify = true;
        cfg.msg_bytes = 64;
        cfg.p = 8;
        cfg.seed = rng.next_u64();
        cfg.cost.max_retries = 8;
        let topos = ["auto", "hypercube", "star:4", "fattree"];
        cfg.topology = topos[(rng.next_u64() % topos.len() as u64) as usize].into();
        // at least one hostile ingredient per run, often several
        let roll = rng.next_u64();
        if roll & 1 != 0 {
            cfg.loss = 0.01;
        }
        if roll & 2 != 0 {
            cfg.corrupt_spec = "0->1:1".into();
        }
        if roll & 4 != 0 {
            cfg.reorder_spec = "1->0:1".into();
        }
        let rank_crash = |rng: &mut SplitMix64| {
            format!("rank:{}@epoch:{}", rng.next_u64() % 8, rng.next_u64() % iters as u64)
        };
        match roll % 3 {
            0 => cfg.crash_spec = rank_crash(&mut rng),
            1 => {
                // a switch death where the wiring has switches, else a rank
                let topo = crate::net::Topology::build(cfg.topology_spec(), cfg.p)
                    .map_err(|e| anyhow!("{e}"))?;
                cfg.crash_spec = if topo.switches() > 0 {
                    format!(
                        "switch:{}@ns:{}",
                        rng.next_u64() % topo.switches() as u64,
                        100_000 + rng.next_u64() % 400_000
                    )
                } else {
                    rank_crash(&mut rng)
                };
            }
            _ => {} // no crash this run: loss/corrupt/reorder only
        }
        cfg.validate().map_err(|e| {
            anyhow!("chaos run {i}: generated an invalid config ({e}) — generator bug")
        })?;
        let compute = engine_from(args, &cfg);
        let summary = format!(
            "{} p={} crash={:?} loss={} corrupt={:?} reorder={:?}",
            cfg.topology, cfg.p, cfg.crash_spec, cfg.loss, cfg.corrupt_spec, cfg.reorder_spec
        );
        let mut cluster = crate::cluster::Cluster::new(cfg.clone(), compute);
        match cluster.run() {
            Ok(m) => {
                verified += 1;
                if m.degraded_completions > 0 {
                    degraded += 1;
                }
                println!("chaos run {i:>3}: ok       {summary}");
            }
            Err(e) => {
                let msg = e.to_string();
                let expected = ["recovery failed", "partition", "degraded failure"];
                if !expected.iter().any(|w| msg.contains(w)) {
                    bail!("chaos run {i} (seed {}): {summary}: unexpected failure: {msg}", cfg.seed);
                }
                named += 1;
                println!("chaos run {i:>3}: named    {summary}: {msg}");
            }
        }
    }
    println!(
        "chaos: {runs} runs — {verified} verified ({degraded} degraded-but-complete), \
         {named} named structured failures, 0 hangs"
    );
    Ok(())
}

/// Dump each rank's result bytes for ONE deterministic collective —
/// the handler-conformance probe.  The per-rank contributions depend
/// only on (seed, rank, dtype, op, msg size), never on the path, so CI
/// runs this once per offload path and byte-compares the files: handler
/// results must equal fixed-function / software results exactly, while
/// latencies are free to differ.
fn cmd_values(args: &Args) -> Result<()> {
    use crate::metrics::json::Json;
    let mut cfg = ExpConfig::default();
    cfg.iters = 1;
    cfg.warmup = 0;
    if let Some(name) = args.get("series") {
        let series = crate::bench::Series::from_name(name)
            .ok_or_else(|| anyhow!("--series {name:?}: unknown series"))?;
        series.apply(&mut cfg);
    }
    args.apply_run_flags(&mut cfg, &["series", "out", "artifacts"])?;
    let compute = engine_from(args, &cfg);
    let contribs: Vec<_> =
        (0..cfg.p).map(|r| crate::cluster::Cluster::gen_payload(&cfg, r, 0)).collect();
    let (results, _metrics) =
        crate::cluster::Cluster::scan_once(cfg.clone(), compute, contribs)?;
    let hex: Vec<Json> = results
        .iter()
        .map(|p| Json::str(p.bytes().iter().map(|b| format!("{b:02x}")).collect::<String>()))
        .collect();
    let doc = Json::Obj(vec![
        ("coll".into(), Json::str(cfg.coll.name())),
        ("op".into(), Json::str(cfg.op.name())),
        ("dtype".into(), Json::str(cfg.dtype.name())),
        ("p".into(), Json::int(cfg.p as u64)),
        ("msg_bytes".into(), Json::int(cfg.msg_bytes as u64)),
        ("results_hex".into(), Json::Arr(hex)),
    ]);
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, doc.pretty()).with_context(|| format!("writing {path}"))?;
            println!("wrote {path} ({} ranks, {})", cfg.p, cfg.series_name());
        }
        None => println!("{}", doc.pretty()),
    }
    Ok(())
}

/// Hot-datapath microbenchmarks: the perf-trajectory data source
/// (`BENCH_N.json` artifacts, see perf/README.md).
fn cmd_bench(args: &Args) -> Result<()> {
    args.ensure_only(&["json", "out", "quick", "compare"])?;
    let quick = args.get("quick") == Some("true");
    if !crate::util::alloc::counting_installed() {
        println!("note: counting allocator not installed — allocs/op will read n/a");
    }
    let results = crate::bench::micro::run_all(quick);
    print!("{}", crate::bench::micro::table(&results).render());
    let doc = crate::bench::micro::to_json(&results);
    if let Some(path) = args.get("out") {
        std::fs::write(path, doc.pretty()).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    } else if args.get("json") == Some("true") {
        print!("{}", doc.pretty());
    }
    if let Some(prev_path) = args.get("compare") {
        let text = std::fs::read_to_string(prev_path)
            .with_context(|| format!("reading {prev_path}"))?;
        let prev = crate::metrics::json::Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let (lines, regressions) = crate::bench::micro::compare(&prev, &doc, 0.10);
        println!("vs {prev_path}:");
        for l in lines {
            println!("  {l}");
        }
        if regressions > 0 {
            println!("advisory: {regressions} ns/op regression(s) > 10% vs {prev_path}");
        }
    }
    Ok(())
}

/// Compare two bench trajectory points (CI's advisory perf-regression
/// step).  Exit code stays 0 unless --strict.
fn cmd_benchdiff(args: &Args) -> Result<()> {
    args.ensure_only(&["prev", "cur", "strict", "threshold"])?;
    let read = |key: &str| -> Result<crate::metrics::json::Json> {
        let path = args.get(key).ok_or_else(|| anyhow!("benchdiff needs --{key} FILE"))?;
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        crate::metrics::json::Json::parse(&text).map_err(|e| anyhow!("{path}: {e}"))
    };
    let prev = read("prev")?;
    let cur = read("cur")?;
    let threshold: f64 = match args.get("threshold") {
        Some(t) => t.parse().with_context(|| "--threshold")?,
        None => 0.10,
    };
    let (lines, regressions) = crate::bench::micro::compare(&prev, &cur, threshold);
    for l in &lines {
        println!("{l}");
    }
    if regressions > 0 {
        println!(
            "warning: {regressions} ns/op regression(s) > {:.0}% (advisory{})",
            threshold * 100.0,
            if args.get("strict") == Some("true") { ", strict mode fails" } else { "" }
        );
        if args.get("strict") == Some("true") {
            bail!("{regressions} perf regression(s) in strict mode");
        }
    } else {
        println!("no ns/op regressions > {:.0}%", threshold * 100.0);
    }
    Ok(())
}

/// Legacy single-experiment sweep (`--config F.toml`).
fn cmd_sweep_single(args: &Args) -> Result<()> {
    args.ensure_only(&["config", "artifacts"])?;
    let path = args.get("config").ok_or_else(|| anyhow!("sweep needs --config FILE"))?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let cfg = ExpConfig::from_toml(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    let compute = engine_from(args, &cfg);
    let mut cluster = crate::cluster::Cluster::new(cfg.clone(), compute);
    let m = cluster.run()?;
    let all = m.host_overall();
    println!(
        "{}: avg {:.2} us | min {:.2} us | {} samples",
        cfg.series_name(),
        all.avg_us(),
        all.min_us(),
        all.count()
    );
    Ok(())
}

/// `nfscan lint [--file prog.hasm] [--quiet]` — run the static verifier
/// over handler programs and print the proof artifacts (per-entry
/// worst-case cost, per-loop bounds) or the findings.  Exits non-zero
/// if anything is rejected, so CI can gate on it.
fn cmd_lint(args: &Args) -> Result<()> {
    use crate::nic::verify::{verify, CostReport, RejectReason, LOOP_BOUND, MAX_P};
    use crate::nic::vm::{Program, MAX_STEPS};
    use crate::packet::CollType;

    args.ensure_only(&["file", "quiet"])?;
    let quiet = args.get("quiet") == Some("true");

    let print_ok = |prog: &Program, report: &CostReport| {
        println!(
            "ok   {:<18} on_request <= {:>4} instrs, on_packet <= {:>4} instrs, on_timer <= {:>4} instrs (budget {MAX_STEPS}, all p <= {MAX_P})",
            prog.name, report.on_request_bound, report.on_packet_bound, report.on_timer_bound
        );
        if quiet {
            return;
        }
        for l in &report.loops {
            println!(
                "       loop @{:<4} {:>3} instrs x {} back-edge(s) x {} trips -> {} instrs",
                l.head, l.body, l.back_edges, LOOP_BOUND, l.bound
            );
        }
    };
    let print_rejects = |prog: &Program, reasons: &[RejectReason]| {
        println!("FAIL {:<18} {} finding(s)", prog.name, reasons.len());
        for r in reasons {
            println!("       {r} [{}]", r.class());
        }
    };

    let mut failed = 0usize;
    if let Some(path) = args.get("file") {
        let src = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let stem = std::path::Path::new(path)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("program");
        let prog = crate::nic::asm_text::assemble(stem, &src)
            .map_err(|e| anyhow!("{path}: {e}"))?;
        match verify(&prog) {
            Ok(report) => print_ok(&prog, &report),
            Err(reasons) => {
                print_rejects(&prog, &reasons);
                failed += 1;
            }
        }
    } else {
        // every shipped image, deduplicated (scan serves exscan too,
        // allreduce serves barrier)
        let mut seen: Vec<&str> = Vec::new();
        for coll in CollType::HANDLER_SET {
            let prog = crate::nic::program_for(coll);
            if seen.contains(&prog.name) {
                continue;
            }
            seen.push(prog.name);
            match verify(prog) {
                Ok(report) => print_ok(prog, &report),
                Err(reasons) => {
                    print_rejects(prog, &reasons);
                    failed += 1;
                }
            }
        }
    }
    if failed > 0 {
        bail!("{failed} program(s) rejected by the static verifier");
    }
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    use crate::data::{Op, Payload};
    let dir = args.get("artifacts").unwrap_or(crate::runtime::ARTIFACT_DIR);
    let xla = crate::runtime::XlaEngine::load(dir)
        .with_context(|| format!("loading artifacts from {dir} (run `make artifacts`)"))?;
    let native = crate::runtime::NativeEngine::new();
    println!("xla engine up: {} artifacts", xla.artifact_count());
    let mut checked = 0;
    for op in [Op::Sum, Op::Prod, Op::Max, Op::Min] {
        for n in [1usize, 100, 2048, 5000] {
            let a = Payload::from_i32(&(0..n as i32).map(|v| v % 13 - 6).collect::<Vec<_>>());
            let b = Payload::from_i32(&(0..n as i32).map(|v| v % 7 - 3).collect::<Vec<_>>());
            let x = xla.combine(&a, &b, op)?;
            let y = native.combine(&a, &b, op)?;
            anyhow::ensure!(x == y, "combine {op:?} n={n} mismatch");
            checked += 1;
        }
    }
    let x = Payload::from_f64(&(0..3000).map(|v| (v % 17) as f64 * 0.25).collect::<Vec<_>>());
    for inclusive in [true, false] {
        let a = xla.scan(&x, Op::Sum, inclusive)?;
        let b = native.scan(&x, Op::Sum, inclusive)?;
        let (av, bv) = (a.to_f64(), b.to_f64());
        for (i, (p, q)) in av.iter().zip(bv.iter()).enumerate() {
            anyhow::ensure!((p - q).abs() < 1e-9, "scan[{i}] {p} vs {q}");
        }
        checked += 1;
    }
    let own = Payload::from_i32(&(0..2500).map(|v| v % 19).collect::<Vec<_>>());
    let peer = Payload::from_i32(&(0..2500).map(|v| v % 23 - 11).collect::<Vec<_>>());
    let cum = native.combine(&peer, &own, Op::Sum)?;
    anyhow::ensure!(xla.derive(&cum, &own)? == peer, "derive mismatch");
    checked += 1;
    println!("selftest ok: {checked} checks, xla == native everywhere");
    Ok(())
}

fn cmd_perf(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or(crate::runtime::ARTIFACT_DIR);
    let reps = args.get_usize("reps", 500)?;
    let xla = crate::runtime::XlaEngine::load(dir)
        .with_context(|| format!("loading artifacts from {dir}"))?;
    let (lit, exec, read) = xla.probe_breakdown(reps)?;
    let total = lit + exec + read;
    println!("combine-call breakdown over one 2048-element block ({reps} reps):");
    let line = |label: &str, ns: u64| {
        println!(
            "  {label} : {:>8.2} us ({:>4.1}%)",
            ns as f64 / 1e3,
            100.0 * ns as f64 / total as f64
        );
    };
    line("literal creation", lit);
    line("pjrt execute    ", exec);
    line("readback+untuple", read);
    println!("  total            : {:>8.2} us", total as f64 / 1e3);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|v| v.to_string()).collect()
    }

    #[test]
    fn parse_flags() {
        let a = Args::parse(&argv(&["run", "--algo", "rd", "--offloaded", "--iters", "5"]))
            .unwrap();
        assert_eq!(a.command, "run");
        assert_eq!(a.get("algo"), Some("rd"));
        assert_eq!(a.get("offloaded"), Some("true"), "bare flag is boolean");
        assert_eq!(a.get_usize("iters", 0).unwrap(), 5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Args::parse(&argv(&["run", "positional"])).is_err());
        assert!(Args::parse(&argv(&["run", "--a", "1", "--a", "2"])).is_err());
    }

    #[test]
    fn apply_run_flags_roundtrip() {
        let a = Args::parse(&argv(&["run", "--algo", "binomial", "--msg_bytes", "256"])).unwrap();
        let mut cfg = ExpConfig::default();
        a.apply_run_flags(&mut cfg, &[]).unwrap();
        assert_eq!(cfg.algo, crate::packet::AlgoType::BinomialTree);
        assert_eq!(cfg.msg_bytes, 256);
    }

    #[test]
    fn unknown_flag_is_error() {
        let a = Args::parse(&argv(&["run", "--bogus", "1"])).unwrap();
        let mut cfg = ExpConfig::default();
        assert!(a.apply_run_flags(&mut cfg, &[]).is_err());
    }

    #[test]
    fn quickstart_runs() {
        let a = Args::parse(&argv(&["quickstart", "--iters", "10", "--warmup", "2"])).unwrap();
        cmd_quickstart(&a).unwrap();
    }

    #[test]
    fn sweep_grid_end_to_end() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_sweep_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let grid = dir.join("grid.toml");
        std::fs::write(
            &grid,
            "[grid]\nname = \"mini\"\nsizes = [4, 64]\nseries = [\"NF_rd\"]\n\
             [run]\niters = 5\nwarmup = 1\n",
        )
        .unwrap();
        let out = dir.join("out");
        let a = Args::parse(&argv(&[
            "sweep",
            "--grid",
            grid.to_str().unwrap(),
            "--jobs",
            "2",
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        cmd_sweep(&a).unwrap();
        let report = std::fs::read_to_string(out.join("mini.json")).unwrap();
        let doc = crate::metrics::json::Json::parse(&report).unwrap();
        assert_eq!(doc.get("jobs").unwrap().as_arr().unwrap().len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_topology_axis_from_cli() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_topo_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let grid = dir.join("grid.toml");
        std::fs::write(
            &grid,
            "[grid]\nname = \"topo\"\nsizes = [4]\nseries = [\"NF_rd\"]\n\
             [run]\niters = 5\nwarmup = 1\np = 8\n",
        )
        .unwrap();
        let out = dir.join("out");
        let a = Args::parse(&argv(&[
            "sweep",
            "--grid",
            grid.to_str().unwrap(),
            "--topology",
            "auto,fattree",
            "--jobs",
            "2",
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        cmd_sweep(&a).unwrap();
        let report = std::fs::read_to_string(out.join("topo.json")).unwrap();
        let doc = crate::metrics::json::Json::parse(&report).unwrap();
        let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].get("topology").unwrap().as_str(), Some("auto"));
        assert_eq!(jobs[1].get("topology").unwrap().as_str(), Some("fattree"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_tenants_axis_from_cli() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_ten_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let grid = dir.join("grid.toml");
        std::fs::write(
            &grid,
            "[grid]\nname = \"ten\"\nsizes = [64]\nseries = [\"NF_rd\"]\n\
             [run]\niters = 5\nwarmup = 1\np = 8\n",
        )
        .unwrap();
        let out = dir.join("out");
        let a = Args::parse(&argv(&[
            "sweep",
            "--grid",
            grid.to_str().unwrap(),
            "--tenants",
            "1,2",
            "--jobs",
            "2",
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        cmd_sweep(&a).unwrap();
        let report = std::fs::read_to_string(out.join("ten.json")).unwrap();
        let doc = crate::metrics::json::Json::parse(&report).unwrap();
        let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].get("tenants").unwrap().as_u64(), Some(1));
        assert_eq!(jobs[1].get("tenants").unwrap().as_u64(), Some(2));
        let p99 = jobs[1].get("tenant_p99_us").unwrap().as_arr().unwrap();
        assert_eq!(p99.len(), 2, "one percentile per tenant");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_loss_axis_from_cli() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_loss_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let grid = dir.join("grid.toml");
        // max_retries = 8 keeps the lossy cell safely clear of give-up
        std::fs::write(
            &grid,
            "[grid]\nname = \"lossy\"\nsizes = [64]\nseries = [\"NF_rd\"]\n\
             [run]\niters = 5\nwarmup = 1\np = 4\nmax_retries = 8\n",
        )
        .unwrap();
        let out = dir.join("out");
        let a = Args::parse(&argv(&[
            "sweep",
            "--grid",
            grid.to_str().unwrap(),
            "--loss",
            "0,0.02",
            "--jobs",
            "2",
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        cmd_sweep(&a).unwrap();
        let report = std::fs::read_to_string(out.join("lossy.json")).unwrap();
        let doc = crate::metrics::json::Json::parse(&report).unwrap();
        let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].get("loss").unwrap().as_f64(), Some(0.0));
        assert_eq!(jobs[1].get("loss").unwrap().as_f64(), Some(0.02));
        assert_eq!(jobs[0].get("retransmits").unwrap().as_u64(), Some(0));
        assert!(jobs[1].get("timeouts_fired").unwrap().as_u64().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_crash_axis_from_cli() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_crash_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let grid = dir.join("grid.toml");
        std::fs::write(
            &grid,
            "[grid]\nname = \"crashy\"\nsizes = [64]\nseries = [\"NF_rd\"]\n\
             [run]\niters = 5\nwarmup = 1\np = 8\n",
        )
        .unwrap();
        let out = dir.join("out");
        let a = Args::parse(&argv(&[
            "sweep",
            "--grid",
            grid.to_str().unwrap(),
            "--crash",
            ";rank:3@epoch:2",
            "--jobs",
            "2",
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        cmd_sweep(&a).unwrap();
        let report = std::fs::read_to_string(out.join("crashy.json")).unwrap();
        let doc = crate::metrics::json::Json::parse(&report).unwrap();
        let axis = doc.get("crash").unwrap().as_arr().unwrap();
        assert_eq!(axis[0].as_str(), Some(""));
        assert_eq!(axis[1].as_str(), Some("rank:3@epoch:2"));
        let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 2);
        assert!(jobs[0].get("crash").is_none(), "quiet cell omits the field");
        assert!(jobs[0].get("crashes").is_none(), "quiet cell has no crash counters");
        assert_eq!(jobs[1].get("crash").unwrap().as_str(), Some("rank:3@epoch:2"));
        assert_eq!(jobs[1].get("crashes").unwrap().as_u64(), Some(1));
        assert!(jobs[1].get("degraded_completions").unwrap().as_u64().unwrap() >= 1);
        // the fault axis triggers the recovery-cost figure artifact
        let fig = std::fs::read_to_string(out.join("fig_recovery.json")).unwrap();
        let fig = crate::metrics::json::Json::parse(&fig).unwrap();
        let rows = fig.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2, "one row per grid cell");
        assert_eq!(rows[1].get("crashes").unwrap().as_u64(), Some(1));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn chaos_campaign_terminates_with_verified_or_named_outcomes() {
        let a =
            Args::parse(&argv(&["chaos", "--seed", "7", "--runs", "6", "--iters", "6"])).unwrap();
        cmd_chaos(&a).unwrap();
        // a different seed draws different scenarios and must also hold
        let a =
            Args::parse(&argv(&["chaos", "--seed", "11", "--runs", "4", "--iters", "5"])).unwrap();
        cmd_chaos(&a).unwrap();
    }

    #[test]
    fn chaos_rejects_unknown_flags() {
        let a = Args::parse(&argv(&["chaos", "--bogus", "1"])).unwrap();
        assert!(cmd_chaos(&a).is_err());
    }

    #[test]
    fn sweep_late_rank_axis_from_cli() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_late_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let grid = dir.join("grid.toml");
        std::fs::write(
            &grid,
            "[grid]\nname = \"late\"\nsizes = [64]\nseries = [\"NF_rd\"]\n\
             [run]\niters = 5\nwarmup = 1\np = 4\n",
        )
        .unwrap();
        let out = dir.join("out");
        let a = Args::parse(&argv(&[
            "sweep",
            "--grid",
            grid.to_str().unwrap(),
            "--late_rank",
            "none,3",
            "--attribution",
            "true",
            "--jobs",
            "2",
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        cmd_sweep(&a).unwrap();
        let report = std::fs::read_to_string(out.join("late.json")).unwrap();
        let doc = crate::metrics::json::Json::parse(&report).unwrap();
        let axis = doc.get("late_rank").unwrap().as_arr().unwrap();
        assert_eq!(axis[0].as_str(), Some("none"));
        assert_eq!(axis[1].as_u64(), Some(3));
        let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 2);
        assert!(jobs[0].get("late_rank").is_none(), "\"none\" cell omits the field");
        assert_eq!(jobs[1].get("late_rank").unwrap().as_u64(), Some(3));
        for j in jobs {
            let a = j.get("attribution").expect("--attribution true reaches every cell");
            let sum: u64 = crate::metrics::Attribution::FIELDS[..7]
                .iter()
                .map(|k| a.get(k).unwrap().as_u64().unwrap())
                .sum();
            assert_eq!(sum, a.get("latency_ns").unwrap().as_u64().unwrap());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_cmd_writes_valid_chrome_trace() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("trace.json");
        let a = Args::parse(&argv(&[
            "trace",
            "--iters",
            "3",
            "--warmup",
            "1",
            "--p",
            "4",
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        cmd_trace(&a).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let doc = crate::metrics::json::Json::parse(&text).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        assert!(events.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X")));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn run_with_observability_flags() {
        let a = Args::parse(&argv(&[
            "run",
            "--iters",
            "5",
            "--warmup",
            "1",
            "--p",
            "4",
            "--trace",
            "true",
            "--trace_cols",
            "60",
            "--trace_raw",
            "true",
            "--profile",
            "true",
            "--attribution",
            "true",
        ]))
        .unwrap();
        cmd_run(&a).unwrap();
    }

    #[test]
    fn run_flags_reach_the_cost_model() {
        let a = Args::parse(&argv(&["run", "--timeout_ns", "50000", "--max_retries", "7"]))
            .unwrap();
        let mut cfg = ExpConfig::default();
        a.apply_run_flags(&mut cfg, &[]).unwrap();
        assert_eq!(cfg.cost.timeout_ns, 50_000);
        assert_eq!(cfg.cost.max_retries, 7);
    }

    #[test]
    fn sweep_series_override_expands_handler() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_hnd_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let grid = dir.join("grid.toml");
        std::fs::write(
            &grid,
            "[grid]\nname = \"hnd\"\nsizes = [4]\nseries = [\"NF_rd\"]\n\
             [run]\niters = 3\nwarmup = 1\np = 4\n",
        )
        .unwrap();
        let out = dir.join("out");
        let a = Args::parse(&argv(&[
            "sweep",
            "--grid",
            grid.to_str().unwrap(),
            "--series",
            "handler",
            "--jobs",
            "2",
            "--out",
            out.to_str().unwrap(),
        ]))
        .unwrap();
        cmd_sweep(&a).unwrap();
        let report = std::fs::read_to_string(out.join("hnd.json")).unwrap();
        let doc = crate::metrics::json::Json::parse(&report).unwrap();
        let jobs = doc.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 5, "bare handler token fans out to all five collectives");
        let names: Vec<&str> =
            jobs.iter().map(|j| j.get("series").unwrap().as_str().unwrap()).collect();
        assert!(names.contains(&"handler:bcast"), "{names:?}");
        assert!(jobs.iter().all(|j| j.get("handler_instrs").unwrap().as_u64().unwrap() > 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn values_conformance_handler_equals_fixed_function() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_val_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let emit = |series: &str, file: &str| {
            let out = dir.join(file);
            let a = Args::parse(&argv(&[
                "values",
                "--series",
                series,
                "--p",
                "4",
                "--msg_bytes",
                "64",
                "--out",
                out.to_str().unwrap(),
            ]))
            .unwrap();
            cmd_values(&a).unwrap();
            std::fs::read_to_string(out).unwrap()
        };
        let vm = emit("handler:scan", "h.json");
        let ff = emit("NF_rd", "o.json");
        assert_eq!(vm, ff, "handler scan bytes must equal the fixed-function path");
        assert!(vm.contains("results_hex"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_quick_writes_json_and_benchdiff_reads_it() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_test.json");
        let a = Args::parse(&argv(&["bench", "--quick", "--out", out.to_str().unwrap()]))
            .unwrap();
        cmd_bench(&a).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let doc = crate::metrics::json::Json::parse(&text).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str(), Some("nfscan-bench/1"));
        // diff a point against itself: no regressions, exit ok even strict
        let a = Args::parse(&argv(&[
            "benchdiff",
            "--prev",
            out.to_str().unwrap(),
            "--cur",
            out.to_str().unwrap(),
            "--strict",
        ]))
        .unwrap();
        cmd_benchdiff(&a).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn benchdiff_strict_fails_on_regression() {
        let dir = std::env::temp_dir().join(format!("nfscan_cli_bdiff_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mk = |ns: f64| {
            format!(
                "{{\"schema\": \"nfscan-bench/1\", \"entries\": [{{\"name\": \"x\", \
                 \"ns_per_op\": {ns}}}]}}"
            )
        };
        let prev = dir.join("prev.json");
        let cur = dir.join("cur.json");
        std::fs::write(&prev, mk(100.0)).unwrap();
        std::fs::write(&cur, mk(150.0)).unwrap();
        let advisory = Args::parse(&argv(&[
            "benchdiff",
            "--prev",
            prev.to_str().unwrap(),
            "--cur",
            cur.to_str().unwrap(),
        ]))
        .unwrap();
        cmd_benchdiff(&advisory).unwrap();
        let strict = Args::parse(&argv(&[
            "benchdiff",
            "--prev",
            prev.to_str().unwrap(),
            "--cur",
            cur.to_str().unwrap(),
            "--strict",
        ]))
        .unwrap();
        assert!(cmd_benchdiff(&strict).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lint_accepts_every_shipped_image() {
        let a = Args::parse(&argv(&["lint", "--quiet"])).unwrap();
        cmd_lint(&a).expect("all shipped images must verify");
    }

    #[test]
    fn lint_rejects_an_ill_formed_file_with_exit_error() {
        let dir = std::env::temp_dir().join(format!("nfscan-lint-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.hasm");
        // reads r1 before any write, then falls off the end
        std::fs::write(&path, "start:\n  alu add r0, r1, r1\n").unwrap();
        let a = Args::parse(&argv(&["lint", "--file", path.to_str().unwrap()])).unwrap();
        let err = format!("{}", cmd_lint(&a).unwrap_err());
        assert!(err.contains("rejected"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lint_accepts_a_well_formed_file() {
        let dir = std::env::temp_dir().join(format!("nfscan-lintok-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ok.hasm");
        std::fs::write(&path, "start:\n  ldpkt r0\n  deliver r0\n  halt\n").unwrap();
        let a = Args::parse(&argv(&["lint", "--file", path.to_str().unwrap()])).unwrap();
        cmd_lint(&a).expect("trivial deliver program verifies");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_without_grid_or_config_errors() {
        let a = Args::parse(&argv(&["sweep"])).unwrap();
        let err = cmd_sweep(&a).unwrap_err();
        assert!(format!("{err}").contains("--grid"));
    }

    #[test]
    fn sweep_rejects_typoed_and_conflicting_flags() {
        let a = Args::parse(&argv(&["sweep", "--grid", "figs", "--iter", "5"])).unwrap();
        let err = format!("{}", cmd_sweep(&a).unwrap_err());
        assert!(err.contains("--iter"), "typo must be named: {err}");
        let a = Args::parse(&argv(&["sweep", "--grid", "figs", "--config", "x.toml"])).unwrap();
        let err = format!("{}", cmd_sweep(&a).unwrap_err());
        assert!(err.contains("mutually exclusive"), "{err}");
    }
}
