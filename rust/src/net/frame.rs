//! Frames: what actually travels on a cable, plus MTU fragmentation.
//!
//! The simulator passes structured frames between NICs (parsing on every
//! hop would only burn host CPU), but every frame knows its exact on-wire
//! byte count — serialization time is charged from it — and can be
//! round-tripped through real bytes (`serialize`/`parse`), which the
//! packet-format tests and the failure-injection tests exercise.

use crate::data::{Dtype, Payload};
use crate::packet::{CollPacket, COLL_HDR_LEN};

use super::headers::{
    EthHeader, Ipv4Header, UdpHeader, ETH_HDR_LEN, IPV4_HDR_LEN, UDP_HDR_LEN,
};
use super::{Rank, MTU, NFSCAN_UDP_PORT};

/// Encoded size of the software-MPI message header inside the UDP body.
pub const SW_HDR_LEN: usize = 24;

/// Encoded size of the background-traffic header inside the UDP body.
pub const BG_HDR_LEN: usize = 12;

/// Encoded size of a transport-level ack body ([`RelAck`]).
pub const RELACK_LEN: usize = 12;

/// Encoded size of the reliability shim prepended to the UDP body when a
/// frame carries a nonzero transaction id: magic + pad + 8-byte txn +
/// 4-byte CRC32 over the body (corruption detection — a frame whose CRC
/// fails is counted and dropped by the receiving NIC, and the sender's
/// retransmit timer recovers it).  Only lossy runs pay these bytes —
/// `txn == 0` frames are wire-identical to the pre-fault format.
pub const TXN_SHIM_LEN: usize = 16;

/// Encoded size of a liveness probe body ([`Probe`]).
pub const PROBE_LEN: usize = 12;

/// CRC-32 (IEEE 802.3 polynomial, bitwise) over `bytes` — the check
/// carried in the reliability shim.  Bitwise is plenty: frames are
/// small and the shim only exists on armed (lossy) runs.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Max payload-data bytes per frame: MTU minus IP/UDP/collective headers,
/// rounded down to a multiple of 8 so f64 elements never straddle frames.
/// 1500 - 20 - 8 - 34 = 1438 -> 1432.
pub const CHUNK_BYTES: usize = (MTU - IPV4_HDR_LEN - UDP_HDR_LEN - COLL_HDR_LEN) / 8 * 8;

/// A software-MPI point-to-point message fragment (the baseline path:
/// Open MPI / MPICH over the host stack).
#[derive(Clone, Debug)]
pub struct SwMsg {
    pub src: Rank,
    /// Which software algorithm this message belongs to (wire code of
    /// `packet::AlgoType`).
    pub algo: u16,
    pub kind: SwMsgKind,
    /// Iteration number (back-to-back MPI_Scan calls pipeline; the
    /// receiver must not mix epochs).
    pub epoch: u32,
    /// Algorithm step (recursive-doubling stage / tree level).
    pub step: u16,
    /// Total element count of the whole message.
    pub count: u32,
    pub frag_idx: u16,
    pub frag_total: u16,
    pub payload: Payload,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SwMsgKind {
    /// Sequential chain / recursive-doubling exchange data.
    Data,
    /// Binomial up-phase partial.
    Up,
    /// Binomial down-phase prefix.
    Down,
}

impl SwMsgKind {
    fn wire_code(self) -> u16 {
        match self {
            SwMsgKind::Data => 1,
            SwMsgKind::Up => 2,
            SwMsgKind::Down => 3,
        }
    }

    fn from_wire(v: u16) -> Option<Self> {
        match v {
            1 => Some(SwMsgKind::Data),
            2 => Some(SwMsgKind::Up),
            3 => Some(SwMsgKind::Down),
            _ => None,
        }
    }
}

impl SwMsg {
    pub fn encoded_len(&self) -> usize {
        SW_HDR_LEN + self.payload.byte_len()
    }

    pub fn emit(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(b"SW"); // magic
        out.extend_from_slice(&self.algo.to_be_bytes());
        out.extend_from_slice(&self.kind.wire_code().to_be_bytes());
        out.extend_from_slice(&self.step.to_be_bytes());
        out.extend_from_slice(&(self.src as u16).to_be_bytes());
        out.extend_from_slice(&self.payload.dtype().wire_code().to_be_bytes());
        out.extend_from_slice(&self.epoch.to_be_bytes());
        out.extend_from_slice(&self.count.to_be_bytes());
        out.extend_from_slice(&self.frag_idx.to_be_bytes());
        out.extend_from_slice(&self.frag_total.to_be_bytes());
        out.extend_from_slice(self.payload.bytes());
    }

    pub fn parse(b: &[u8]) -> Option<SwMsg> {
        if b.len() < SW_HDR_LEN || &b[0..2] != b"SW" {
            return None;
        }
        let u16at = |i: usize| u16::from_be_bytes([b[i], b[i + 1]]);
        let u32at = |i: usize| u32::from_be_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]]);
        let dtype = Dtype::from_wire(u16at(10))?;
        let body = &b[SW_HDR_LEN..];
        if body.len() % dtype.size() != 0 {
            return None;
        }
        Some(SwMsg {
            src: u16at(8) as Rank,
            algo: u16at(2),
            kind: SwMsgKind::from_wire(u16at(4))?,
            epoch: u32at(12),
            step: u16at(6),
            count: u32at(16),
            frag_idx: u16at(20),
            frag_total: u16at(22),
            payload: Payload::from_bytes(dtype, body.to_vec()),
        })
    }
}

/// One frame of seeded background point-to-point traffic (the non-MPI
/// tenant load sharing the fabric).  The payload is synthetic — only its
/// length matters for serialization and trunk contention — so the frame
/// carries a byte count, not data.
#[derive(Clone, Debug)]
pub struct BgMsg {
    pub flow: u16,
    pub seq: u32,
    /// Synthetic payload bytes (zeros on the wire).
    pub len: u32,
}

impl BgMsg {
    pub fn encoded_len(&self) -> usize {
        BG_HDR_LEN + self.len as usize
    }

    pub fn emit(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(b"BG"); // magic
        out.extend_from_slice(&self.flow.to_be_bytes());
        out.extend_from_slice(&self.seq.to_be_bytes());
        out.extend_from_slice(&self.len.to_be_bytes());
        out.resize(out.len() + self.len as usize, 0);
    }

    pub fn parse(b: &[u8]) -> Option<BgMsg> {
        if b.len() < BG_HDR_LEN || &b[0..2] != b"BG" {
            return None;
        }
        let flow = u16::from_be_bytes([b[2], b[3]]);
        let seq = u32::from_be_bytes([b[4], b[5], b[6], b[7]]);
        let len = u32::from_be_bytes([b[8], b[9], b[10], b[11]]);
        if b.len() < BG_HDR_LEN + len as usize {
            return None;
        }
        Some(BgMsg { flow, seq, len })
    }
}

/// Transport-level acknowledgement for the NIC reliability protocol:
/// the final destination confirms transaction `txn` end-to-end.  Acks
/// are themselves unreliable (txn 0) — a lost ack just costs one
/// retransmission, which the receiver dedups and re-acks.
#[derive(Clone, Copy, Debug)]
pub struct RelAck {
    pub txn: u64,
}

impl RelAck {
    pub fn encoded_len(&self) -> usize {
        RELACK_LEN
    }

    pub fn emit(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(b"RA"); // magic
        out.extend_from_slice(&[0, 0]);
        out.extend_from_slice(&self.txn.to_be_bytes());
    }

    pub fn parse(b: &[u8]) -> Option<RelAck> {
        if b.len() < RELACK_LEN || &b[0..2] != b"RA" {
            return None;
        }
        let txn = u64::from_be_bytes(b[4..12].try_into().ok()?);
        Some(RelAck { txn })
    }
}

/// NIC-level liveness probe (crash-scheduled runs only): a minimal
/// reliable frame whose end-to-end ack is the only answer — a live peer
/// NIC acks it like any reliable frame, a dead one lets its retransmit
/// timer exhaust, which is the suspicion signal.  `seq` numbers the
/// probes a monitor has sent.
#[derive(Clone, Copy, Debug)]
pub struct Probe {
    pub seq: u64,
}

impl Probe {
    pub fn encoded_len(&self) -> usize {
        PROBE_LEN
    }

    pub fn emit(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(b"PB"); // magic
        out.extend_from_slice(&[0, 0]);
        out.extend_from_slice(&self.seq.to_be_bytes());
    }

    pub fn parse(b: &[u8]) -> Option<Probe> {
        if b.len() < PROBE_LEN || &b[0..2] != b"PB" {
            return None;
        }
        let seq = u64::from_be_bytes(b[4..12].try_into().ok()?);
        Some(Probe { seq })
    }
}

/// The UDP body of a frame.
#[derive(Clone, Debug)]
pub enum FrameBody {
    /// NetFPGA collective-offload traffic (Fig. 1 packets).
    Coll(CollPacket),
    /// Software-MPI baseline traffic.
    Sw(SwMsg),
    /// Background point-to-point traffic (no collective semantics).
    Bg(BgMsg),
    /// Transport-level reliability ack (lossy runs only).
    RelAck(RelAck),
    /// NIC liveness probe (crash-scheduled runs only).
    Probe(Probe),
}

impl FrameBody {
    pub fn encoded_len(&self) -> usize {
        match self {
            FrameBody::Coll(p) => p.encoded_len(),
            FrameBody::Sw(m) => m.encoded_len(),
            FrameBody::Bg(m) => m.encoded_len(),
            FrameBody::RelAck(a) => a.encoded_len(),
            FrameBody::Probe(p) => p.encoded_len(),
        }
    }
}

/// One Ethernet frame in flight.
#[derive(Clone, Debug)]
pub struct Frame {
    pub src: Rank,
    pub dst: Rank,
    pub body: FrameBody,
    /// Reliability transaction id: 0 = unreliable (the pre-fault wire
    /// format, bit for bit), nonzero = tracked by the sender NIC's
    /// timeout/retransmit protocol and acked end-to-end by the
    /// destination.  Assigned by the cluster only on lossy runs.
    pub txn: u64,
    /// Set in flight by a corruption fault: the serialized frame carries
    /// a mangled CRC, and the receiving NIC discards it on the CRC check
    /// (which the retransmit path then recovers).  Never set at
    /// construction; costs nothing when false.
    pub corrupt: bool,
}

impl Frame {
    /// An unreliable frame (txn 0) — every pre-fault construction site.
    pub fn new(src: Rank, dst: Rank, body: FrameBody) -> Frame {
        Frame { src, dst, body, txn: 0, corrupt: false }
    }

    /// Exact bytes this frame occupies from MAC header through UDP body
    /// (excludes preamble/FCS/IFG — see `net::WIRE_OVERHEAD_BYTES`).
    pub fn wire_bytes(&self) -> usize {
        let shim = if self.txn != 0 { TXN_SHIM_LEN } else { 0 };
        // minimum Ethernet payload is 46 bytes (frames are padded on wire)
        let l3 = IPV4_HDR_LEN + UDP_HDR_LEN + shim + self.body.encoded_len();
        ETH_HDR_LEN + l3.max(46)
    }

    /// Full byte serialization (Ethernet + IPv4 + UDP + body) — the frame
    /// exactly as it would appear on the cable, checksums included.
    pub fn serialize(&self) -> Vec<u8> {
        let shim = if self.txn != 0 { TXN_SHIM_LEN } else { 0 };
        let mut body = Vec::with_capacity(shim + self.body.encoded_len());
        if self.txn != 0 {
            body.extend_from_slice(b"TX"); // reliability shim magic
            body.extend_from_slice(&[0, 0]);
            body.extend_from_slice(&self.txn.to_be_bytes());
            body.extend_from_slice(&[0, 0, 0, 0]); // CRC placeholder
        }
        match &self.body {
            FrameBody::Coll(p) => p.emit(&mut body),
            FrameBody::Sw(m) => m.emit(&mut body),
            FrameBody::Bg(m) => m.emit(&mut body),
            FrameBody::RelAck(a) => a.emit(&mut body),
            FrameBody::Probe(p) => p.emit(&mut body),
        }
        if self.txn != 0 {
            let mut crc = crc32(&body[TXN_SHIM_LEN..]);
            if self.corrupt {
                crc ^= 0xA5A5_5A5A; // in-flight bit flips: CRC no longer matches
            }
            body[TXN_SHIM_LEN - 4..TXN_SHIM_LEN].copy_from_slice(&crc.to_be_bytes());
        }
        let mut out = Vec::with_capacity(self.wire_bytes());
        EthHeader::new(self.src, self.dst).emit(&mut out);
        Ipv4Header::new(self.src, self.dst, UDP_HDR_LEN + body.len()).emit(&mut out);
        UdpHeader::new(NFSCAN_UDP_PORT, NFSCAN_UDP_PORT, body.len()).emit(&mut out, &body);
        out
    }

    /// Parse wire bytes back into a frame (inverse of `serialize`).
    pub fn parse(bytes: &[u8]) -> Option<Frame> {
        let (eth, rest) = EthHeader::parse(bytes)?;
        let (ip, rest) = Ipv4Header::parse(rest)?;
        let (udp, _ck, rest) = UdpHeader::parse(rest)?;
        let body_len = (udp.len as usize).checked_sub(UDP_HDR_LEN)?;
        let body_bytes = rest.get(..body_len)?;
        let src = eth.src.to_rank()?;
        let dst = eth.dst.to_rank()?;
        if super::headers::rank_of_ip(ip.src)? != src || super::headers::rank_of_ip(ip.dst)? != dst
        {
            return None; // L2/L3 address mismatch
        }
        let (txn, body_bytes) =
            if body_bytes.len() >= TXN_SHIM_LEN && &body_bytes[0..2] == b"TX" {
                let t = u64::from_be_bytes(body_bytes[4..12].try_into().ok()?);
                if t == 0 {
                    return None; // a shim carrying txn 0 is malformed
                }
                let want = u32::from_be_bytes(body_bytes[12..16].try_into().ok()?);
                if crc32(&body_bytes[TXN_SHIM_LEN..]) != want {
                    return None; // CRC mismatch: corrupt in flight, drop
                }
                (t, &body_bytes[TXN_SHIM_LEN..])
            } else {
                (0, body_bytes)
            };
        let body = if let Some(m) = BgMsg::parse(body_bytes) {
            FrameBody::Bg(m)
        } else if let Some(m) = SwMsg::parse(body_bytes) {
            FrameBody::Sw(m)
        } else if let Some(a) = RelAck::parse(body_bytes) {
            FrameBody::RelAck(a)
        } else if let Some(p) = Probe::parse(body_bytes) {
            FrameBody::Probe(p)
        } else {
            FrameBody::Coll(CollPacket::parse(body_bytes)?)
        };
        Some(Frame { src, dst, body, txn, corrupt: false })
    }
}

/// Split a payload into MTU-sized element chunks.  Returns
/// (frag_idx, frag_total, elem_offset, chunk) per fragment.
pub fn fragment(payload: &Payload) -> Vec<(u16, u16, usize, Payload)> {
    let es = payload.dtype().size();
    let elems_per_chunk = CHUNK_BYTES / es;
    let n = payload.len();
    if n == 0 {
        return vec![(0, 1, 0, payload.clone())];
    }
    let total = n.div_ceil(elems_per_chunk);
    (0..total)
        .map(|i| {
            let start = i * elems_per_chunk;
            let len = elems_per_chunk.min(n - start);
            (i as u16, total as u16, start, payload.slice(start, len))
        })
        .collect()
}

/// Reassemble fragments (must be in-order and complete).
pub fn reassemble(chunks: &[Payload]) -> Payload {
    Payload::concat(chunks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dtype, Op};
    use crate::packet::{AlgoType, CollType, MsgType, NodeType};

    fn sw_msg(n: usize) -> SwMsg {
        SwMsg {
            src: 2,
            algo: AlgoType::Sequential.wire_code(),
            kind: SwMsgKind::Data,
            epoch: 9,
            step: 0,
            count: n as u32,
            frag_idx: 0,
            frag_total: 1,
            payload: Payload::from_i32(&(0..n as i32).collect::<Vec<_>>()),
        }
    }

    #[test]
    fn chunk_bytes_is_mtu_safe_and_aligned() {
        assert!(CHUNK_BYTES % 8 == 0);
        assert!(IPV4_HDR_LEN + UDP_HDR_LEN + COLL_HDR_LEN + CHUNK_BYTES <= MTU);
    }

    #[test]
    fn sw_roundtrip() {
        let m = sw_msg(10);
        let mut buf = Vec::new();
        m.emit(&mut buf);
        let back = SwMsg::parse(&buf).unwrap();
        assert_eq!(back.src, m.src);
        assert_eq!(back.epoch, m.epoch);
        assert_eq!(back.payload, m.payload);
    }

    #[test]
    fn frame_serialize_parse_roundtrip_sw() {
        let f = Frame::new(2, 5, FrameBody::Sw(sw_msg(3)));
        let bytes = f.serialize();
        let back = Frame::parse(&bytes).unwrap();
        assert_eq!(back.src, 2);
        assert_eq!(back.dst, 5);
        match back.body {
            FrameBody::Sw(m) => assert_eq!(m.payload.to_i32(), vec![0, 1, 2]),
            _ => panic!("wrong body"),
        }
    }

    #[test]
    fn frame_serialize_parse_roundtrip_coll() {
        let pkt = CollPacket {
            comm_id: 7,
            comm_size: 8,
            coll_type: CollType::Scan,
            algo_type: AlgoType::BinomialTree,
            node_type: NodeType::Leaf,
            msg_type: MsgType::Data,
            step: 0,
            rank: 1,
            root: 0,
            operation: Op::Sum,
            data_type: Dtype::F64,
            count: 2,
            frag_idx: 0,
            frag_total: 1,
            tag: 0,
            payload: Payload::from_f64(&[1.5, 2.5]),
        };
        let f = Frame::new(1, 3, FrameBody::Coll(pkt));
        let back = Frame::parse(&f.serialize()).unwrap();
        match back.body {
            FrameBody::Coll(p) => assert_eq!(p.payload.to_f64(), vec![1.5, 2.5]),
            _ => panic!("wrong body"),
        }
    }

    #[test]
    fn min_frame_padding() {
        // 4-byte scan payload still occupies a minimum-size frame
        let f = Frame::new(0, 1, FrameBody::Sw(sw_msg(1)));
        let payload_min = 46.max(IPV4_HDR_LEN + UDP_HDR_LEN + SW_HDR_LEN + 4);
        assert_eq!(f.wire_bytes(), ETH_HDR_LEN + payload_min);
    }

    #[test]
    fn fragment_reassemble_roundtrip() {
        let n = 3 * (CHUNK_BYTES / 4) + 17; // 3 full chunks + tail (i32)
        let p = Payload::from_i32(&(0..n as i32).collect::<Vec<_>>());
        let frags = fragment(&p);
        assert_eq!(frags.len(), 4);
        assert_eq!(frags[0].1, 4);
        assert_eq!(frags[3].3.len(), 17);
        // element offsets ascend by chunk size
        assert_eq!(frags[1].2, CHUNK_BYTES / 4);
        let whole = reassemble(&frags.iter().map(|(_, _, _, c)| c.clone()).collect::<Vec<_>>());
        assert_eq!(whole, p);
    }

    #[test]
    fn fragment_empty_payload() {
        let p = Payload::from_i32(&[]);
        let frags = fragment(&p);
        assert_eq!(frags.len(), 1);
        assert_eq!(frags[0].1, 1);
    }

    #[test]
    fn fragment_f64_never_straddles() {
        let n = CHUNK_BYTES / 8 + 1;
        let p = Payload::from_f64(&vec![1.0; n]);
        let frags = fragment(&p);
        assert_eq!(frags.len(), 2);
        assert_eq!(frags[0].3.len(), CHUNK_BYTES / 8);
        assert_eq!(frags[1].3.len(), 1);
    }

    #[test]
    fn frame_serialize_parse_roundtrip_bg() {
        let f =
            Frame::new(4, 6, FrameBody::Bg(BgMsg { flow: 3, seq: 41, len: 700 }));
        assert_eq!(
            f.wire_bytes(),
            ETH_HDR_LEN + IPV4_HDR_LEN + UDP_HDR_LEN + BG_HDR_LEN + 700
        );
        let back = Frame::parse(&f.serialize()).unwrap();
        match back.body {
            FrameBody::Bg(m) => {
                assert_eq!(m.flow, 3);
                assert_eq!(m.seq, 41);
                assert_eq!(m.len, 700);
            }
            _ => panic!("wrong body"),
        }
    }

    #[test]
    fn corrupted_frame_rejected() {
        let f = Frame::new(2, 5, FrameBody::Sw(sw_msg(3)));
        let mut bytes = f.serialize();
        bytes[20] ^= 0xFF; // corrupt IP header
        assert!(Frame::parse(&bytes).is_none());
    }

    #[test]
    fn txn_shim_roundtrips_and_costs_exactly_its_bytes() {
        let plain = Frame::new(2, 5, FrameBody::Sw(sw_msg(100)));
        let mut reliable = plain.clone();
        reliable.txn = 0xDEAD_BEEF;
        // the shim adds exactly its encoded length above padding range
        assert_eq!(reliable.wire_bytes(), plain.wire_bytes() + TXN_SHIM_LEN);
        let back = Frame::parse(&reliable.serialize()).unwrap();
        assert_eq!(back.txn, 0xDEAD_BEEF);
        match back.body {
            FrameBody::Sw(m) => assert_eq!(m.count, 100),
            _ => panic!("wrong body"),
        }
        // txn 0 stays byte-identical to the pre-fault wire format
        let back = Frame::parse(&plain.serialize()).unwrap();
        assert_eq!(back.txn, 0);
    }

    #[test]
    fn crc_shim_detects_in_flight_corruption() {
        let mut f = Frame::new(2, 5, FrameBody::Sw(sw_msg(8)));
        f.txn = 41;
        // clean reliable frame roundtrips through the CRC check
        let back = Frame::parse(&f.serialize()).unwrap();
        assert_eq!(back.txn, 41);
        // a corruption fault mangles the CRC: the receiver rejects it
        f.corrupt = true;
        assert!(Frame::parse(&f.serialize()).is_none(), "bad CRC must be dropped");
        assert_eq!(f.wire_bytes(), {
            let mut clean = f.clone();
            clean.corrupt = false;
            clean.wire_bytes()
        }, "corruption never changes the frame's wire size");
        // flipping a payload byte (not the stored CRC) is also caught
        let clean = {
            let mut c = f.clone();
            c.corrupt = false;
            c
        };
        let mut bytes = clean.serialize();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        assert!(Frame::parse(&bytes).is_none());
    }

    #[test]
    fn probe_roundtrip() {
        let mut f = Frame::new(3, 4, FrameBody::Probe(Probe { seq: 9 }));
        f.txn = 17; // probes are always reliable
        // probes are minimum-size frames even with the shim
        assert_eq!(
            f.wire_bytes(),
            ETH_HDR_LEN + 46.max(IPV4_HDR_LEN + UDP_HDR_LEN + TXN_SHIM_LEN + PROBE_LEN)
        );
        let back = Frame::parse(&f.serialize()).unwrap();
        assert_eq!(back.txn, 17);
        match back.body {
            FrameBody::Probe(p) => assert_eq!(p.seq, 9),
            _ => panic!("wrong body"),
        }
    }

    #[test]
    fn relack_roundtrip() {
        let f = Frame::new(5, 2, FrameBody::RelAck(RelAck { txn: 77 }));
        // acks are minimum-size frames
        assert_eq!(f.wire_bytes(), ETH_HDR_LEN + 46);
        let back = Frame::parse(&f.serialize()).unwrap();
        assert_eq!(back.txn, 0, "acks are themselves unreliable");
        match back.body {
            FrameBody::RelAck(a) => assert_eq!(a.txn, 77),
            _ => panic!("wrong body"),
        }
    }
}
