//! Simulated 1 GbE network substrate.
//!
//! The paper's testbed wires NetFPGA ports directly to each other; this
//! module provides the wire-level pieces: real Ethernet/IPv4/UDP header
//! layouts ([`headers`]), frames and MTU fragmentation ([`frame`]), the
//! physical port graph ([`topology`]) and BFS routing tables ([`routing`])
//! used by the NetFPGA's reference-router forwarding path.

pub mod fault;
pub mod frame;
pub mod headers;
pub mod routing;
pub mod topology;

pub use fault::{parse_crash_spec, parse_drop_spec, CrashSpec, DropRule, FaultPlan, LinkFault};
pub use frame::{BgMsg, Frame, FrameBody, Probe, RelAck, SwMsg, SwMsgKind, CHUNK_BYTES};
pub use headers::{EthHeader, Ipv4Header, MacAddr, UdpHeader};
pub use routing::RouteTable;
pub use topology::{NodeId, Topology};

/// MPI rank / node index.  Hosts and their NetFPGA share the index.
pub type Rank = usize;

/// NetFPGA port number (first-gen card: 4 x 1 GbE).
pub type PortNo = u8;

/// Ports per first-generation NetFPGA card.
pub const PORTS_PER_CARD: usize = 4;

/// Ethernet frame overhead that occupies the wire but not the frame:
/// preamble+SFD (8) + FCS (4) + inter-frame gap (12).
pub const WIRE_OVERHEAD_BYTES: usize = 24;

/// Ethernet MTU (payload bytes available above the 14-byte MAC header).
pub const MTU: usize = 1500;

/// UDP destination port the offload engine listens on (arbitrary but
/// fixed, like the paper's specially-crafted UDP messages).
pub const NFSCAN_UDP_PORT: u16 = 0x4E46; // "NF"
