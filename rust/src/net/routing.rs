//! Next-hop routing tables for the NetFPGA reference-router forwarding
//! path.
//!
//! When the topology doesn't match the algorithm's communication pattern,
//! packets between non-adjacent NICs are store-and-forwarded through
//! intermediate NetFPGAs (the card "maintains the ability to forward
//! standard IP packets") — and, on the hierarchical presets, through
//! switch nodes that never terminate traffic at all.  Routes are
//! shortest-path BFS, tie-broken by port number, so they are
//! deterministic; every flow between two hosts always takes the same
//! single path, which is what makes shared-trunk contention observable.

use std::collections::VecDeque;

use super::topology::Topology;
use super::{PortNo, Rank};

#[derive(Clone, Debug)]
pub struct RouteTable {
    /// `next[node][dst]` = output port at `node` towards rank `dst`.
    /// Rows cover every graph node (switches included); columns only
    /// ranks — frames are never addressed to a switch.
    next: Vec<Vec<Option<PortNo>>>,
}

impl RouteTable {
    /// All-pairs next-hop ports via BFS from every destination rank.
    pub fn build(topo: &Topology) -> RouteTable {
        RouteTable::build_avoiding(topo, &[])
    }

    /// All-pairs next-hop ports via BFS, routing AROUND dead nodes
    /// (fail-stop recovery: a dead switch or rank forwards nothing, so
    /// BFS never expands through it).  `dead` is indexed by node id and
    /// may be shorter than the node count (missing entries = alive);
    /// an empty slice is exactly [`RouteTable::build`].  Dead
    /// destinations keep unreachable (all-None) columns.  Tie-breaking
    /// stays port-ordered, so rebuilt tables are deterministic too.
    pub fn build_avoiding(topo: &Topology, dead: &[bool]) -> RouteTable {
        let nodes = topo.nodes();
        let p = topo.p();
        let is_dead = |n: usize| dead.get(n).copied().unwrap_or(false);
        let mut next = vec![vec![None; p]; nodes];
        let mut dist = vec![usize::MAX; nodes];
        let mut q = VecDeque::new();
        for dst in 0..p {
            if is_dead(dst) {
                continue;
            }
            // BFS outward from dst; the first hop each node uses to reach
            // its BFS parent is its next-hop towards dst.
            dist.iter_mut().for_each(|d| *d = usize::MAX);
            dist[dst] = 0;
            q.clear();
            q.push_back(dst);
            while let Some(u) = q.pop_front() {
                for &(_, v) in topo.neighbors(u) {
                    if dist[v] == usize::MAX && !is_dead(v) {
                        dist[v] = dist[u] + 1;
                        // v reaches dst by sending to u: find v's port to u.
                        // neighbor lookup is port-ordered => deterministic.
                        let port_v = topo.port_towards(v, u).expect("cable is bidirectional");
                        next[v][dst] = Some(port_v);
                        q.push_back(v);
                    }
                }
            }
        }
        RouteTable { next }
    }

    /// Can `src` still reach rank `dst` under this table?  (Trivially
    /// true for src == dst.)  Used for the post-reroute partition check.
    pub fn reaches(&self, src: usize, dst: Rank) -> bool {
        src == dst || self.next[src][dst].is_some()
    }

    /// Output port at `node` for traffic to rank `dst`; None if
    /// unreachable or node == dst (local delivery).
    pub fn next_hop(&self, node: usize, dst: Rank) -> Option<PortNo> {
        if node == dst {
            return None;
        }
        self.next[node][dst]
    }

    /// Hop count from src to dst following the table (for tests/metrics).
    pub fn hops(&self, topo: &Topology, src: usize, dst: Rank) -> Option<usize> {
        let mut cur = src;
        let mut n = 0;
        while cur != dst {
            let port = self.next_hop(cur, dst)?;
            cur = topo.neighbor(cur, port)?.0;
            n += 1;
            if n > topo.nodes() {
                return None; // routing loop — must never happen
            }
        }
        Some(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_routes_linear() {
        let t = Topology::chain(4);
        let r = RouteTable::build(&t);
        assert_eq!(r.next_hop(0, 3), Some(1));
        assert_eq!(r.next_hop(3, 0), Some(0));
        assert_eq!(r.hops(&t, 0, 3), Some(3));
        assert_eq!(r.next_hop(2, 2), None);
    }

    #[test]
    fn hypercube_all_pairs_reachable_shortest() {
        let t = Topology::hypercube(8);
        let r = RouteTable::build(&t);
        for s in 0..8usize {
            for d in 0..8usize {
                if s == d {
                    continue;
                }
                // shortest path in a hypercube = hamming distance
                let want = (s ^ d).count_ones() as usize;
                assert_eq!(r.hops(&t, s, d), Some(want), "{s}->{d}");
            }
        }
    }

    #[test]
    fn ring_takes_short_way() {
        let t = Topology::ring(8);
        let r = RouteTable::build(&t);
        assert_eq!(r.hops(&t, 0, 1), Some(1));
        assert_eq!(r.hops(&t, 0, 7), Some(1), "wraparound is shorter");
        assert_eq!(r.hops(&t, 0, 4), Some(4));
    }

    #[test]
    fn disconnected_unreachable() {
        // two disjoint cables: 0-1, 2-3
        let t = Topology::custom("split", 4, &[((0, 0), (1, 0)), ((2, 0), (3, 0))]);
        let r = RouteTable::build(&t);
        assert_eq!(r.next_hop(0, 2), None);
        assert_eq!(r.hops(&t, 0, 3), None);
    }

    #[test]
    fn star_routes_through_leaf_and_core() {
        let t = Topology::star(10, 4).unwrap();
        let r = RouteTable::build(&t);
        // same leaf: host -> leaf -> host
        assert_eq!(r.hops(&t, 0, 1), Some(2));
        // different leaves: host -> leaf -> core -> leaf -> host
        assert_eq!(r.hops(&t, 0, 9), Some(4));
        // the first hop of any host is its single uplink port
        for h in 0..10usize {
            assert_eq!(r.next_hop(h, (h + 1) % 10), Some(0));
        }
    }

    #[test]
    fn fattree_diameter_and_reachability() {
        // k=4, 16 hosts: same edge 2 hops, same pod 4, cross pod 6
        let t = Topology::fattree(16, 4).unwrap();
        let r = RouteTable::build(&t);
        assert_eq!(r.hops(&t, 0, 1), Some(2), "same edge switch");
        assert_eq!(r.hops(&t, 0, 2), Some(4), "same pod, other edge");
        assert_eq!(r.hops(&t, 0, 15), Some(6), "cross pod");
        for s in 0..16usize {
            for d in 0..16usize {
                if s != d {
                    let h = r.hops(&t, s, d).expect("reachable");
                    assert!(h >= 2 && h <= 6, "{s}->{d} took {h} hops");
                }
            }
        }
    }

    #[test]
    fn reroute_around_dead_switch_on_fattree() {
        let t = Topology::fattree(16, 4).unwrap();
        let alive = RouteTable::build(&t);
        // kill the first hop out of host 0 (its edge switch): hosts under
        // it are cut off, but every other pair reroutes
        let edge0 = t.neighbors(0)[0].1;
        let mut dead = vec![false; t.nodes()];
        dead[edge0] = true;
        let r = RouteTable::build_avoiding(&t, &dead);
        assert!(!r.reaches(0, 2), "host under the dead edge switch is cut off");
        for s in 4..16usize {
            for d in 4..16usize {
                assert!(r.reaches(s, d), "{s}->{d} must survive an edge-switch death");
                if s != d {
                    assert!(r.hops(&t, s, d).is_some());
                }
            }
        }
        // killing an AGGREGATION-layer switch instead cuts nothing off:
        // fat-trees have redundant paths above the edge layer
        let agg = t.neighbors(edge0).iter().map(|&(_, v)| v).find(|&v| v != 0 && t.is_switch(v));
        if let Some(agg) = agg {
            let mut dead = vec![false; t.nodes()];
            dead[agg] = true;
            let r = RouteTable::build_avoiding(&t, &dead);
            for s in 0..16usize {
                for d in 0..16usize {
                    assert!(r.reaches(s, d), "{s}->{d} must reroute around a dead agg switch");
                }
            }
        }
        // empty dead set is exactly build()
        let rebuilt = RouteTable::build_avoiding(&t, &[]);
        for s in 0..t.nodes() {
            for d in 0..16usize {
                assert_eq!(rebuilt.next_hop(s, d), alive.next_hop(s, d));
            }
        }
    }

    #[test]
    fn star_trunk_death_partitions() {
        let t = Topology::star(8, 4).unwrap();
        // kill one leaf switch: its hosts are partitioned from the rest
        let leaf0 = t.neighbors(0)[0].1;
        assert!(t.is_switch(leaf0));
        let mut dead = vec![false; t.nodes()];
        dead[leaf0] = true;
        let r = RouteTable::build_avoiding(&t, &dead);
        assert!(!r.reaches(0, 7), "hosts behind a dead leaf are unreachable");
        assert!(r.reaches(4, 7), "the other leaf's hosts still talk");
    }

    #[test]
    fn ring_reroutes_around_dead_rank() {
        let t = Topology::ring(8);
        let mut dead = vec![false; t.nodes()];
        dead[3] = true;
        let r = RouteTable::build_avoiding(&t, &dead);
        // 2 -> 4 now goes the long way around the ring
        assert!(r.reaches(2, 4));
        assert_eq!(r.hops(&t, 2, 4), Some(6));
        assert!(!r.reaches(0, 3), "dead destination stays unreachable");
    }

    #[test]
    fn tiny_fattree_path_longer_than_p() {
        // k=2 holds exactly 2 hosts but the path is 6 hops — the loop
        // guard must be on node count, not rank count
        let t = Topology::fattree(2, 2).unwrap();
        let r = RouteTable::build(&t);
        assert_eq!(r.hops(&t, 0, 1), Some(6));
    }
}
