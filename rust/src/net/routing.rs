//! Next-hop routing tables for the NetFPGA reference-router forwarding
//! path.
//!
//! When the topology doesn't match the algorithm's communication pattern,
//! packets between non-adjacent NICs are store-and-forwarded through
//! intermediate NetFPGAs (the card "maintains the ability to forward
//! standard IP packets").  Routes are shortest-path BFS, tie-broken by
//! port number, so they are deterministic.

use std::collections::VecDeque;

use super::topology::Topology;
use super::{PortNo, Rank};

#[derive(Clone, Debug)]
pub struct RouteTable {
    /// `next[src][dst]` = output port at `src` towards `dst`.
    next: Vec<Vec<Option<PortNo>>>,
}

impl RouteTable {
    /// All-pairs next-hop ports via BFS from every destination.
    pub fn build(topo: &Topology) -> RouteTable {
        let p = topo.p();
        let mut next = vec![vec![None; p]; p];
        for dst in 0..p {
            // BFS outward from dst; the first hop each node uses to reach
            // its BFS parent is its next-hop towards dst.
            let mut dist = vec![usize::MAX; p];
            dist[dst] = 0;
            let mut q = VecDeque::from([dst]);
            while let Some(u) = q.pop_front() {
                for (port_u, v) in topo.neighbors(u) {
                    if dist[v] == usize::MAX {
                        dist[v] = dist[u] + 1;
                        // v reaches dst by sending to u: find v's port to u.
                        // neighbor lookup is port-ordered => deterministic.
                        let _ = port_u;
                        let port_v = topo.port_towards(v, u).expect("cable is bidirectional");
                        next[v][dst] = Some(port_v);
                        q.push_back(v);
                    }
                }
            }
        }
        RouteTable { next }
    }

    /// Output port at `src` for traffic to `dst`; None if unreachable or
    /// src == dst (local delivery).
    pub fn next_hop(&self, src: Rank, dst: Rank) -> Option<PortNo> {
        if src == dst {
            return None;
        }
        self.next[src][dst]
    }

    /// Hop count from src to dst following the table (for tests/metrics).
    pub fn hops(&self, topo: &Topology, src: Rank, dst: Rank) -> Option<usize> {
        let mut cur = src;
        let mut n = 0;
        while cur != dst {
            let port = self.next_hop(cur, dst)?;
            cur = topo.neighbor(cur, port)?.0;
            n += 1;
            if n > topo.p() {
                return None; // routing loop — must never happen
            }
        }
        Some(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_routes_linear() {
        let t = Topology::chain(4);
        let r = RouteTable::build(&t);
        assert_eq!(r.next_hop(0, 3), Some(1));
        assert_eq!(r.next_hop(3, 0), Some(0));
        assert_eq!(r.hops(&t, 0, 3), Some(3));
        assert_eq!(r.next_hop(2, 2), None);
    }

    #[test]
    fn hypercube_all_pairs_reachable_shortest() {
        let t = Topology::hypercube(8);
        let r = RouteTable::build(&t);
        for s in 0..8usize {
            for d in 0..8usize {
                if s == d {
                    continue;
                }
                // shortest path in a hypercube = hamming distance
                let want = (s ^ d).count_ones() as usize;
                assert_eq!(r.hops(&t, s, d), Some(want), "{s}->{d}");
            }
        }
    }

    #[test]
    fn ring_takes_short_way() {
        let t = Topology::ring(8);
        let r = RouteTable::build(&t);
        assert_eq!(r.hops(&t, 0, 1), Some(1));
        assert_eq!(r.hops(&t, 0, 7), Some(1), "wraparound is shorter");
        assert_eq!(r.hops(&t, 0, 4), Some(4));
    }

    #[test]
    fn disconnected_unreachable() {
        // two disjoint cables: 0-1, 2-3
        let t = Topology::custom("split", 4, &[((0, 0), (1, 0)), ((2, 0), (3, 0))]);
        let r = RouteTable::build(&t);
        assert_eq!(r.next_hop(0, 2), None);
        assert_eq!(r.hops(&t, 0, 3), None);
    }
}
