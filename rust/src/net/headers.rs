//! Real wire layouts for Ethernet II, IPv4 and UDP headers.
//!
//! The paper stresses that result packets "must be properly formed, so that
//! none of the layers prevent the packet from being processed by the
//! application layer" — the NetFPGA stores MAC/IP/UDP fields from the
//! request and regenerates valid headers (including checksums) for the
//! result.  We implement the actual byte layouts and the Internet checksum
//! so that property tests can assert exactly that well-formedness.

use super::Rank;

pub const ETH_HDR_LEN: usize = 14;
pub const IPV4_HDR_LEN: usize = 20;
pub const UDP_HDR_LEN: usize = 8;

pub const ETHERTYPE_IPV4: u16 = 0x0800;
pub const IPPROTO_UDP: u8 = 17;

/// 48-bit MAC address.  Simulated cards use the locally-administered
/// prefix 02:4E:46 ("NF") + the rank.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct MacAddr(pub [u8; 6]);

impl MacAddr {
    pub fn of_rank(rank: Rank) -> MacAddr {
        MacAddr([0x02, 0x4E, 0x46, 0x00, (rank >> 8) as u8, rank as u8])
    }

    /// Rank encoded in a simulated MAC, if it is one of ours.
    pub fn to_rank(self) -> Option<Rank> {
        let b = self.0;
        if b[0] == 0x02 && b[1] == 0x4E && b[2] == 0x46 && b[3] == 0 {
            Some(((b[4] as usize) << 8) | b[5] as usize)
        } else {
            None
        }
    }
}

/// 32-bit IPv4 address; hosts live in 10.78.70.0/24 (78=N, 70=F).
pub fn ip_of_rank(rank: Rank) -> u32 {
    assert!(rank < 254, "rank {rank} does not fit the /24");
    0x0A4E_4600 | (rank as u32 + 1)
}

pub fn rank_of_ip(ip: u32) -> Option<Rank> {
    if ip & 0xFFFF_FF00 == 0x0A4E_4600 && ip & 0xFF != 0 {
        Some((ip & 0xFF) as usize - 1)
    } else {
        None
    }
}

/// RFC 1071 Internet checksum over `data` (pads odd length with zero).
pub fn inet_checksum(data: &[u8]) -> u16 {
    let mut sum: u32 = 0;
    let mut chunks = data.chunks_exact(2);
    for c in &mut chunks {
        sum += u32::from(u16::from_be_bytes([c[0], c[1]]));
    }
    if let [last] = chunks.remainder() {
        sum += u32::from(u16::from_be_bytes([*last, 0]));
    }
    while sum >> 16 != 0 {
        sum = (sum & 0xFFFF) + (sum >> 16);
    }
    !(sum as u16)
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct EthHeader {
    pub dst: MacAddr,
    pub src: MacAddr,
    pub ethertype: u16,
}

impl EthHeader {
    pub fn new(src: Rank, dst: Rank) -> Self {
        EthHeader {
            dst: MacAddr::of_rank(dst),
            src: MacAddr::of_rank(src),
            ethertype: ETHERTYPE_IPV4,
        }
    }

    pub fn emit(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.dst.0);
        out.extend_from_slice(&self.src.0);
        out.extend_from_slice(&self.ethertype.to_be_bytes());
    }

    pub fn parse(b: &[u8]) -> Option<(EthHeader, &[u8])> {
        if b.len() < ETH_HDR_LEN {
            return None;
        }
        let hdr = EthHeader {
            dst: MacAddr(b[0..6].try_into().unwrap()),
            src: MacAddr(b[6..12].try_into().unwrap()),
            ethertype: u16::from_be_bytes([b[12], b[13]]),
        };
        Some((hdr, &b[ETH_HDR_LEN..]))
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Ipv4Header {
    pub tos: u8,
    pub total_len: u16,
    pub ident: u16,
    pub flags_frag: u16,
    pub ttl: u8,
    pub protocol: u8,
    pub src: u32,
    pub dst: u32,
}

impl Ipv4Header {
    pub fn new(src: Rank, dst: Rank, payload_len: usize) -> Self {
        Ipv4Header {
            tos: 0,
            total_len: (IPV4_HDR_LEN + payload_len) as u16,
            ident: 0,
            flags_frag: 0x4000, // DF: fragmentation happens above, in chunks
            ttl: 64,
            protocol: IPPROTO_UDP,
            src: ip_of_rank(src),
            dst: ip_of_rank(dst),
        }
    }

    /// Serialize with a correct header checksum.
    pub fn emit(&self, out: &mut Vec<u8>) {
        let start = out.len();
        out.push(0x45); // version 4, IHL 5
        out.push(self.tos);
        out.extend_from_slice(&self.total_len.to_be_bytes());
        out.extend_from_slice(&self.ident.to_be_bytes());
        out.extend_from_slice(&self.flags_frag.to_be_bytes());
        out.push(self.ttl);
        out.push(self.protocol);
        out.extend_from_slice(&[0, 0]); // checksum placeholder
        out.extend_from_slice(&self.src.to_be_bytes());
        out.extend_from_slice(&self.dst.to_be_bytes());
        let ck = inet_checksum(&out[start..start + IPV4_HDR_LEN]);
        out[start + 10..start + 12].copy_from_slice(&ck.to_be_bytes());
    }

    /// Parse and verify version/IHL + checksum.
    pub fn parse(b: &[u8]) -> Option<(Ipv4Header, &[u8])> {
        if b.len() < IPV4_HDR_LEN || b[0] != 0x45 {
            return None;
        }
        if inet_checksum(&b[..IPV4_HDR_LEN]) != 0 {
            return None; // corrupted header
        }
        let hdr = Ipv4Header {
            tos: b[1],
            total_len: u16::from_be_bytes([b[2], b[3]]),
            ident: u16::from_be_bytes([b[4], b[5]]),
            flags_frag: u16::from_be_bytes([b[6], b[7]]),
            ttl: b[8],
            protocol: b[9],
            src: u32::from_be_bytes(b[12..16].try_into().unwrap()),
            dst: u32::from_be_bytes(b[16..20].try_into().unwrap()),
        };
        Some((hdr, &b[IPV4_HDR_LEN..]))
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct UdpHeader {
    pub src_port: u16,
    pub dst_port: u16,
    pub len: u16,
}

impl UdpHeader {
    pub fn new(src_port: u16, dst_port: u16, payload_len: usize) -> Self {
        UdpHeader { src_port, dst_port, len: (UDP_HDR_LEN + payload_len) as u16 }
    }

    pub fn emit(&self, out: &mut Vec<u8>, payload: &[u8]) {
        out.extend_from_slice(&self.src_port.to_be_bytes());
        out.extend_from_slice(&self.dst_port.to_be_bytes());
        out.extend_from_slice(&self.len.to_be_bytes());
        // UDP checksum over header+payload with zero placeholder (pseudo-
        // header omitted: links are point-to-point and IP already checks
        // addressing; 0xFFFF means "computed", never 0 = disabled).
        let mut tmp = Vec::with_capacity(UDP_HDR_LEN + payload.len());
        tmp.extend_from_slice(&self.src_port.to_be_bytes());
        tmp.extend_from_slice(&self.dst_port.to_be_bytes());
        tmp.extend_from_slice(&self.len.to_be_bytes());
        tmp.extend_from_slice(&[0, 0]);
        tmp.extend_from_slice(payload);
        let ck = inet_checksum(&tmp);
        out.extend_from_slice(&ck.to_be_bytes());
        out.extend_from_slice(payload);
    }

    pub fn parse(b: &[u8]) -> Option<(UdpHeader, u16, &[u8])> {
        if b.len() < UDP_HDR_LEN {
            return None;
        }
        let hdr = UdpHeader {
            src_port: u16::from_be_bytes([b[0], b[1]]),
            dst_port: u16::from_be_bytes([b[2], b[3]]),
            len: u16::from_be_bytes([b[4], b[5]]),
        };
        let ck = u16::from_be_bytes([b[6], b[7]]);
        Some((hdr, ck, &b[UDP_HDR_LEN..]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_rank_roundtrip() {
        for r in [0usize, 1, 7, 255, 300] {
            assert_eq!(MacAddr::of_rank(r).to_rank(), Some(r));
        }
        assert_eq!(MacAddr([0xFF; 6]).to_rank(), None);
    }

    #[test]
    fn ip_rank_roundtrip() {
        for r in 0..16 {
            assert_eq!(rank_of_ip(ip_of_rank(r)), Some(r));
        }
        assert_eq!(rank_of_ip(0x0101_0101), None);
    }

    #[test]
    fn checksum_verifies_to_zero() {
        // A checksummed buffer re-checksums to 0 (RFC 1071 property).
        let mut h = Ipv4Header::new(0, 1, 100);
        h.ident = 0x1234;
        let mut buf = Vec::new();
        h.emit(&mut buf);
        assert_eq!(inet_checksum(&buf), 0);
    }

    #[test]
    fn ipv4_roundtrip_and_corruption_detected() {
        let h = Ipv4Header::new(2, 5, 64);
        let mut buf = Vec::new();
        h.emit(&mut buf);
        let (parsed, rest) = Ipv4Header::parse(&buf).unwrap();
        assert_eq!(parsed, h);
        assert!(rest.is_empty());

        let mut bad = buf.clone();
        bad[15] ^= 0x40; // flip a bit in src ip
        assert!(Ipv4Header::parse(&bad).is_none(), "checksum must catch corruption");
    }

    #[test]
    fn eth_roundtrip() {
        let h = EthHeader::new(3, 4);
        let mut buf = Vec::new();
        h.emit(&mut buf);
        buf.extend_from_slice(b"payload");
        let (parsed, rest) = EthHeader::parse(&buf).unwrap();
        assert_eq!(parsed, h);
        assert_eq!(rest, b"payload");
    }

    #[test]
    fn udp_roundtrip() {
        let payload = b"scan data";
        let h = UdpHeader::new(4000, super::super::NFSCAN_UDP_PORT, payload.len());
        let mut buf = Vec::new();
        h.emit(&mut buf, payload);
        let (parsed, ck, rest) = UdpHeader::parse(&buf).unwrap();
        assert_eq!(parsed.len as usize, UDP_HDR_LEN + payload.len());
        assert_ne!(ck, 0);
        assert_eq!(rest, payload, "emit appends the datagram body");
    }

    #[test]
    fn odd_length_checksum() {
        assert_eq!(inet_checksum(&[0xFF]), !0xFF00u16);
    }
}
