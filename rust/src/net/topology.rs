//! Physical port graph: how the NetFPGA cards — and, past the paper's
//! 4-node ceiling, the switches between them — are wired together.
//!
//! The paper: "The NetFPGA ports were directly connected to each other
//! establishing a testbed topology" — and admits the node roles / wiring
//! are manually configured per algorithm.  We provide the wirings each
//! algorithm wants (chain for sequential, hypercube for recursive
//! doubling / binomial) plus a ring, and let experiments deliberately
//! mismatch them to measure the multi-hop forwarding penalty.
//!
//! The paper names scaling as open work (SSVI): one 4-port card per host
//! caps the direct wirings at toy sizes.  The hierarchical presets lift
//! that cap by adding *switch nodes* — graph nodes `p..p+switches` that
//! carry no rank and no host, only forward frames:
//!
//! - `star:<g>` — leaf switches of `g` hosts each, all uplinked to one
//!   core switch (two-level tree; every inter-leaf flow shares the leaf's
//!   single trunk, so trunk contention is the interesting failure mode);
//! - `fattree:<k>` — the classic k-ary fat-tree (k pods of k/2 edge +
//!   k/2 aggregation switches, (k/2)^2 cores; up to k^3/4 hosts, filled
//!   in pod order when p is smaller).
//!
//! Hosts in hierarchical presets use exactly one NIC port (port 0), so a
//! first-generation card always suffices — that is the point.

use std::collections::BTreeMap;

use super::{PortNo, Rank, PORTS_PER_CARD};

/// A graph node: ranks are `0..p`, switches are `p..p+switches`.
pub type NodeId = usize;

#[derive(Clone, Debug)]
pub struct Topology {
    p: usize,
    switches: usize,
    name: String,
    /// (node, port) -> (node, port) for every plugged cable, both ways.
    adj: BTreeMap<(NodeId, PortNo), (NodeId, PortNo)>,
    /// Per-node adjacency, port-ordered (deterministic iteration without
    /// walking the whole map — the BFS route build is O(V+E) per
    /// destination because of this).
    nbr: Vec<Vec<(PortNo, NodeId)>>,
}

impl Topology {
    /// Checked assembly shared by every preset.  `cables` endpoints may
    /// reference switch nodes (`>= p`); errors name the offending cable.
    fn assemble(
        name: &str,
        p: usize,
        switches: usize,
        cables: &[((NodeId, PortNo), (NodeId, PortNo))],
    ) -> Result<Topology, String> {
        let nodes = p + switches;
        let mut adj = BTreeMap::new();
        for &(a, b) in cables {
            if a.0 >= nodes || b.0 >= nodes {
                return Err(format!(
                    "cable endpoint node out of range: {a:?} <-> {b:?} (nodes = {nodes})"
                ));
            }
            if a.0 == b.0 {
                return Err(format!("self-loop cable on node {}", a.0));
            }
            if adj.contains_key(&a) {
                return Err(format!("port {a:?} already cabled"));
            }
            if adj.contains_key(&b) {
                return Err(format!("port {b:?} already cabled"));
            }
            adj.insert(a, b);
            adj.insert(b, a);
        }
        let mut nbr: Vec<Vec<(PortNo, NodeId)>> = vec![Vec::new(); nodes];
        for (&(node, port), &(peer, _)) in &adj {
            // BTreeMap iteration is (node, port)-ordered, so each list
            // comes out port-sorted.
            nbr[node].push((port, peer));
        }
        Ok(Topology { p, switches, name: name.to_string(), adj, nbr })
    }

    /// Build from explicit rank-to-rank cables.  Panics on port reuse or
    /// self-loops — a miswired testbed should fail loudly at construction.
    pub fn custom(name: &str, p: usize, cables: &[((Rank, PortNo), (Rank, PortNo))]) -> Topology {
        for &(a, b) in cables {
            assert!(a.0 < p && b.0 < p, "cable endpoint rank out of range");
        }
        Topology::assemble(name, p, 0, cables).unwrap_or_else(|e| panic!("{name}: {e}"))
    }

    /// Line: rank j port 1 <-> rank j+1 port 0.  Sequential algorithm's
    /// natural wiring (every j, j+1 one hop apart).
    pub fn chain(p: usize) -> Topology {
        let cables: Vec<_> = (0..p.saturating_sub(1)).map(|j| ((j, 1), (j + 1, 0))).collect();
        Topology::custom("chain", p, &cables)
    }

    /// Chain + wraparound cable.
    pub fn ring(p: usize) -> Topology {
        assert!(p >= 3, "ring needs >= 3 nodes");
        let mut cables: Vec<_> = (0..p - 1).map(|j| ((j, 1), (j + 1, 0))).collect();
        cables.push(((p - 1, 1), (0, 0)));
        Topology::custom("ring", p, &cables)
    }

    /// Boolean hypercube: rank j port k <-> rank j^2^k port k.  Natural
    /// wiring for recursive doubling and the binomial tree (every
    /// partner/parent differs in exactly one bit).  Dimension > 4 exceeds
    /// the first-gen card's 4 ports; `fits_card` reports that.
    pub fn hypercube(p: usize) -> Topology {
        assert!(crate::util::is_pow2(p) && p >= 2, "hypercube needs power-of-two nodes");
        let dim = crate::util::log2(p) as u8;
        let mut cables = Vec::new();
        for j in 0..p {
            for k in 0..dim {
                let peer = j ^ (1 << k);
                if j < peer {
                    cables.push(((j, k), (peer, k)));
                }
            }
        }
        Topology::custom("hypercube", p, &cables)
    }

    /// Star-of-switches: `ceil(p/group)` leaf switches of up to `group`
    /// hosts each, all uplinked to one core switch.  Degenerates to a
    /// single switch when one leaf suffices.  Host h sits on leaf h/group
    /// port h%group; each host uses NIC port 0 only.
    pub fn star(p: usize, group: usize) -> Result<Topology, String> {
        if group == 0 {
            return Err("star group size must be >= 1".into());
        }
        if group > PortNo::MAX as usize {
            return Err(format!("star group {group} exceeds the port-number range"));
        }
        let leaves = p.div_ceil(group);
        if leaves > PortNo::MAX as usize {
            return Err(format!(
                "star needs {leaves} leaf switches for p={p}, exceeding the core port range"
            ));
        }
        let mut cables: Vec<((NodeId, PortNo), (NodeId, PortNo))> = Vec::new();
        if leaves == 1 {
            // one switch, every host attached directly
            let sw = p;
            for h in 0..p {
                cables.push(((h, 0), (sw, h as PortNo)));
            }
            return Topology::assemble(&format!("star:{group}"), p, 1, &cables);
        }
        let leaf = |l: usize| p + l;
        let core = p + leaves;
        for h in 0..p {
            cables.push(((h, 0), (leaf(h / group), (h % group) as PortNo)));
        }
        for l in 0..leaves {
            // leaf trunk: one uplink port shared by every flow leaving it
            cables.push(((leaf(l), group as PortNo), (core, l as PortNo)));
        }
        Topology::assemble(&format!("star:{group}"), p, leaves + 1, &cables)
    }

    /// k-ary fat-tree (Leiserson / Al-Fares): k pods, each with k/2 edge
    /// and k/2 aggregation switches; (k/2)^2 core switches; capacity
    /// k^3/4 hosts.  `p` may be below capacity — hosts fill in pod order
    /// and surplus edge ports dangle.  All switches have radix k.
    pub fn fattree(p: usize, k: usize) -> Result<Topology, String> {
        if k < 2 || k % 2 != 0 {
            return Err(format!("fat-tree arity k={k} must be even and >= 2"));
        }
        if k > 64 {
            return Err(format!("fat-tree arity k={k} is unreasonably large (max 64)"));
        }
        let half = k / 2;
        let capacity = k * k * k / 4;
        if p > capacity {
            return Err(format!("fat-tree k={k} holds at most {capacity} hosts, got p={p}"));
        }
        let hosts_per_pod = half * half;
        // node numbering: pod x holds edges then aggs at p + x*k;
        // cores follow after all pods.
        let edge = |x: usize, e: usize| p + x * k + e;
        let agg = |x: usize, a: usize| p + x * k + half + a;
        let core = |c: usize| p + k * k + c;
        let switches = k * k + half * half;
        let mut cables: Vec<((NodeId, PortNo), (NodeId, PortNo))> = Vec::new();
        for h in 0..p {
            let x = h / hosts_per_pod;
            let e = (h % hosts_per_pod) / half;
            let slot = h % half;
            cables.push(((h, 0), (edge(x, e), slot as PortNo)));
        }
        for x in 0..k {
            for e in 0..half {
                for a in 0..half {
                    // edge uplink a <-> agg a's down port e
                    cables.push(((edge(x, e), (half + a) as PortNo), (agg(x, a), e as PortNo)));
                }
            }
            for a in 0..half {
                for i in 0..half {
                    // agg a reaches core group a; core port = pod index
                    cables.push(((agg(x, a), (half + i) as PortNo), (core(a * half + i), x as PortNo)));
                }
            }
        }
        Topology::assemble(&format!("fattree:{k}"), p, switches, &cables)
    }

    /// Smallest even arity whose fat-tree holds `p` hosts.
    pub fn fattree_arity_for(p: usize) -> usize {
        let mut k = 2;
        while k * k * k / 4 < p {
            k += 2;
        }
        k
    }

    /// Parse and build a topology spec: `chain`, `ring`, `hypercube`,
    /// `star[:group]` (group defaults to 4, one leaf port per host slot),
    /// `fattree[:k]` (k defaults to the smallest even arity holding p).
    /// Errors describe both unknown names and p-incompatible presets.
    pub fn build(spec: &str, p: usize) -> Result<Topology, String> {
        let (base, param) = match spec.split_once(':') {
            Some((b, v)) => {
                let v: usize = v
                    .parse()
                    .map_err(|e| format!("topology {spec:?}: bad parameter {v:?}: {e}"))?;
                (b, Some(v))
            }
            None => (spec, None),
        };
        match base {
            "chain" => {
                if param.is_some() {
                    return Err("chain takes no parameter".into());
                }
                Ok(Topology::chain(p))
            }
            "ring" => {
                if param.is_some() {
                    return Err("ring takes no parameter".into());
                }
                if p < 3 {
                    return Err(format!("ring needs >= 3 nodes, got p={p}"));
                }
                Ok(Topology::ring(p))
            }
            "hypercube" => {
                if param.is_some() {
                    return Err("hypercube takes no parameter".into());
                }
                if !crate::util::is_pow2(p) || p < 2 {
                    return Err(format!("hypercube needs power-of-two nodes, got p={p}"));
                }
                Ok(Topology::hypercube(p))
            }
            "star" => Topology::star(p, param.unwrap_or(4)),
            "fattree" => {
                let k = param.unwrap_or_else(|| Topology::fattree_arity_for(p));
                Topology::fattree(p, k)
            }
            other => Err(format!(
                "unknown topology {other:?} (chain|ring|hypercube|star[:g]|fattree[:k])"
            )),
        }
    }

    pub fn by_name(name: &str, p: usize) -> Option<Topology> {
        Topology::build(name, p).ok()
    }

    /// Number of ranks (hosts).  Switch nodes are NOT counted here.
    pub fn p(&self) -> usize {
        self.p
    }

    /// Switch nodes in the graph (0 for the direct-wired presets).
    pub fn switches(&self) -> usize {
        self.switches
    }

    /// Total graph nodes: ranks then switches.
    pub fn nodes(&self) -> usize {
        self.p + self.switches
    }

    /// Is this node a switch (forwards only, hosts no rank)?
    pub fn is_switch(&self, node: NodeId) -> bool {
        node >= self.p
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Other end of the cable plugged into (node, port), if any.
    pub fn neighbor(&self, node: NodeId, port: PortNo) -> Option<(NodeId, PortNo)> {
        self.adj.get(&(node, port)).copied()
    }

    /// Direct port from `node` towards `dst`, if they share a cable.
    pub fn port_towards(&self, node: NodeId, dst: NodeId) -> Option<PortNo> {
        self.nbr[node].iter().find(|&&(_, peer)| peer == dst).map(|&(port, _)| port)
    }

    /// All (port, neighbor) pairs of `node`, port-ordered (determinism).
    /// Borrowed, not cloned — the BFS route build walks this per visit.
    pub fn neighbors(&self, node: NodeId) -> &[(PortNo, NodeId)] {
        &self.nbr[node]
    }

    /// Ports in use at one node (highest cabled port + 1).
    pub fn ports_of(&self, node: NodeId) -> usize {
        self.nbr[node].last().map(|&(port, _)| port as usize + 1).unwrap_or(0)
    }

    /// Highest port number used by any node, +1.
    pub fn ports_used(&self) -> usize {
        (0..self.nodes()).map(|n| self.ports_of(n)).max().unwrap_or(0)
    }

    /// Does the wiring fit a first-generation NetFPGA (4 ports) at every
    /// HOST?  Switch radix is unconstrained — switches are not cards.
    pub fn fits_card(&self) -> bool {
        (0..self.p).all(|r| self.ports_of(r) <= PORTS_PER_CARD)
    }

    /// Highest port count over the switches that directly attach hosts
    /// (the leaf tier; 0 when switchless).  Star leaves are NetFPGA-class
    /// boxes in the paper's world, so the CLI warns when this exceeds
    /// [`PORTS_PER_CARD`] on a `star:g` — the core/aggregation tiers are
    /// real switches with unconstrained radix and are excluded.
    pub fn max_leaf_radix(&self) -> usize {
        (self.p..self.nodes())
            .filter(|&sw| self.nbr[sw].iter().any(|&(_, peer)| peer < self.p))
            .map(|sw| self.ports_of(sw))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_adjacency() {
        let t = Topology::chain(4);
        assert_eq!(t.neighbor(0, 1), Some((1, 0)));
        assert_eq!(t.neighbor(1, 1), Some((2, 0)));
        assert_eq!(t.neighbor(0, 0), None, "head has no upstream");
        assert_eq!(t.port_towards(2, 1), Some(0));
        assert!(t.fits_card());
        assert_eq!(t.switches(), 0);
        assert_eq!(t.nodes(), 4);
    }

    #[test]
    fn ring_wraps() {
        let t = Topology::ring(4);
        assert_eq!(t.neighbor(3, 1), Some((0, 0)));
        assert_eq!(t.port_towards(0, 3), Some(0));
    }

    #[test]
    fn hypercube_partners_one_hop() {
        let t = Topology::hypercube(8);
        for j in 0..8usize {
            for k in 0..3u8 {
                let peer = j ^ (1 << k);
                assert_eq!(t.neighbor(j, k), Some((peer, k)), "rank {j} dim {k}");
                assert_eq!(t.port_towards(j, peer), Some(k));
            }
        }
        assert!(t.fits_card(), "3-cube uses 3 of 4 ports");
        assert!(!Topology::hypercube(32).fits_card(), "5-cube exceeds the card");
    }

    #[test]
    fn neighbors_sorted_by_port() {
        let t = Topology::hypercube(8);
        let n = t.neighbors(5);
        assert_eq!(n, &[(0, 4), (1, 7), (2, 1)]);
    }

    #[test]
    #[should_panic]
    fn port_reuse_rejected() {
        Topology::custom("bad", 3, &[((0, 0), (1, 0)), ((0, 0), (2, 0))]);
    }

    #[test]
    #[should_panic]
    fn self_loop_rejected() {
        Topology::custom("bad", 2, &[((0, 0), (0, 1))]);
    }

    #[test]
    fn star_two_level_shape() {
        // 10 hosts in groups of 4: 3 leaves + 1 core
        let t = Topology::star(10, 4).unwrap();
        assert_eq!(t.p(), 10);
        assert_eq!(t.switches(), 4);
        assert_eq!(t.nodes(), 14);
        assert!(t.fits_card(), "hosts use one port each");
        for h in 0..10usize {
            let up = t.neighbor(h, 0).expect("host uplink");
            assert_eq!(up.0, 10 + h / 4, "host {h} on its leaf");
            assert!(t.is_switch(up.0));
        }
        // each leaf's trunk lands on the core (node 13) at the leaf index
        for l in 0..3usize {
            assert_eq!(t.neighbor(10 + l, 4), Some((13, l as PortNo)));
        }
        // leaf 0 is full (4 hosts + trunk), leaf 2 holds hosts 8..10
        assert_eq!(t.ports_of(10), 5);
        assert_eq!(t.ports_of(13), 3, "core has one port per leaf");
    }

    #[test]
    fn max_leaf_radix_reports_host_facing_fan_in_only() {
        assert_eq!(Topology::chain(4).max_leaf_radix(), 0, "switchless");
        // star:4 leaves carry 4 hosts + the trunk = radix 5
        assert_eq!(Topology::star(10, 4).unwrap().max_leaf_radix(), 5);
        // star:3 leaves fit a 4-port card (3 hosts + trunk)
        assert_eq!(Topology::star(9, 3).unwrap().max_leaf_radix(), 4);
        // the core switch (radix = leaf count) is NOT a card: a big
        // star:3 stays clean even with 22 leaves on the core
        assert_eq!(Topology::star(64, 3).unwrap().max_leaf_radix(), 4);
        // degenerate single-switch star: the one switch attaches hosts
        assert_eq!(Topology::star(6, 8).unwrap().max_leaf_radix(), 6);
    }

    #[test]
    fn star_degenerates_to_single_switch() {
        let t = Topology::star(4, 8).unwrap();
        assert_eq!(t.switches(), 1);
        for h in 0..4usize {
            assert_eq!(t.neighbor(h, 0), Some((4, h as PortNo)));
        }
    }

    #[test]
    fn fattree_shape_k4() {
        // k=4: 16 hosts, 4 pods x (2 edge + 2 agg), 4 cores = 20 switches
        let t = Topology::fattree(16, 4).unwrap();
        assert_eq!(t.p(), 16);
        assert_eq!(t.switches(), 20);
        assert_eq!(t.nodes(), 36);
        assert!(t.fits_card());
        // every switch has radix k = 4
        for sw in 16..36usize {
            assert_eq!(t.ports_of(sw), 4, "switch {sw}");
        }
        // host 0: pod 0 edge 0 slot 0
        assert_eq!(t.neighbor(0, 0), Some((16, 0)));
        // host 5: pod 1 (hosts_per_pod = 4), edge 0, slot 1
        assert_eq!(t.neighbor(5, 0), Some((16 + 4, 1)));
    }

    #[test]
    fn fattree_partial_population() {
        // 6 hosts on the 16-host k=4 tree: all switches built, hosts
        // fill pods 0 and 1 only
        let t = Topology::fattree(6, 4).unwrap();
        assert_eq!(t.switches(), 20);
        for h in 0..6usize {
            assert!(t.neighbor(h, 0).is_some(), "host {h} attached");
        }
    }

    #[test]
    fn fattree_arity_selection() {
        assert_eq!(Topology::fattree_arity_for(2), 2);
        assert_eq!(Topology::fattree_arity_for(16), 4);
        assert_eq!(Topology::fattree_arity_for(17), 6);
        assert_eq!(Topology::fattree_arity_for(128), 8);
        assert_eq!(Topology::fattree_arity_for(256), 12);
    }

    #[test]
    fn build_parses_specs() {
        assert_eq!(Topology::build("chain", 5).unwrap().name(), "chain");
        assert_eq!(Topology::build("star", 10).unwrap().name(), "star:4");
        assert_eq!(Topology::build("star:2", 10).unwrap().switches(), 6);
        assert_eq!(Topology::build("fattree", 8).unwrap().name(), "fattree:4");
        assert_eq!(Topology::build("fattree:6", 54).unwrap().p(), 54);
        assert!(Topology::build("fattree:3", 8).is_err(), "odd arity");
        assert!(Topology::build("fattree:4", 17).is_err(), "over capacity");
        assert!(Topology::build("ring", 2).is_err());
        assert!(Topology::build("hypercube", 6).is_err());
        assert!(Topology::build("warp", 8).is_err());
        assert!(Topology::build("star:x", 8).is_err());
        assert!(Topology::build("chain:2", 8).is_err());
    }
}
