//! Physical port graph: how the NetFPGA cards are wired together.
//!
//! The paper: "The NetFPGA ports were directly connected to each other
//! establishing a testbed topology" — and admits the node roles / wiring
//! are manually configured per algorithm.  We provide the wirings each
//! algorithm wants (chain for sequential, hypercube for recursive
//! doubling / binomial) plus a ring, and let experiments deliberately
//! mismatch them to measure the multi-hop forwarding penalty.

use std::collections::BTreeMap;

use super::{PortNo, Rank, PORTS_PER_CARD};

#[derive(Clone, Debug)]
pub struct Topology {
    p: usize,
    name: String,
    /// (rank, port) -> (rank, port) for every plugged cable, both ways.
    adj: BTreeMap<(Rank, PortNo), (Rank, PortNo)>,
}

impl Topology {
    /// Build from explicit cables.  Panics on port reuse or self-loops —
    /// a miswired testbed should fail loudly at construction.
    pub fn custom(name: &str, p: usize, cables: &[((Rank, PortNo), (Rank, PortNo))]) -> Topology {
        let mut adj = BTreeMap::new();
        for &(a, b) in cables {
            assert!(a.0 < p && b.0 < p, "cable endpoint rank out of range");
            assert_ne!(a.0, b.0, "self-loop cable on rank {}", a.0);
            assert!(!adj.contains_key(&a), "port {a:?} already cabled");
            assert!(!adj.contains_key(&b), "port {b:?} already cabled");
            adj.insert(a, b);
            adj.insert(b, a);
        }
        Topology { p, name: name.to_string(), adj }
    }

    /// Line: rank j port 1 <-> rank j+1 port 0.  Sequential algorithm's
    /// natural wiring (every j, j+1 one hop apart).
    pub fn chain(p: usize) -> Topology {
        let cables: Vec<_> = (0..p.saturating_sub(1)).map(|j| ((j, 1), (j + 1, 0))).collect();
        Topology::custom("chain", p, &cables)
    }

    /// Chain + wraparound cable.
    pub fn ring(p: usize) -> Topology {
        assert!(p >= 3, "ring needs >= 3 nodes");
        let mut cables: Vec<_> = (0..p - 1).map(|j| ((j, 1), (j + 1, 0))).collect();
        cables.push(((p - 1, 1), (0, 0)));
        Topology::custom("ring", p, &cables)
    }

    /// Boolean hypercube: rank j port k <-> rank j^2^k port k.  Natural
    /// wiring for recursive doubling and the binomial tree (every
    /// partner/parent differs in exactly one bit).  Dimension > 4 exceeds
    /// the first-gen card's 4 ports; `strict_ports` rejects that.
    pub fn hypercube(p: usize) -> Topology {
        assert!(crate::util::is_pow2(p) && p >= 2, "hypercube needs power-of-two nodes");
        let dim = crate::util::log2(p) as u8;
        let mut cables = Vec::new();
        for j in 0..p {
            for k in 0..dim {
                let peer = j ^ (1 << k);
                if j < peer {
                    cables.push(((j, k), (peer, k)));
                }
            }
        }
        Topology::custom("hypercube", p, &cables)
    }

    pub fn by_name(name: &str, p: usize) -> Option<Topology> {
        match name {
            "chain" => Some(Topology::chain(p)),
            "ring" => Some(Topology::ring(p)),
            "hypercube" => Some(Topology::hypercube(p)),
            _ => None,
        }
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Other end of the cable plugged into (rank, port), if any.
    pub fn neighbor(&self, rank: Rank, port: PortNo) -> Option<(Rank, PortNo)> {
        self.adj.get(&(rank, port)).copied()
    }

    /// Direct port from `rank` towards `dst`, if they share a cable.
    pub fn port_towards(&self, rank: Rank, dst: Rank) -> Option<PortNo> {
        self.adj
            .iter()
            .find(|&(&(r, _), &(nr, _))| r == rank && nr == dst)
            .map(|(&(_, port), _)| port)
    }

    /// All (port, neighbor) pairs of `rank`, port-ordered (determinism).
    pub fn neighbors(&self, rank: Rank) -> Vec<(PortNo, Rank)> {
        self.adj
            .iter()
            .filter(|&(&(r, _), _)| r == rank)
            .map(|(&(_, port), &(nr, _))| (port, nr))
            .collect()
    }

    /// Highest port number used by any node, +1.
    pub fn ports_used(&self) -> usize {
        self.adj.keys().map(|&(_, port)| port as usize + 1).max().unwrap_or(0)
    }

    /// Does the wiring fit a first-generation NetFPGA (4 ports)?
    pub fn fits_card(&self) -> bool {
        self.ports_used() <= PORTS_PER_CARD
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_adjacency() {
        let t = Topology::chain(4);
        assert_eq!(t.neighbor(0, 1), Some((1, 0)));
        assert_eq!(t.neighbor(1, 1), Some((2, 0)));
        assert_eq!(t.neighbor(0, 0), None, "head has no upstream");
        assert_eq!(t.port_towards(2, 1), Some(0));
        assert!(t.fits_card());
    }

    #[test]
    fn ring_wraps() {
        let t = Topology::ring(4);
        assert_eq!(t.neighbor(3, 1), Some((0, 0)));
        assert_eq!(t.port_towards(0, 3), Some(0));
    }

    #[test]
    fn hypercube_partners_one_hop() {
        let t = Topology::hypercube(8);
        for j in 0..8usize {
            for k in 0..3u8 {
                let peer = j ^ (1 << k);
                assert_eq!(t.neighbor(j, k), Some((peer, k)), "rank {j} dim {k}");
                assert_eq!(t.port_towards(j, peer), Some(k));
            }
        }
        assert!(t.fits_card(), "3-cube uses 3 of 4 ports");
        assert!(!Topology::hypercube(32).fits_card(), "5-cube exceeds the card");
    }

    #[test]
    fn neighbors_sorted_by_port() {
        let t = Topology::hypercube(8);
        let n = t.neighbors(5);
        assert_eq!(n, vec![(0, 4), (1, 7), (2, 1)]);
    }

    #[test]
    #[should_panic]
    fn port_reuse_rejected() {
        Topology::custom("bad", 3, &[((0, 0), (1, 0)), ((0, 0), (2, 0))]);
    }

    #[test]
    #[should_panic]
    fn self_loop_rejected() {
        Topology::custom("bad", 2, &[((0, 0), (0, 1))]);
    }
}
