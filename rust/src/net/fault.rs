//! Deterministic hostile-network fault model.
//!
//! Everything here is driven by the per-job seed: random per-hop packet
//! loss (`loss`), explicit drop schedules replayable from TOML
//! (`drop = "src->dst:nth"`), frame corruption and reordering schedules
//! (`corrupt` / `reorder`, same rule syntax), fail-stop crash schedules
//! (`crash = "rank:3@epoch:2, switch:1@ns:5000"`), and degraded trunk
//! bandwidth (`trunk_degrade`).  The plan is consulted once per frame
//! hop in `Cluster::transmit_on_port`; a quiet plan (loss 0, no rules,
//! no crashes, degrade 1.0) is never consulted at all, so fault-free
//! runs keep the pre-fault event schedule — and the golden figure
//! bytes — byte-identical.
//!
//! Link-rule syntax (one rule per comma-separated entry, bare or as a
//! TOML string array), shared by `drop`, `corrupt` and `reorder`:
//!
//! - `"3->1:2"` — hit the 2nd frame transmitted on the directed
//!   physical link from node 3 to node 1 (nodes >= p are switches);
//! - `"0->*:1"` — hit the 1st frame node 0 transmits on ANY link
//!   (wildcard destination — the easy way to guarantee a fault without
//!   knowing the topology's wiring).
//!
//! `nth` is 1-based and counts every frame on the edge, retransmissions
//! and transport acks included — so a schedule can kill the same frame
//! repeatedly (`"0->1:1, 0->1:2, ..."`) to exhaust `max_retries`.  The
//! three rule kinds share ONE per-edge counter: the 3rd frame on a link
//! is the 3rd frame, whether a rule drops, corrupts or reorders it
//! (drop wins over corrupt wins over reorder if several match).
//!
//! Crash-schedule syntax (comma-separated, bare or TOML array):
//!
//! - `"rank:3@epoch:2"` — rank 3 fail-stops (host and NIC die) the
//!   instant it would start collective epoch 2;
//! - `"switch:1@ns:5000"` — the topology's switch #1 (node id `p + 1`)
//!   dies at simulated time 5000 ns.

use std::collections::HashMap;

use crate::sim::SplitMix64;

/// One scheduled deterministic link fault: the `nth` (1-based) frame on
/// the directed edge `src -> dst`, or on any edge out of `src` when
/// `dst` is the wildcard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DropRule {
    pub src: usize,
    /// `None` = wildcard destination (`src->*:nth`).
    pub dst: Option<usize>,
    /// 1-based frame ordinal on the matched edge/source.
    pub nth: u64,
}

/// What the plan does to one frame hop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkFault {
    /// Frame vanishes in flight (scheduled drop or seeded loss coin).
    Drop,
    /// Frame arrives with a flipped payload: the receiver's CRC check
    /// rejects it, so it behaves like a drop that costs delivery time.
    Corrupt,
    /// Frame is held back in flight and delivered late, behind frames
    /// transmitted after it.
    Reorder,
}

/// One scheduled fail-stop crash.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashSpec {
    /// `rank:R@epoch:E` — rank R's host and NIC die when the rank would
    /// start collective epoch E (0-based iteration index).
    Rank { rank: usize, epoch: u32 },
    /// `switch:S@ns:T` — switch #S (0-based, node id `p + S`) dies at
    /// simulated time T ns.
    Switch { switch: usize, at_ns: u64 },
}

fn clean_list(spec: &str) -> String {
    spec.chars().filter(|c| !matches!(c, '[' | ']' | '"' | '\'')).collect()
}

/// Parse a link-fault schedule: comma-separated `src->dst:nth` rules,
/// with `*` as a wildcard destination.  Accepts both the bare form
/// (`"0->1:1, 2->*:3"`) and the raw bracketed TOML-array form
/// (`["0->1:1", "2->*:3"]`) — the mini-TOML parser hands list values
/// through as their source text.  `knob` names the schedule in errors
/// (`drop` / `corrupt` / `reorder`).
pub fn parse_link_spec(spec: &str, knob: &str) -> Result<Vec<DropRule>, String> {
    let mut rules = Vec::new();
    let cleaned = clean_list(spec);
    for part in cleaned.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (edge, nth) = part
            .split_once(':')
            .ok_or_else(|| format!("{knob} rule '{part}': expected src->dst:nth"))?;
        let (src, dst) = edge
            .split_once("->")
            .ok_or_else(|| format!("{knob} rule '{part}': expected src->dst:nth"))?;
        let src: usize =
            src.trim().parse().map_err(|e| format!("{knob} rule '{part}': bad src: {e}"))?;
        let dst = match dst.trim() {
            "*" => None,
            d => Some(d.parse().map_err(|e| format!("{knob} rule '{part}': bad dst: {e}"))?),
        };
        let nth: u64 =
            nth.trim().parse().map_err(|e| format!("{knob} rule '{part}': bad nth: {e}"))?;
        if nth == 0 {
            return Err(format!("{knob} rule '{part}': nth is 1-based, 0 never matches"));
        }
        rules.push(DropRule { src, dst, nth });
    }
    Ok(rules)
}

/// Parse a drop schedule (see [`parse_link_spec`]).
pub fn parse_drop_spec(spec: &str) -> Result<Vec<DropRule>, String> {
    parse_link_spec(spec, "drop")
}

/// Parse a fail-stop crash schedule: comma-separated
/// `rank:R@epoch:E` / `switch:S@ns:T` entries, bare or as a TOML
/// string array.
pub fn parse_crash_spec(spec: &str) -> Result<Vec<CrashSpec>, String> {
    let mut crashes = Vec::new();
    let cleaned = clean_list(spec);
    for part in cleaned.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (who, when) = part.split_once('@').ok_or_else(|| {
            format!("crash rule '{part}': expected rank:R@epoch:E or switch:S@ns:T")
        })?;
        let (kind, idx) = who
            .split_once(':')
            .ok_or_else(|| format!("crash rule '{part}': expected rank:R or switch:S"))?;
        let idx: usize = idx
            .trim()
            .parse()
            .map_err(|e| format!("crash rule '{part}': bad index: {e}"))?;
        let (wkey, wval) = when
            .split_once(':')
            .ok_or_else(|| format!("crash rule '{part}': expected @epoch:E or @ns:T"))?;
        match (kind.trim(), wkey.trim()) {
            ("rank", "epoch") => {
                let epoch: u32 = wval
                    .trim()
                    .parse()
                    .map_err(|e| format!("crash rule '{part}': bad epoch: {e}"))?;
                crashes.push(CrashSpec::Rank { rank: idx, epoch });
            }
            ("switch", "ns") => {
                let at_ns: u64 = wval
                    .trim()
                    .parse()
                    .map_err(|e| format!("crash rule '{part}': bad ns: {e}"))?;
                crashes.push(CrashSpec::Switch { switch: idx, at_ns });
            }
            ("rank", k) => {
                return Err(format!("crash rule '{part}': rank crashes take @epoch:E, not @{k}"));
            }
            ("switch", k) => {
                return Err(format!("crash rule '{part}': switch crashes take @ns:T, not @{k}"));
            }
            (k, _) => {
                return Err(format!("crash rule '{part}': unknown component '{k}' (rank|switch)"));
            }
        }
    }
    Ok(crashes)
}

/// The per-run fault plan: seeded loss draws, scheduled drops /
/// corruptions / reorders, fail-stop crashes and trunk degradation,
/// plus the per-edge frame counters the schedules match against.
pub struct FaultPlan {
    /// Per-hop loss probability in [0, 1) for reliable-protocol frames.
    pub loss: f64,
    /// Bandwidth multiplier on switch-node (trunk) transmissions; 1.0
    /// means full rate and is never applied.
    pub trunk_degrade: f64,
    rules: Vec<DropRule>,
    corrupt_rules: Vec<DropRule>,
    reorder_rules: Vec<DropRule>,
    crashes: Vec<CrashSpec>,
    rng: SplitMix64,
    /// Frames seen per directed edge (counting starts at 1).
    edge_seen: HashMap<(usize, usize), u64>,
    /// Frames seen per source node (for wildcard rules).
    src_seen: HashMap<usize, u64>,
    /// Total frames this plan has dropped (diagnostics).
    pub drops_injected: u64,
    /// Total frames this plan has corrupted (diagnostics).
    pub corruptions_injected: u64,
    /// Total frames this plan has reordered (diagnostics).
    pub reorders_injected: u64,
}

impl FaultPlan {
    pub fn new(
        loss: f64,
        drop_spec: &str,
        trunk_degrade: f64,
        seed: u64,
    ) -> Result<FaultPlan, String> {
        if !(0.0..1.0).contains(&loss) {
            return Err(format!("loss {loss} must be in [0, 1)"));
        }
        if !trunk_degrade.is_finite() || trunk_degrade < 1.0 {
            return Err(format!("trunk_degrade {trunk_degrade} must be >= 1.0"));
        }
        Ok(FaultPlan {
            loss,
            trunk_degrade,
            rules: parse_drop_spec(drop_spec)?,
            corrupt_rules: Vec::new(),
            reorder_rules: Vec::new(),
            crashes: Vec::new(),
            // forked off the job seed so the fault stream never perturbs
            // the jitter / payload / background draws
            rng: SplitMix64::new(seed ^ 0xFAD7_1A11),
            edge_seen: HashMap::new(),
            src_seen: HashMap::new(),
            drops_injected: 0,
            corruptions_injected: 0,
            reorders_injected: 0,
        })
    }

    /// Attach the fail-stop / corruption / reorder schedules (empty
    /// strings are no-ops, keeping the plan quiet).
    pub fn with_failures(
        mut self,
        crash_spec: &str,
        corrupt_spec: &str,
        reorder_spec: &str,
    ) -> Result<FaultPlan, String> {
        self.crashes = parse_crash_spec(crash_spec)?;
        self.corrupt_rules = parse_link_spec(corrupt_spec, "corrupt")?;
        self.reorder_rules = parse_link_spec(reorder_spec, "reorder")?;
        Ok(self)
    }

    /// A quiet plan that is never consulted (the default).
    pub fn quiet(seed: u64) -> FaultPlan {
        FaultPlan::new(0.0, "", 1.0, seed).expect("quiet plan is always valid")
    }

    /// Does this plan ever lose, damage or kill anything?  Only lossy
    /// plans arm the timeout/retransmit protocol (txn ids, acks,
    /// timers) and — when crashes are scheduled — the heartbeat probes;
    /// a non-lossy plan leaves the wire format and event schedule
    /// untouched.  Crash schedules count: detecting a dead peer rides
    /// on the same ack/timeout machinery.
    pub fn lossy(&self) -> bool {
        self.loss > 0.0
            || !self.rules.is_empty()
            || !self.corrupt_rules.is_empty()
            || !self.reorder_rules.is_empty()
            || !self.crashes.is_empty()
    }

    /// Does this plan schedule any fail-stop crash?  Arms heartbeat
    /// probes, liveness tracking and the degrade-don't-hang machinery.
    pub fn has_crashes(&self) -> bool {
        !self.crashes.is_empty()
    }

    /// The epoch at which `rank` is scheduled to fail-stop, if any.
    pub fn rank_crash_epoch(&self, rank: usize) -> Option<u32> {
        self.crashes.iter().find_map(|c| match c {
            CrashSpec::Rank { rank: r, epoch } if *r == rank => Some(*epoch),
            _ => None,
        })
    }

    /// All scheduled switch crashes as `(switch_index, at_ns)` pairs.
    pub fn switch_crashes(&self) -> Vec<(usize, u64)> {
        self.crashes
            .iter()
            .filter_map(|c| match c {
                CrashSpec::Switch { switch, at_ns } => Some((*switch, *at_ns)),
                _ => None,
            })
            .collect()
    }

    /// Largest rank index named by a rank-crash rule (validation).
    pub fn max_crash_rank(&self) -> Option<usize> {
        self.crashes
            .iter()
            .filter_map(|c| match c {
                CrashSpec::Rank { rank, .. } => Some(*rank),
                _ => None,
            })
            .max()
    }

    /// Largest switch index named by a switch-crash rule (validation).
    pub fn max_crash_switch(&self) -> Option<usize> {
        self.crashes
            .iter()
            .filter_map(|c| match c {
                CrashSpec::Switch { switch, .. } => Some(*switch),
                _ => None,
            })
            .max()
    }

    /// Does this plan slow trunk links down?
    pub fn degrades(&self) -> bool {
        self.trunk_degrade != 1.0
    }

    /// Scale one trunk transmission's serialization time.
    pub fn scaled_tx_ns(&self, tx_ns: u64) -> u64 {
        (tx_ns as f64 * self.trunk_degrade) as u64
    }

    /// Consult the plan for one frame hop on the directed edge
    /// `src -> dst`.  Counts the hop, applies scheduled rules first
    /// (deterministic, no RNG draw; drop beats corrupt beats reorder),
    /// then the seeded loss coin.  Only call when [`FaultPlan::lossy`]
    /// — every call advances counters.
    pub fn link_fault(&mut self, src: usize, dst: usize) -> Option<LinkFault> {
        let edge_n = {
            let c = self.edge_seen.entry((src, dst)).or_insert(0);
            *c += 1;
            *c
        };
        let src_n = {
            let c = self.src_seen.entry(src).or_insert(0);
            *c += 1;
            *c
        };
        let hit = |rules: &[DropRule]| {
            rules.iter().any(|r| {
                r.src == src
                    && match r.dst {
                        Some(d) => d == dst && r.nth == edge_n,
                        None => r.nth == src_n,
                    }
            })
        };
        if hit(&self.rules) {
            self.drops_injected += 1;
            return Some(LinkFault::Drop);
        }
        if hit(&self.corrupt_rules) {
            self.corruptions_injected += 1;
            return Some(LinkFault::Corrupt);
        }
        if hit(&self.reorder_rules) {
            self.reorders_injected += 1;
            return Some(LinkFault::Reorder);
        }
        if self.loss > 0.0 && self.rng.next_f64() < self.loss {
            self.drops_injected += 1;
            return Some(LinkFault::Drop);
        }
        None
    }

    /// Legacy drop-only view of [`FaultPlan::link_fault`] (kept for the
    /// PR 8 call sites and tests; corrupt/reorder hits return false but
    /// still advance the shared counters).
    pub fn should_drop(&mut self, src: usize, dst: usize) -> bool {
        matches!(self.link_fault(src, dst), Some(LinkFault::Drop))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_bare_and_bracketed_forms() {
        let bare = parse_drop_spec("0->1:1, 2->*:3").unwrap();
        let toml = parse_drop_spec(r#"["0->1:1", "2->*:3"]"#).unwrap();
        assert_eq!(bare, toml);
        assert_eq!(bare[0], DropRule { src: 0, dst: Some(1), nth: 1 });
        assert_eq!(bare[1], DropRule { src: 2, dst: None, nth: 3 });
        assert!(parse_drop_spec("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_rules() {
        assert!(parse_drop_spec("0-1:1").is_err());
        assert!(parse_drop_spec("0->1").is_err());
        assert!(parse_drop_spec("a->1:1").is_err());
        assert!(parse_drop_spec("0->1:0").is_err(), "nth is 1-based");
    }

    #[test]
    fn parse_crash_bare_and_bracketed_forms() {
        let bare = parse_crash_spec("rank:3@epoch:2, switch:1@ns:5000").unwrap();
        let toml = parse_crash_spec(r#"["rank:3@epoch:2", "switch:1@ns:5000"]"#).unwrap();
        assert_eq!(bare, toml);
        assert_eq!(bare[0], CrashSpec::Rank { rank: 3, epoch: 2 });
        assert_eq!(bare[1], CrashSpec::Switch { switch: 1, at_ns: 5000 });
        assert!(parse_crash_spec("").unwrap().is_empty());
    }

    #[test]
    fn parse_crash_rejects_malformed_rules() {
        assert!(parse_crash_spec("rank:3").is_err(), "missing @when");
        assert!(parse_crash_spec("rank:3@ns:5").is_err(), "ranks die at epochs");
        assert!(parse_crash_spec("switch:1@epoch:2").is_err(), "switches die at ns");
        assert!(parse_crash_spec("host:1@epoch:2").is_err(), "unknown component");
        assert!(parse_crash_spec("rank:x@epoch:2").is_err());
        assert!(parse_crash_spec("rank:3@epoch:x").is_err());
    }

    #[test]
    fn crash_plan_accessors() {
        let p = FaultPlan::quiet(1)
            .with_failures("rank:3@epoch:2, switch:1@ns:5000, rank:5@epoch:0", "", "")
            .unwrap();
        assert!(p.lossy(), "crash schedules arm the reliable protocol");
        assert!(p.has_crashes());
        assert_eq!(p.rank_crash_epoch(3), Some(2));
        assert_eq!(p.rank_crash_epoch(5), Some(0));
        assert_eq!(p.rank_crash_epoch(0), None);
        assert_eq!(p.switch_crashes(), vec![(1, 5000)]);
        assert_eq!(p.max_crash_rank(), Some(5));
        assert_eq!(p.max_crash_switch(), Some(1));
    }

    #[test]
    fn scheduled_drop_hits_exactly_the_nth_frame() {
        let mut p = FaultPlan::new(0.0, "3->1:2", 1.0, 7).unwrap();
        assert!(p.lossy());
        assert!(!p.should_drop(3, 1), "1st frame passes");
        assert!(p.should_drop(3, 1), "2nd frame dropped");
        assert!(!p.should_drop(3, 1), "3rd frame passes");
        assert!(!p.should_drop(1, 3), "reverse edge counts separately");
        assert_eq!(p.drops_injected, 1);
    }

    #[test]
    fn corrupt_and_reorder_rules_share_the_edge_counter() {
        let mut p =
            FaultPlan::new(0.0, "", 1.0, 7).unwrap().with_failures("", "0->1:2", "0->1:3").unwrap();
        assert!(p.lossy(), "corrupt/reorder schedules arm the reliable protocol");
        assert!(!p.has_crashes());
        assert_eq!(p.link_fault(0, 1), None, "1st frame clean");
        assert_eq!(p.link_fault(0, 1), Some(LinkFault::Corrupt), "2nd corrupted");
        assert_eq!(p.link_fault(0, 1), Some(LinkFault::Reorder), "3rd reordered");
        assert_eq!(p.link_fault(0, 1), None, "4th clean");
        assert_eq!(p.corruptions_injected, 1);
        assert_eq!(p.reorders_injected, 1);
        assert_eq!(p.drops_injected, 0);
    }

    #[test]
    fn drop_beats_corrupt_beats_reorder_on_one_frame() {
        let mut p = FaultPlan::new(0.0, "0->1:1", 1.0, 7)
            .unwrap()
            .with_failures("", "0->1:1", "0->1:1")
            .unwrap();
        assert_eq!(p.link_fault(0, 1), Some(LinkFault::Drop));
        assert_eq!(p.drops_injected, 1);
        assert_eq!(p.corruptions_injected, 0);
    }

    #[test]
    fn wildcard_counts_across_all_destinations() {
        let mut p = FaultPlan::new(0.0, "0->*:3", 1.0, 7).unwrap();
        assert!(!p.should_drop(0, 1));
        assert!(!p.should_drop(0, 2));
        assert!(p.should_drop(0, 5), "3rd frame out of node 0, any edge");
    }

    #[test]
    fn random_loss_is_seed_deterministic() {
        let run = |seed| {
            let mut p = FaultPlan::new(0.25, "", 1.0, seed).unwrap();
            (0..200).map(|i| p.should_drop(i % 4, (i + 1) % 4)).collect::<Vec<_>>()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
        let drops = run(42).iter().filter(|&&d| d).count();
        assert!(drops > 10 && drops < 100, "≈25% of 200: got {drops}");
    }

    #[test]
    fn quiet_plan_is_not_lossy_and_validation_rejects_bad_knobs() {
        let p = FaultPlan::quiet(1);
        assert!(!p.lossy());
        assert!(!p.degrades());
        assert!(!p.has_crashes());
        assert!(FaultPlan::new(1.0, "", 1.0, 1).is_err(), "loss must stay below 1");
        assert!(FaultPlan::new(-0.1, "", 1.0, 1).is_err());
        assert!(FaultPlan::new(0.0, "", 0.5, 1).is_err(), "degrade < 1 would speed trunks up");
        assert!(FaultPlan::quiet(1).with_failures("rank:1@epoch", "", "").is_err());
        assert!(FaultPlan::quiet(1).with_failures("", "0->1", "").is_err());
        assert!(FaultPlan::quiet(1).with_failures("", "", "0->1:0").is_err());
    }

    #[test]
    fn trunk_degrade_scales_tx() {
        let p = FaultPlan::new(0.0, "", 2.5, 1).unwrap();
        assert!(p.degrades());
        assert_eq!(p.scaled_tx_ns(1000), 2500);
        assert!(!p.lossy(), "degradation alone does not arm the retransmit protocol");
    }
}
