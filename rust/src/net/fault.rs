//! Deterministic hostile-network fault model.
//!
//! Everything here is driven by the per-job seed: random per-hop packet
//! loss (`loss`), explicit drop schedules replayable from TOML
//! (`drop = "src->dst:nth"`), and degraded trunk bandwidth
//! (`trunk_degrade`).  The plan is consulted once per frame hop in
//! `Cluster::transmit_on_port`; a quiet plan (loss 0, no rules, degrade
//! 1.0) is never consulted at all, so fault-free runs keep the
//! pre-fault event schedule — and the golden figure bytes —
//! byte-identical.
//!
//! Drop-schedule syntax (one rule per comma-separated entry, bare or as
//! a TOML string array):
//!
//! - `"3->1:2"` — drop the 2nd frame transmitted on the directed
//!   physical link from node 3 to node 1 (nodes >= p are switches);
//! - `"0->*:1"` — drop the 1st frame node 0 transmits on ANY link
//!   (wildcard destination — the easy way to guarantee a loss without
//!   knowing the topology's wiring).
//!
//! `nth` is 1-based and counts every frame on the edge, retransmissions
//! and transport acks included — so a schedule can kill the same frame
//! repeatedly (`"0->1:1, 0->1:2, ..."`) to exhaust `max_retries`.

use std::collections::HashMap;

use crate::sim::SplitMix64;

/// One scheduled deterministic drop: the `nth` (1-based) frame on the
/// directed edge `src -> dst`, or on any edge out of `src` when `dst`
/// is the wildcard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DropRule {
    pub src: usize,
    /// `None` = wildcard destination (`src->*:nth`).
    pub dst: Option<usize>,
    /// 1-based frame ordinal on the matched edge/source.
    pub nth: u64,
}

/// Parse a drop schedule: comma-separated `src->dst:nth` rules, with
/// `*` as a wildcard destination.  Accepts both the bare form
/// (`"0->1:1, 2->*:3"`) and the raw bracketed TOML-array form
/// (`["0->1:1", "2->*:3"]`) — the mini-TOML parser hands list values
/// through as their source text.
pub fn parse_drop_spec(spec: &str) -> Result<Vec<DropRule>, String> {
    let mut rules = Vec::new();
    let cleaned: String =
        spec.chars().filter(|c| !matches!(c, '[' | ']' | '"' | '\'')).collect();
    for part in cleaned.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (edge, nth) = part
            .split_once(':')
            .ok_or_else(|| format!("drop rule '{part}': expected src->dst:nth"))?;
        let (src, dst) = edge
            .split_once("->")
            .ok_or_else(|| format!("drop rule '{part}': expected src->dst:nth"))?;
        let src: usize =
            src.trim().parse().map_err(|e| format!("drop rule '{part}': bad src: {e}"))?;
        let dst = match dst.trim() {
            "*" => None,
            d => Some(d.parse().map_err(|e| format!("drop rule '{part}': bad dst: {e}"))?),
        };
        let nth: u64 =
            nth.trim().parse().map_err(|e| format!("drop rule '{part}': bad nth: {e}"))?;
        if nth == 0 {
            return Err(format!("drop rule '{part}': nth is 1-based, 0 never matches"));
        }
        rules.push(DropRule { src, dst, nth });
    }
    Ok(rules)
}

/// The per-run fault plan: seeded loss draws, scheduled drops and trunk
/// degradation, plus the per-edge frame counters the schedules match
/// against.
pub struct FaultPlan {
    /// Per-hop loss probability in [0, 1) for reliable-protocol frames.
    pub loss: f64,
    /// Bandwidth multiplier on switch-node (trunk) transmissions; 1.0
    /// means full rate and is never applied.
    pub trunk_degrade: f64,
    rules: Vec<DropRule>,
    rng: SplitMix64,
    /// Frames seen per directed edge (counting starts at 1).
    edge_seen: HashMap<(usize, usize), u64>,
    /// Frames seen per source node (for wildcard rules).
    src_seen: HashMap<usize, u64>,
    /// Total frames this plan has dropped (diagnostics).
    pub drops_injected: u64,
}

impl FaultPlan {
    pub fn new(
        loss: f64,
        drop_spec: &str,
        trunk_degrade: f64,
        seed: u64,
    ) -> Result<FaultPlan, String> {
        if !(0.0..1.0).contains(&loss) {
            return Err(format!("loss {loss} must be in [0, 1)"));
        }
        if !trunk_degrade.is_finite() || trunk_degrade < 1.0 {
            return Err(format!("trunk_degrade {trunk_degrade} must be >= 1.0"));
        }
        Ok(FaultPlan {
            loss,
            trunk_degrade,
            rules: parse_drop_spec(drop_spec)?,
            // forked off the job seed so the fault stream never perturbs
            // the jitter / payload / background draws
            rng: SplitMix64::new(seed ^ 0xFAD7_1A11),
            edge_seen: HashMap::new(),
            src_seen: HashMap::new(),
            drops_injected: 0,
        })
    }

    /// A quiet plan that is never consulted (the default).
    pub fn quiet(seed: u64) -> FaultPlan {
        FaultPlan::new(0.0, "", 1.0, seed).expect("quiet plan is always valid")
    }

    /// Does this plan ever drop frames?  Only lossy plans arm the
    /// timeout/retransmit protocol (txn ids, acks, timers) — a non-lossy
    /// plan leaves the wire format and event schedule untouched.
    pub fn lossy(&self) -> bool {
        self.loss > 0.0 || !self.rules.is_empty()
    }

    /// Does this plan slow trunk links down?
    pub fn degrades(&self) -> bool {
        self.trunk_degrade != 1.0
    }

    /// Scale one trunk transmission's serialization time.
    pub fn scaled_tx_ns(&self, tx_ns: u64) -> u64 {
        (tx_ns as f64 * self.trunk_degrade) as u64
    }

    /// Consult the plan for one frame hop on the directed edge
    /// `src -> dst`.  Counts the hop, applies scheduled drops first
    /// (deterministic, no RNG draw), then the seeded loss coin.  Only
    /// call when [`FaultPlan::lossy`] — every call advances counters.
    pub fn should_drop(&mut self, src: usize, dst: usize) -> bool {
        let edge_n = {
            let c = self.edge_seen.entry((src, dst)).or_insert(0);
            *c += 1;
            *c
        };
        let src_n = {
            let c = self.src_seen.entry(src).or_insert(0);
            *c += 1;
            *c
        };
        let scheduled = self.rules.iter().any(|r| {
            r.src == src
                && match r.dst {
                    Some(d) => d == dst && r.nth == edge_n,
                    None => r.nth == src_n,
                }
        });
        if scheduled {
            self.drops_injected += 1;
            return true;
        }
        if self.loss > 0.0 && self.rng.next_f64() < self.loss {
            self.drops_injected += 1;
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_bare_and_bracketed_forms() {
        let bare = parse_drop_spec("0->1:1, 2->*:3").unwrap();
        let toml = parse_drop_spec(r#"["0->1:1", "2->*:3"]"#).unwrap();
        assert_eq!(bare, toml);
        assert_eq!(bare[0], DropRule { src: 0, dst: Some(1), nth: 1 });
        assert_eq!(bare[1], DropRule { src: 2, dst: None, nth: 3 });
        assert!(parse_drop_spec("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_malformed_rules() {
        assert!(parse_drop_spec("0-1:1").is_err());
        assert!(parse_drop_spec("0->1").is_err());
        assert!(parse_drop_spec("a->1:1").is_err());
        assert!(parse_drop_spec("0->1:0").is_err(), "nth is 1-based");
    }

    #[test]
    fn scheduled_drop_hits_exactly_the_nth_frame() {
        let mut p = FaultPlan::new(0.0, "3->1:2", 1.0, 7).unwrap();
        assert!(p.lossy());
        assert!(!p.should_drop(3, 1), "1st frame passes");
        assert!(p.should_drop(3, 1), "2nd frame dropped");
        assert!(!p.should_drop(3, 1), "3rd frame passes");
        assert!(!p.should_drop(1, 3), "reverse edge counts separately");
        assert_eq!(p.drops_injected, 1);
    }

    #[test]
    fn wildcard_counts_across_all_destinations() {
        let mut p = FaultPlan::new(0.0, "0->*:3", 1.0, 7).unwrap();
        assert!(!p.should_drop(0, 1));
        assert!(!p.should_drop(0, 2));
        assert!(p.should_drop(0, 5), "3rd frame out of node 0, any edge");
    }

    #[test]
    fn random_loss_is_seed_deterministic() {
        let run = |seed| {
            let mut p = FaultPlan::new(0.25, "", 1.0, seed).unwrap();
            (0..200).map(|i| p.should_drop(i % 4, (i + 1) % 4)).collect::<Vec<_>>()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
        let drops = run(42).iter().filter(|&&d| d).count();
        assert!(drops > 10 && drops < 100, "≈25% of 200: got {drops}");
    }

    #[test]
    fn quiet_plan_is_not_lossy_and_validation_rejects_bad_knobs() {
        let p = FaultPlan::quiet(1);
        assert!(!p.lossy());
        assert!(!p.degrades());
        assert!(FaultPlan::new(1.0, "", 1.0, 1).is_err(), "loss must stay below 1");
        assert!(FaultPlan::new(-0.1, "", 1.0, 1).is_err());
        assert!(FaultPlan::new(0.0, "", 0.5, 1).is_err(), "degrade < 1 would speed trunks up");
    }

    #[test]
    fn trunk_degrade_scales_tx() {
        let p = FaultPlan::new(0.0, "", 2.5, 1).unwrap();
        assert!(p.degrades());
        assert_eq!(p.scaled_tx_ns(1000), 2500);
        assert!(!p.lossy(), "degradation alone does not arm the retransmit protocol");
    }
}
