//! Software MPI_Allreduce / MPI_Barrier — recursive-doubling butterfly
//! (MPICH's default for small messages), run on the host.  The baseline
//! the authors' companion works [6][7] compare their offloads against.

use std::collections::HashMap;

use crate::data::Payload;
use crate::net::{Rank, SwMsg, SwMsgKind};
use crate::packet::{AlgoType, CollType};
use crate::util::{is_pow2, log2};

use super::{SwAction, SwCtx, SwScanAlgo};

pub struct SwRdAllreduce {
    rank: Rank,
    logp: u16,
    called: bool,
    step: u16,
    value: Option<Payload>,
    sent: Vec<bool>,
    inbox: HashMap<u16, Payload>,
    completed: bool,
}

impl SwRdAllreduce {
    pub fn new(rank: Rank, p: usize, coll: CollType) -> SwRdAllreduce {
        assert!(is_pow2(p), "recursive doubling needs power-of-two ranks");
        assert!(matches!(coll, CollType::Allreduce | CollType::Barrier));
        SwRdAllreduce {
            rank,
            logp: log2(p) as u16,
            called: false,
            step: 0,
            value: None,
            sent: vec![false; log2(p) as usize],
            inbox: HashMap::new(),
            completed: false,
        }
    }

    fn partner(&self, k: u16) -> Rank {
        self.rank ^ (1usize << k)
    }

    fn advance(&mut self, ctx: &mut SwCtx) -> Vec<SwAction> {
        let mut out = Vec::new();
        if !self.called {
            return out;
        }
        while self.step < self.logp {
            let k = self.step;
            if !self.sent[k as usize] {
                self.sent[k as usize] = true;
                out.push(SwAction::Send {
                    dst: self.partner(k),
                    kind: SwMsgKind::Data,
                    step: k,
                    payload: self.value.clone().unwrap(),
                });
            }
            let Some(incoming) = self.inbox.remove(&k) else { break };
            let partner = self.partner(k);
            // rank-ordered in-place fold (mirrors fpga::allreduce)
            let mut value = self.value.take().unwrap();
            if partner < self.rank {
                ctx.combine_into_rev(&mut value, &incoming);
            } else {
                ctx.combine_into(&mut value, &incoming);
            }
            self.value = Some(value);
            self.step = k + 1;
        }
        if self.step == self.logp && !self.completed {
            self.completed = true;
            out.push(SwAction::Complete { result: self.value.clone().unwrap() });
        }
        out
    }
}

impl SwScanAlgo for SwRdAllreduce {
    fn on_call(&mut self, ctx: &mut SwCtx, own: &Payload) -> Vec<SwAction> {
        assert!(!self.called, "duplicate call");
        self.called = true;
        self.value = Some(own.clone());
        self.advance(ctx)
    }

    fn on_msg(&mut self, ctx: &mut SwCtx, msg: &SwMsg) -> Vec<SwAction> {
        assert_eq!(msg.src, self.partner(msg.step), "allreduce data from non-partner");
        assert!(self.inbox.insert(msg.step, msg.payload.clone()).is_none());
        self.advance(ctx)
    }

    fn done(&self) -> bool {
        self.completed
    }

    fn algo(&self) -> AlgoType {
        AlgoType::RecursiveDoubling
    }
}
