//! Software binomial-tree scan — the up/down-phase algorithm of the
//! paper's SSII-B3, run on the host.  Same mathematics as
//! `fpga::binomial`; the paper measured it as the worst software variant
//! (two tree traversals of host-stack messages) and omitted it from the
//! software figures.

use crate::data::Payload;
use crate::net::{Rank, SwMsg, SwMsgKind};
use crate::packet::{AlgoType, CollType};
use crate::util::is_pow2;

use super::{SwAction, SwCtx, SwScanAlgo};

pub struct SwBinomial {
    rank: Rank,
    p: usize,
    coll: CollType,
    t: u32,
    called: bool,
    own: Option<Payload>,
    child_bufs: Vec<Option<Payload>>,
    children_seen: usize,
    children_fold: Option<Payload>,
    block: Option<Payload>,
    up_sent: bool,
    down_in: Option<Payload>,
    prefix: Option<Payload>,
    downs_sent: bool,
    completed: bool,
}

impl SwBinomial {
    pub fn new(rank: Rank, p: usize, coll: CollType) -> SwBinomial {
        assert!(is_pow2(p), "binomial tree needs power-of-two ranks");
        let t = (rank as u64).trailing_ones();
        SwBinomial {
            rank,
            p,
            coll,
            t,
            called: false,
            own: None,
            child_bufs: vec![None; t as usize],
            children_seen: 0,
            children_fold: None,
            block: None,
            up_sent: false,
            down_in: None,
            prefix: None,
            downs_sent: false,
            completed: false,
        }
    }

    fn is_root(&self) -> bool {
        self.rank == self.p - 1
    }

    fn base_is_zero(&self) -> bool {
        self.rank + 1 == (1usize << self.t)
    }

    fn try_complete_up(&mut self, ctx: &mut SwCtx) -> Vec<SwAction> {
        let mut out = Vec::new();
        if self.block.is_some() || !self.called || self.children_seen != self.child_bufs.len() {
            return out;
        }
        // k-way in-place fold: one pooled buffer for the whole chain
        let mut fold: Option<Payload> = None;
        for k in (0..self.t as usize).rev() {
            let c = self.child_bufs[k].clone().unwrap();
            fold = Some(match fold {
                Some(mut f) => {
                    ctx.combine_into(&mut f, &c);
                    f
                }
                None => c,
            });
        }
        self.children_fold = fold.clone();
        let own = self.own.clone().unwrap();
        let block = match fold {
            Some(mut f) => {
                ctx.combine_into(&mut f, &own);
                f
            }
            None => own,
        };
        self.block = Some(block.clone());
        if !self.is_root() && !self.up_sent {
            self.up_sent = true;
            out.push(SwAction::Send {
                dst: self.rank + (1usize << self.t),
                kind: SwMsgKind::Up,
                step: self.t as u16,
                payload: block,
            });
        }
        if self.base_is_zero() {
            self.prefix = Some(self.block.clone().unwrap());
            out.extend(self.finish(ctx));
        } else if self.down_in.is_some() {
            out.extend(self.absorb_down(ctx));
        }
        out
    }

    fn absorb_down(&mut self, ctx: &mut SwCtx) -> Vec<SwAction> {
        if self.prefix.is_some() || self.block.is_none() || self.down_in.is_none() {
            return Vec::new();
        }
        let down = self.down_in.clone().unwrap();
        // prefix = down (op) block, folded in place
        let mut prefix = self.block.clone().unwrap();
        ctx.combine_into_rev(&mut prefix, &down);
        self.prefix = Some(prefix);
        self.finish(ctx)
    }

    fn finish(&mut self, ctx: &mut SwCtx) -> Vec<SwAction> {
        let mut out = Vec::new();
        let prefix = self.prefix.clone().unwrap();
        if !self.downs_sent {
            self.downs_sent = true;
            for k in (1..=self.t as u16).rev() {
                let target = self.rank + (1usize << (k - 1));
                if target < self.p {
                    out.push(SwAction::Send {
                        dst: target,
                        kind: SwMsgKind::Down,
                        step: k,
                        payload: prefix.clone(),
                    });
                }
            }
        }
        if !self.completed {
            self.completed = true;
            let result = if self.coll.inclusive() {
                prefix
            } else {
                match (&self.down_in, &self.children_fold) {
                    (Some(d), Some(cf)) => {
                        let mut r = cf.clone();
                        ctx.combine_into_rev(&mut r, d); // r = d (op) cf
                        r
                    }
                    (Some(d), None) => d.clone(),
                    (None, Some(cf)) => cf.clone(),
                    (None, None) => ctx.identity(self.own.as_ref().unwrap()),
                }
            };
            out.push(SwAction::Complete { result });
        }
        out
    }
}

impl SwScanAlgo for SwBinomial {
    fn on_call(&mut self, ctx: &mut SwCtx, own: &Payload) -> Vec<SwAction> {
        assert!(!self.called, "duplicate call");
        self.called = true;
        self.own = Some(own.clone());
        self.try_complete_up(ctx)
    }

    fn on_msg(&mut self, ctx: &mut SwCtx, msg: &SwMsg) -> Vec<SwAction> {
        match msg.kind {
            SwMsgKind::Up | SwMsgKind::Data => {
                let k = msg.step as usize;
                assert!(k < self.child_bufs.len(), "not my child");
                assert_eq!(msg.src + (1 << k), self.rank, "child/slot mismatch");
                assert!(self.child_bufs[k].is_none(), "child buffer overrun");
                self.child_bufs[k] = Some(msg.payload.clone());
                self.children_seen += 1;
                self.try_complete_up(ctx)
            }
            SwMsgKind::Down => {
                assert!(self.down_in.is_none(), "duplicate down prefix");
                self.down_in = Some(msg.payload.clone());
                self.absorb_down(ctx)
            }
        }
    }

    fn done(&self) -> bool {
        self.completed
    }

    fn algo(&self) -> AlgoType {
        AlgoType::BinomialTree
    }
}
