//! Software recursive-doubling scan — MPICH's default algorithm.
//!
//! Identical mathematics to `fpga::rd`, minus the hardware-only pieces
//! (no multicast engine, no inverse-subtract: the host simply keeps both
//! buffers).  The lockstep pairwise exchanges give it the implicit
//! synchronization the paper contrasts with the sequential algorithm.

use std::collections::HashMap;

use crate::data::Payload;
use crate::net::{Rank, SwMsg, SwMsgKind};
use crate::packet::{AlgoType, CollType};
use crate::util::{is_pow2, log2};

use super::{SwAction, SwCtx, SwScanAlgo};

pub struct SwRd {
    rank: Rank,
    logp: u16,
    coll: CollType,
    called: bool,
    step: u16,
    partial: Option<Payload>,
    recv_inc: Option<Payload>,
    recv_exc: Option<Payload>,
    sent: Vec<bool>,
    inbox: HashMap<u16, Payload>,
    completed: bool,
}

impl SwRd {
    pub fn new(rank: Rank, p: usize, coll: CollType) -> SwRd {
        assert!(is_pow2(p), "recursive doubling needs power-of-two ranks");
        let logp = log2(p) as u16;
        SwRd {
            rank,
            logp,
            coll,
            called: false,
            step: 0,
            partial: None,
            recv_inc: None,
            recv_exc: None,
            sent: vec![false; logp as usize],
            inbox: HashMap::new(),
            completed: false,
        }
    }

    fn partner(&self, k: u16) -> Rank {
        self.rank ^ (1usize << k)
    }

    fn advance(&mut self, ctx: &mut SwCtx) -> Vec<SwAction> {
        let mut out = Vec::new();
        if !self.called {
            return out;
        }
        while self.step < self.logp {
            let k = self.step;
            if !self.sent[k as usize] {
                self.sent[k as usize] = true;
                out.push(SwAction::Send {
                    dst: self.partner(k),
                    kind: SwMsgKind::Data,
                    step: k,
                    payload: self.partial.clone().unwrap(),
                });
            }
            let Some(incoming) = self.inbox.remove(&k) else { break };
            let partner = self.partner(k);
            // accumulators fold in place (mirrors fpga::rd::fold_step)
            let mut partial = self.partial.take().unwrap();
            if partner < self.rank {
                let mut inc = self.recv_inc.take().unwrap();
                ctx.combine_into_rev(&mut inc, &incoming);
                self.recv_inc = Some(inc);
                self.recv_exc = Some(match self.recv_exc.take() {
                    Some(mut exc) => {
                        ctx.combine_into_rev(&mut exc, &incoming);
                        exc
                    }
                    None => incoming.clone(),
                });
                ctx.combine_into_rev(&mut partial, &incoming);
            } else {
                ctx.combine_into(&mut partial, &incoming);
            }
            self.partial = Some(partial);
            self.step = k + 1;
        }
        if self.step == self.logp && !self.completed {
            self.completed = true;
            let result = if self.coll.inclusive() {
                self.recv_inc.clone().unwrap()
            } else {
                match &self.recv_exc {
                    Some(exc) => exc.clone(),
                    None => ctx.identity(self.recv_inc.as_ref().unwrap()),
                }
            };
            out.push(SwAction::Complete { result });
        }
        out
    }
}

impl SwScanAlgo for SwRd {
    fn on_call(&mut self, ctx: &mut SwCtx, own: &Payload) -> Vec<SwAction> {
        assert!(!self.called, "duplicate call");
        self.called = true;
        self.partial = Some(own.clone());
        self.recv_inc = Some(own.clone());
        self.advance(ctx)
    }

    fn on_msg(&mut self, ctx: &mut SwCtx, msg: &SwMsg) -> Vec<SwAction> {
        assert_eq!(msg.src, self.partner(msg.step), "rd data from non-partner");
        assert!(self.inbox.insert(msg.step, msg.payload.clone()).is_none());
        self.advance(ctx)
    }

    fn done(&self) -> bool {
        self.completed
    }

    fn algo(&self) -> AlgoType {
        AlgoType::RecursiveDoubling
    }
}
