//! Software sequential scan — Open MPI's default algorithm.
//!
//! No ACKs and no return gating: "once a process produces its partial
//! sum, it simply returns and continues its execution ... the data
//! transfer is handled in another layer of the MPI stack" — which is why
//! this algorithm posts the lowest *average* latency in the paper's
//! Fig. 4 despite O(p) steps.

use crate::data::Payload;
use crate::net::{Rank, SwMsg, SwMsgKind};
use crate::packet::{AlgoType, CollType};

use super::{SwAction, SwCtx, SwScanAlgo};

pub struct SwSeq {
    rank: Rank,
    p: usize,
    coll: CollType,
    called: bool,
    own: Option<Payload>,
    /// Unexpected-message queue slot for the upstream partial.
    upstream: Option<Payload>,
    completed: bool,
}

impl SwSeq {
    pub fn new(rank: Rank, p: usize, coll: CollType) -> SwSeq {
        SwSeq { rank, p, coll, called: false, own: None, upstream: None, completed: false }
    }

    fn proceed(&mut self, ctx: &mut SwCtx) -> Vec<SwAction> {
        let mut out = Vec::new();
        if !self.called || self.completed {
            return out;
        }
        let own = self.own.clone().unwrap();
        if self.rank == 0 {
            self.completed = true;
            if self.p > 1 {
                out.push(SwAction::Send {
                    dst: 1,
                    kind: SwMsgKind::Data,
                    step: 0,
                    payload: own.clone(),
                });
            }
            let result = if self.coll.inclusive() { own } else { ctx.identity(&own) };
            out.push(SwAction::Complete { result });
        } else if let Some(upstream) = self.upstream.clone() {
            self.completed = true;
            // prefix = upstream (op) own, folded in place
            let mut prefix = upstream.clone();
            ctx.combine_into(&mut prefix, &own);
            if self.rank + 1 < self.p {
                out.push(SwAction::Send {
                    dst: self.rank + 1,
                    kind: SwMsgKind::Data,
                    step: 0,
                    payload: prefix.clone(),
                });
            }
            let result = if self.coll.inclusive() { prefix } else { upstream };
            out.push(SwAction::Complete { result });
        }
        out
    }
}

impl SwScanAlgo for SwSeq {
    fn on_call(&mut self, ctx: &mut SwCtx, own: &Payload) -> Vec<SwAction> {
        assert!(!self.called, "duplicate call");
        self.called = true;
        self.own = Some(own.clone());
        self.proceed(ctx)
    }

    fn on_msg(&mut self, ctx: &mut SwCtx, msg: &SwMsg) -> Vec<SwAction> {
        assert_eq!(msg.src, self.rank - 1, "sequential data must come from j-1");
        assert!(self.upstream.is_none(), "duplicate upstream partial");
        self.upstream = Some(msg.payload.clone());
        self.proceed(ctx)
    }

    fn done(&self) -> bool {
        self.completed
    }

    fn algo(&self) -> AlgoType {
        AlgoType::Sequential
    }
}
