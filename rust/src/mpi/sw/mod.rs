//! Host-side scan algorithm state machines.
//!
//! Each instance runs one collective invocation (one epoch) on one rank,
//! mirroring `fpga::engine` — but actions hand messages to the *host
//! stack* and completion means the MPI_Scan call returns.  Messages that
//! arrive before the local call are buffered in the unexpected-message
//! queue (software has host RAM; no ACK machinery needed — that asymmetry
//! is exactly what the paper's SSIII-B is about).

pub mod allreduce;
pub mod bcast;
pub mod binomial;
pub mod rd;
pub mod seq;

use crate::config::CostModel;
use crate::data::{Op, Payload};
use crate::net::{Rank, SwMsg, SwMsgKind};
use crate::packet::{AlgoType, CollType};
use crate::runtime::Compute;

/// What a host-side machine asks the MPI layer to do.
#[derive(Debug)]
pub enum SwAction {
    /// Hand a message to the stack for `dst` (non-blocking hand-off).
    Send { dst: Rank, kind: SwMsgKind, step: u16, payload: Payload },
    /// The MPI_Scan call returns with `result`.
    Complete { result: Payload },
}

/// Activation context: compute access + host-CPU time accounting.
pub struct SwCtx<'a> {
    pub rank: Rank,
    pub p: usize,
    pub inclusive: bool,
    pub op: Op,
    pub compute: &'a dyn Compute,
    pub cost: &'a CostModel,
    /// Host CPU time consumed by this activation (reduction work).
    pub elapsed_ns: u64,
}

impl SwCtx<'_> {
    /// Elementwise combine on the host CPU.
    pub fn combine(&mut self, a: &Payload, b: &Payload) -> Payload {
        self.elapsed_ns += self.cost.host_combine_ns(a.byte_len());
        self.compute.combine(a, b, self.op).expect("sw combine")
    }

    /// In-place combine `acc = acc (op) b` — same time charge and
    /// bit-identical result as [`SwCtx::combine`], without allocating.
    pub fn combine_into(&mut self, acc: &mut Payload, b: &Payload) {
        self.elapsed_ns += self.cost.host_combine_ns(acc.byte_len());
        self.compute.combine_into(acc, b, self.op).expect("sw combine");
    }

    /// In-place combine with the accumulator on the right: `acc = a (op) acc`.
    pub fn combine_into_rev(&mut self, acc: &mut Payload, a: &Payload) {
        self.elapsed_ns += self.cost.host_combine_ns(a.byte_len());
        self.compute.combine_into_rev(acc, a, self.op).expect("sw combine");
    }

    pub fn identity(&self, like: &Payload) -> Payload {
        Payload::identity(like.dtype(), self.op, like.len())
    }
}

/// One software collective invocation on one rank.
pub trait SwScanAlgo {
    fn on_call(&mut self, ctx: &mut SwCtx, own: &Payload) -> Vec<SwAction>;
    fn on_msg(&mut self, ctx: &mut SwCtx, msg: &SwMsg) -> Vec<SwAction>;
    fn done(&self) -> bool;
    fn algo(&self) -> AlgoType;
}

pub fn make_sw(algo: AlgoType, rank: Rank, p: usize, coll: CollType) -> Box<dyn SwScanAlgo> {
    match coll {
        CollType::Scan | CollType::Exscan => match algo {
            AlgoType::Sequential => Box::new(seq::SwSeq::new(rank, p, coll)),
            AlgoType::RecursiveDoubling => Box::new(rd::SwRd::new(rank, p, coll)),
            AlgoType::BinomialTree => Box::new(binomial::SwBinomial::new(rank, p, coll)),
        },
        CollType::Allreduce | CollType::Barrier => {
            // software baseline: MPICH's recursive doubling regardless of
            // the requested tree shape (matches the comparison baseline
            // of the companion works [6][7])
            Box::new(allreduce::SwRdAllreduce::new(rank, p, coll))
        }
        CollType::Bcast => Box::new(bcast::SwBcast::new(rank, p)),
        CollType::Reduce => panic!("software MPI_Reduce not implemented"),
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    //! In-memory harness mirroring `fpga::engine::testutil`.

    use std::collections::VecDeque;

    use super::*;
    use crate::runtime::NativeEngine;

    pub struct SwHarness {
        pub p: usize,
        pub coll: CollType,
        pub op: Op,
        pub algos: Vec<Box<dyn SwScanAlgo>>,
        pub results: Vec<Option<Payload>>,
        queue: VecDeque<(Rank, SwMsg)>,
        compute: NativeEngine,
        cost: CostModel,
    }

    impl SwHarness {
        pub fn new(algo: AlgoType, p: usize, coll: CollType) -> SwHarness {
            SwHarness {
                p,
                coll,
                op: Op::Sum,
                algos: (0..p).map(|r| make_sw(algo, r, p, coll)).collect(),
                results: vec![None; p],
                queue: VecDeque::new(),
                compute: NativeEngine::new(),
                cost: CostModel::default(),
            }
        }

        fn enqueue(&mut self, from: Rank, actions: Vec<SwAction>) {
            for a in actions {
                match a {
                    SwAction::Send { dst, kind, step, payload } => {
                        let msg = SwMsg {
                            src: from,
                            algo: self.algos[from].algo().wire_code(),
                            kind,
                            epoch: 0,
                            step,
                            count: payload.len() as u32,
                            frag_idx: 0,
                            frag_total: 1,
                            payload,
                        };
                        self.queue.push_back((dst, msg));
                    }
                    SwAction::Complete { result } => {
                        assert!(self.results[from].is_none(), "double completion at {from}");
                        self.results[from] = Some(result);
                    }
                }
            }
        }

        pub fn call(&mut self, rank: Rank, own: Payload) {
            // field-disjoint borrows: algos (mut) + compute/cost (ref)
            let mut ctx = SwCtx {
                rank,
                p: self.p,
                inclusive: self.coll.inclusive(),
                op: self.op,
                compute: &self.compute,
                cost: &self.cost,
                elapsed_ns: 0,
            };
            let actions = self.algos[rank].on_call(&mut ctx, &own);
            self.enqueue(rank, actions);
        }

        pub fn drain(&mut self) {
            while let Some((dst, msg)) = self.queue.pop_front() {
                let mut ctx = SwCtx {
                    rank: dst,
                    p: self.p,
                    inclusive: self.coll.inclusive(),
                    op: self.op,
                    compute: &self.compute,
                    cost: &self.cost,
                    elapsed_ns: 0,
                };
                let actions = self.algos[dst].on_msg(&mut ctx, &msg);
                self.enqueue(dst, actions);
            }
        }

        pub fn run_and_check(&mut self, contributions: &[Vec<i32>], order: &[Rank]) {
            for &r in order {
                self.call(r, Payload::from_i32(&contributions[r]));
                self.drain();
            }
            let payloads: Vec<Payload> =
                contributions.iter().map(|c| Payload::from_i32(c)).collect();
            for r in 0..self.p {
                let want = match self.coll {
                    CollType::Scan | CollType::Exscan => crate::runtime::engine::oracle_prefix(
                        &self.compute,
                        &payloads,
                        self.op,
                        self.coll.inclusive(),
                        r,
                    )
                    .unwrap(),
                    CollType::Allreduce | CollType::Barrier => {
                        crate::runtime::engine::oracle_prefix(
                            &self.compute,
                            &payloads,
                            self.op,
                            true,
                            self.p - 1,
                        )
                        .unwrap()
                    }
                    CollType::Bcast => {
                        // every rank receives the root's contribution
                        payloads[0].clone()
                    }
                    CollType::Reduce => unreachable!(),
                };
                let got =
                    self.results[r].as_ref().unwrap_or_else(|| panic!("rank {r} no result"));
                assert_eq!(
                    got.to_i32(),
                    want.to_i32(),
                    "rank {r} wrong sw {:?} result",
                    self.coll
                );
                assert!(self.algos[r].done(), "rank {r} sw algo not done");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::SwHarness;
    use super::*;

    fn contributions(p: usize) -> Vec<Vec<i32>> {
        (0..p).map(|r| vec![r as i32 + 3, 7 - r as i32]).collect()
    }

    #[test]
    fn all_algos_all_orders() {
        for algo in AlgoType::ALL {
            for p in [2usize, 4, 8, 16] {
                for coll in [CollType::Scan, CollType::Exscan] {
                    let orders: Vec<Vec<usize>> = vec![
                        (0..p).collect(),
                        (0..p).rev().collect(),
                        (0..p).step_by(2).chain((1..p).step_by(2)).collect(),
                    ];
                    for order in orders {
                        let mut h = SwHarness::new(algo, p, coll);
                        h.run_and_check(&contributions(p), &order);
                    }
                }
            }
        }
    }

    #[test]
    fn sequential_odd_p() {
        let mut h = SwHarness::new(AlgoType::Sequential, 7, CollType::Scan);
        h.run_and_check(&contributions(7), &[6, 5, 4, 3, 2, 1, 0]);
    }

    #[test]
    fn bcast_sw_all_orders() {
        for p in [2usize, 4, 8, 16] {
            let orders: Vec<Vec<usize>> = vec![
                (0..p).collect(),
                (0..p).rev().collect(),
                (0..p).step_by(2).chain((1..p).step_by(2)).collect(),
            ];
            for order in orders {
                let mut h = SwHarness::new(AlgoType::BinomialTree, p, CollType::Bcast);
                h.run_and_check(&contributions(p), &order);
            }
        }
    }

    #[test]
    fn allreduce_and_barrier_sw() {
        for p in [2usize, 4, 8, 16] {
            let mut h = SwHarness::new(AlgoType::RecursiveDoubling, p, CollType::Allreduce);
            h.run_and_check(&contributions(p), &(0..p).rev().collect::<Vec<_>>());
            let mut h = SwHarness::new(AlgoType::RecursiveDoubling, p, CollType::Barrier);
            h.run_and_check(&vec![vec![]; p], &(0..p).collect::<Vec<_>>());
        }
    }
}
