//! Software MPI_Bcast — MPICH's binomial tree rooted at rank 0, run on
//! the host.  The baseline the handler-VM bcast program is
//! cross-validated against (`prop::cross`): values must match the root's
//! contribution bit-for-bit on every rank; only latencies may differ.
//!
//! Receive mask: walk `mask = 1, 2, 4, ...` until `rank & mask != 0` —
//! the parent is `rank - mask`.  Rank 0 exits the walk at `mask == p`
//! and only forwards.  Forwarding covers every mask below the receive
//! mask, so the root reaches p-1 in log2(p) message generations.

use crate::data::Payload;
use crate::net::{Rank, SwMsg, SwMsgKind};
use crate::packet::AlgoType;
use crate::util::is_pow2;

use super::{SwAction, SwCtx, SwScanAlgo};

pub struct SwBcast {
    rank: Rank,
    p: usize,
    /// Mask at which this rank receives; `p` for the root (never
    /// receives).  Forwarding walks the masks strictly below it.
    recv_mask: usize,
    called: bool,
    data: Option<Payload>,
    forwarded: bool,
    completed: bool,
}

impl SwBcast {
    pub fn new(rank: Rank, p: usize) -> SwBcast {
        assert!(is_pow2(p), "binomial bcast needs power-of-two ranks");
        let mut mask = 1;
        while mask < p && rank & mask == 0 {
            mask <<= 1;
        }
        SwBcast {
            rank,
            p,
            recv_mask: mask,
            called: false,
            data: None,
            forwarded: false,
            completed: false,
        }
    }

    /// Forward + complete once both the local call and the root's data
    /// are in.  The library acts only on behalf of a process that has
    /// entered the collective — pre-call data sits in the unexpected-
    /// message buffer like every other software machine's.
    fn try_progress(&mut self) -> Vec<SwAction> {
        let mut out = Vec::new();
        if !self.called {
            return out;
        }
        let Some(data) = self.data.clone() else { return out };
        if !self.forwarded {
            self.forwarded = true;
            let mut mask = self.recv_mask >> 1;
            while mask > 0 {
                let dst = self.rank + mask;
                if dst < self.p {
                    out.push(SwAction::Send {
                        dst,
                        kind: SwMsgKind::Down,
                        step: 0,
                        payload: data.clone(),
                    });
                }
                mask >>= 1;
            }
        }
        if !self.completed {
            self.completed = true;
            out.push(SwAction::Complete { result: data });
        }
        out
    }
}

impl SwScanAlgo for SwBcast {
    fn on_call(&mut self, _ctx: &mut SwCtx, own: &Payload) -> Vec<SwAction> {
        assert!(!self.called, "duplicate call");
        self.called = true;
        if self.rank == 0 {
            self.data = Some(own.clone());
        }
        self.try_progress()
    }

    fn on_msg(&mut self, _ctx: &mut SwCtx, msg: &SwMsg) -> Vec<SwAction> {
        assert_eq!(msg.kind, SwMsgKind::Down, "bcast only carries Down data");
        assert_ne!(self.rank, 0, "the root never receives");
        assert_eq!(msg.src, self.rank - self.recv_mask, "bcast data must come from the parent");
        assert!(self.data.is_none(), "duplicate bcast data");
        self.data = Some(msg.payload.clone());
        self.try_progress()
    }

    fn done(&self) -> bool {
        self.completed && self.forwarded
    }

    fn algo(&self) -> AlgoType {
        AlgoType::BinomialTree
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recv_masks() {
        // p = 8: rank 0 never receives (mask = p); others at lowest set bit
        assert_eq!(SwBcast::new(0, 8).recv_mask, 8);
        assert_eq!(SwBcast::new(1, 8).recv_mask, 1);
        assert_eq!(SwBcast::new(4, 8).recv_mask, 4);
        assert_eq!(SwBcast::new(6, 8).recv_mask, 2);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        SwBcast::new(0, 6);
    }
}
