//! The software-MPI substrate: the baseline the paper compares against.
//!
//! The same three scan algorithms as the hardware engines, but run on the
//! host CPU over the kernel network stack (Open MPI's sequential default,
//! MPICH's recursive doubling, and the binomial tree).  Costs differ from
//! the offload path — every message pays the host stack's per-message +
//! per-byte price, but there are no host<->NIC crossings and "the data
//! transfer is handled in another layer of the MPI stack", so a rank can
//! complete as soon as it hands its send off (the paper's explanation for
//! software-sequential's low average latency).

pub mod sw;

pub use sw::{make_sw, SwAction, SwCtx, SwScanAlgo};
